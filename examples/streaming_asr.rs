//! Streaming session demo: the "YouTube long-utterance" scenario.
//!
//! One very long stream is fed chunk-by-chunk through a persistent
//! session — the integer engine's state (int16 cell, int8 hidden)
//! carries across chunks exactly like a streaming speech recognizer's.
//! We track the float-vs-integer prediction divergence over time to
//! show quantization error does **not** accumulate (the paper's
//! robustness claim for the YouTube set).
//!
//! ```sh
//! make artifacts && cargo run --release --example streaming_asr
//! ```

use iqrnn::lstm::{QuantizeOptions, StackEngine};
use iqrnn::model::lm::CharLm;
use iqrnn::workload::corpus::{calibration_sequences, load_eval_sets};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let lm = CharLm::load(&artifacts)?;
    let corpus = std::path::Path::new(&artifacts).join("corpus.txt");
    let calib = calibration_sequences(&corpus, 100, 64, 11)?;
    let stats = lm.calibrate(&calib);

    let float = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
    let integer = lm.engine(StackEngine::Integer, Some(&stats), QuantizeOptions::default());

    // A single long stream (the YouTube analog: avg 16.5 min/utterance
    // in the paper; here 6000 tokens ≈ 6 "minutes" at the nominal rate).
    let sets = load_eval_sets(&corpus, 1, 64, 1, 6000, 0.0, 33)?;
    let stream = &sets[1].sequences[0];
    println!("streaming one {}-token utterance in 500-token chunks", stream.len());

    let mut f_state = float.new_state();
    let mut i_state = integer.new_state();
    let mut f_nll = 0f64;
    let mut i_nll = 0f64;
    let mut n = 0usize;
    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "tokens", "float bpc", "integer bpc", "Δbpc (window)"
    );
    for chunk in stream.windows(2).collect::<Vec<_>>().chunks(500) {
        let mut fw = 0f64;
        let mut iw = 0f64;
        for w in chunk {
            float.step_token(w[0], &mut f_state);
            integer.step_token(w[0], &mut i_state);
            fw += iqrnn::model::lm::nll_bits(&f_state.logits, w[1]);
            iw += iqrnn::model::lm::nll_bits(&i_state.logits, w[1]);
        }
        f_nll += fw;
        i_nll += iw;
        n += chunk.len();
        println!(
            "{:>8} {:>12.4} {:>12.4} {:>+14.4}",
            n,
            f_nll / n as f64,
            i_nll / n as f64,
            (iw - fw) / chunk.len() as f64
        );
    }
    let degradation = (i_nll - f_nll) / n as f64;
    println!(
        "\nfinal: float {:.4} bpc, integer {:.4} bpc, degradation {:+.4} bpc \
         over {} tokens (stable ⇒ no error accumulation)",
        f_nll / n as f64,
        i_nll / n as f64,
        degradation,
        n
    );
    anyhow::ensure!(degradation.abs() < 0.2, "quantization drift too large");
    println!("streaming_asr OK");
    Ok(())
}
