//! End-to-end driver (experiment E10): load the *trained* char-LM
//! artifacts, serve a batched streaming request trace through all three
//! engines, and report latency / throughput / RT factor plus quality
//! parity — the full stack in one run:
//!
//!   python-trained weights → rust loader → post-training calibration →
//!   Table-2 quantization → sticky-session coordinator → metrics,
//!   with the PJRT runtime executing the AOT float artifact as a
//!   cross-check of the serving numerics.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use std::time::Duration;

use iqrnn::coordinator::{
    shard_home, BatchPolicy, Frame, ModelRegistry, ModelSpec, NetClient, NetConfig,
    NetServer, NetShutdown, Residency, SchedulerMode, Server, ServerConfig,
};
use iqrnn::lstm::{QuantizeOptions, StackEngine};
use iqrnn::model::lm::{CharLm, VOCAB};
use iqrnn::workload::corpus::{calibration_sequences, load_eval_sets, EvalSet};
use iqrnn::workload::synth::RequestTrace;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let lm = CharLm::load(&artifacts)?;
    println!(
        "loaded trained char-LM: hidden={} depth={} ({} params)",
        lm.hidden,
        lm.depth,
        lm.stack_weights.param_count()
    );

    // Post-training calibration: 100 short sequences (the paper's §5
    // finding: a fixed 100-utterance set suffices).
    let corpus = std::path::Path::new(&artifacts).join("corpus.txt");
    let calib = calibration_sequences(&corpus, 100, 64, 11)?;
    let stats = lm.calibrate(&calib);
    println!("calibrated on {} sequences", calib.len());

    // --- Quality parity (Table-1 analog, abbreviated) ---------------
    println!("\n== quality (bits/char on held-out corpus) ==");
    let sets = load_eval_sets(&corpus, 8, 128, 1, 1500, 0.05, 21)?;
    println!("{:<8} {:>9} {:>9} {:>9}", "set", "Float", "Hybrid", "Integer");
    for set in &sets {
        let mut row = Vec::new();
        for engine in StackEngine::ALL {
            let e = lm.engine(engine, Some(&stats), QuantizeOptions::default());
            let bpc: f64 = set.sequences.iter().map(|s| e.bits_per_char(s)).sum::<f64>()
                / set.sequences.len() as f64;
            row.push(bpc);
        }
        println!(
            "{:<8} {:>9.4} {:>9.4} {:>9.4}",
            set.name, row[0], row[1], row[2]
        );
    }

    // --- Serving: batched streaming requests -------------------------
    println!("\n== serving (open-loop trace, 2 workers, batch<=8) ==");
    let trace = RequestTrace::generate(150, 400.0, 80, VOCAB, 17);
    println!(
        "trace: {} requests, {} tokens, {:.1}s span",
        trace.requests.len(),
        trace.total_tokens(),
        trace.span_secs()
    );
    let mut reports = Vec::new();
    for engine in StackEngine::ALL {
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
                engine,
                opts: QuantizeOptions::default(),
                mode: SchedulerMode::Continuous,
                ..ServerConfig::default()
            },
        );
        let report = server.run_trace(&trace, 4.0)?;
        report.print();
        reports.push(report);
    }

    // --- Continuous batching vs the PR 1 wave-at-a-time baseline -----
    println!("\n== scheduler A/B: wave-at-a-time vs continuous (Integer) ==");
    for mode in [SchedulerMode::Wave, SchedulerMode::Continuous] {
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
                engine: StackEngine::Integer,
                opts: QuantizeOptions::default(),
                mode,
                ..ServerConfig::default()
            },
        );
        let report = server.run_trace(&trace, 4.0)?;
        report.print();
        if mode == SchedulerMode::Continuous {
            println!(
                "  (lanes turned over {} times; mean admission wait {:.2}ms)",
                report.lane_admissions, report.mean_admission_ms
            );
        }
    }
    // --- Sharded serving: skewed routing, work stealing A/B ----------
    // Every session hash-homes to worker 0 — the adversarial case for
    // static sticky routing. Stealing lets the other workers pull the
    // backlog over; `--workers 1` stays the single-worker baseline.
    println!("\n== sharded serving: skewed routing, steal A/B (Integer) ==");
    for &workers in &[1usize, 2, 4] {
        let mut skewed = RequestTrace::generate(120, 600.0, 40, VOCAB, 23);
        skewed.reassign_ids(|id| shard_home(id, workers) == 0);
        for steal in [false, true] {
            let server = Server::new(
                &lm,
                Some(&stats),
                ServerConfig {
                    workers,
                    batch: BatchPolicy {
                        max_batch: 8,
                        max_wait: Duration::from_millis(2),
                    },
                    engine: StackEngine::Integer,
                    opts: QuantizeOptions::default(),
                    mode: SchedulerMode::Continuous,
                    steal,
                    ..ServerConfig::default()
                },
            );
            let report = server.run_trace(&skewed, 4.0)?;
            print!("  workers={workers} steal={}", if steal { "on " } else { "off" });
            report.print();
        }
    }

    // --- Multi-model serving: one registry, several variants ---------
    // The trained artifact registered twice — an integer variant and a
    // hybrid A/B recipe — served as a mixed trace over one pool. The
    // per-model lines break out occupancy, steals, evictions, and the
    // resident weight bytes each variant costs the fleet.
    println!("\n== multi-model serving: integer + hybrid A/B over one pool ==");
    {
        let mut registry = ModelRegistry::new();
        registry.register(ModelSpec {
            name: "int-prod".into(),
            lm: &lm,
            engine: StackEngine::Integer,
            stats: Some(&stats),
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        registry.register(ModelSpec {
            name: "hybrid-ab".into(),
            lm: &lm,
            engine: StackEngine::Hybrid,
            stats: Some(&stats),
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        let mut mixed = RequestTrace::generate(120, 500.0, 40, VOCAB, 29);
        mixed.assign_models(|id| (id % 2) as iqrnn::coordinator::ModelId);
        let server = Server::with_registry(
            registry,
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
                ..ServerConfig::default()
            },
        );
        let report = server.run_trace(&mixed, 4.0)?;
        report.print();
        report.print_models();
    }

    // --- Network serving: loopback TCP front (wall-clock) ------------
    // The same pool behind a real socket: frames in, token streams
    // out, with Busy backpressure and graceful drain. Wall-clock
    // first-token / per-token latencies appear on the report's second
    // line; the loopback tests pin the streams bit-identical to the
    // shard simulator.
    println!("\n== network serving: loopback TCP front (Integer) ==");
    {
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
                engine: StackEngine::Integer,
                opts: QuantizeOptions::default(),
                ..ServerConfig::default()
            },
        );
        let net_trace = RequestTrace::generate(60, 500.0, 40, VOCAB, 31);
        let net = NetServer::bind(
            &server,
            NetConfig {
                max_inflight_per_model: Some(net_trace.requests.len()),
                ..NetConfig::default()
            },
        )?;
        let addr = net.local_addr()?;
        let stop = NetShutdown::new();
        let report = std::thread::scope(|s| -> anyhow::Result<_> {
            let handle = s.spawn(|| net.serve(&stop));
            let mut client = NetClient::connect(addr)?;
            let mut streamed = 0usize;
            for req in &net_trace.requests {
                client.send(req.model, req.id, &req.tokens)?;
            }
            client.finish()?;
            for frame in client.read_to_bye()? {
                if matches!(frame, Frame::Token { .. }) {
                    streamed += 1;
                }
            }
            println!(
                "  loopback client on {addr}: {} requests, {streamed} tokens streamed",
                net_trace.requests.len()
            );
            stop.shutdown();
            handle.join().expect("serve thread")
        })?;
        println!(
            "  connections={} refused={} busy={}",
            report.connections, report.refused_connects, report.busy_rejections
        );
        report.serving.print();
    }

    let speedup_float = reports[0].compute_secs / reports[2].compute_secs;
    let speedup_hybrid = reports[1].compute_secs / reports[2].compute_secs;
    println!(
        "integer speedup: {speedup_float:.2}x vs float, {speedup_hybrid:.2}x vs hybrid \
         (paper §6: ~2x vs float, ~1.05x vs hybrid)"
    );

    // --- PJRT runtime cross-check (needs the xla-runtime feature) ----
    pjrt_cross_check(&artifacts, &lm, &sets)?;

    println!("\ne2e_serving OK");
    Ok(())
}

#[cfg(feature = "xla-runtime")]
fn pjrt_cross_check(artifacts: &str, lm: &CharLm, sets: &[EvalSet]) -> anyhow::Result<()> {
    use iqrnn::model::lm::one_hot_seq;
    use iqrnn::runtime::pjrt::CharLmRuntime;

    println!("\n== PJRT runtime cross-check (AOT float artifact) ==");
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let runtime = CharLmRuntime::load(&client, artifacts, 8, VOCAB, lm.hidden, lm.depth)?;
    let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
    let seq = &sets[0].sequences[0][..32.min(sets[0].sequences[0].len())];
    let mut rust_state = engine.new_state();
    let mut rt_state = runtime.zero_state();
    let mut x = vec![0f32; 8 * VOCAB];
    let mut worst = 0f32;
    for oh in one_hot_seq(seq) {
        x[..VOCAB].copy_from_slice(&oh);
        let logits = runtime.step(&x, &mut rt_state)?;
        // Reconstruct the token index to drive the rust engine.
        let tok = oh.iter().position(|&v| v == 1.0).unwrap();
        engine.step_token(tok, &mut rust_state);
        for (a, b) in rust_state.logits.iter().zip(&logits[..VOCAB]) {
            worst = worst.max((a - b).abs());
        }
    }
    println!("max |rust float − XLA runtime| logit divergence: {worst:.2e}");
    anyhow::ensure!(worst < 2e-3, "runtime cross-check failed");
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn pjrt_cross_check(_artifacts: &str, _lm: &CharLm, _sets: &[EvalSet]) -> anyhow::Result<()> {
    println!(
        "\n(PJRT runtime cross-check skipped: add `xla = \"0.1\"` to [dependencies] \
         and build with --features xla-runtime)"
    );
    Ok(())
}
