//! Quickstart: quantize an LSTM post-training and run it with integer
//! arithmetic only.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use iqrnn::lstm::{
    CalibrationStats, FloatLstm, FloatState, IntegerState, LstmSpec,
    LstmWeights, QuantizeOptions,
};
use iqrnn::lstm::quantize_lstm;
use iqrnn::util::Pcg32;

fn main() {
    // 1. A float LSTM (here random; in practice load trained weights).
    //    Variants (peephole/projection/layer-norm/CIFG) are flags on
    //    the spec — all are supported by the integer path.
    let mut rng = Pcg32::seeded(7);
    let spec = LstmSpec::plain(32, 64).with_peephole();
    let weights = LstmWeights::random(spec, &mut rng);
    let float = FloatLstm::new(weights.clone());

    // 2. Post-training calibration (§4 of the paper): run a small
    //    representative dataset through the float model, recording the
    //    dynamic ranges of every tensor the recipe needs.
    let calib: Vec<Vec<Vec<f32>>> = (0..16)
        .map(|_| {
            (0..32)
                .map(|_| (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect()
        })
        .collect();
    let stats = CalibrationStats::collect(&float, &calib);
    println!(
        "calibrated on {} sequences: x∈[{:.2},{:.2}] h∈[{:.2},{:.2}] |c|max={:.2}",
        stats.sequences, stats.x.min, stats.x.max, stats.h.min, stats.h.max,
        stats.c.max_abs()
    );

    // 3. Quantize with the Table-2 recipe: int8 weights, int16
    //    cell/activations, int32 accumulators, no floats at inference.
    let integer = quantize_lstm(&weights, &stats, QuantizeOptions::default());
    println!(
        "quantized: cell format Q{}.{}  weights {}B (float was {}B)",
        integer.cell_ib,
        15 - integer.cell_ib,
        integer.weight_bytes(),
        weights.param_count() * 4
    );

    // 4. Run both engines on fresh data and compare.
    let mut fs = FloatState::zeros(&spec);
    let mut is = IntegerState::zeros(&integer);
    let mut worst = 0f32;
    let mut h_int = vec![0f32; spec.n_output];
    for t in 0..50 {
        let x: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        float.step(&x, &mut fs);
        integer.step(&x, &mut is);
        integer.dequantize_h(&is, &mut h_int);
        for (a, b) in fs.h.iter().zip(&h_int) {
            worst = worst.max((a - b).abs());
        }
        if t % 10 == 0 {
            println!("step {t:>2}: float h[0]={:+.4} integer h[0]={:+.4}", fs.h[0], h_int[0]);
        }
    }
    println!("max |float - integer| divergence over 50 steps: {worst:.4}");
    assert!(worst < 0.1);
    println!("quickstart OK");
}
