//! Experiment E9: how much calibration data does post-training
//! quantization need?
//!
//! The paper (§5): "A fixed 100-utterances dataset is sufficient to
//! quantize the model with negligible accuracy loss." This sweep
//! quantizes the trained char-LM with calibration sets from 1 to 200
//! sequences and reports the integer engine's quality at each size.
//!
//! ```sh
//! make artifacts && cargo run --release --example calibration_sweep
//! ```

use iqrnn::lstm::{QuantizeOptions, StackEngine};
use iqrnn::model::lm::CharLm;
use iqrnn::workload::corpus::{calibration_sequences, load_eval_sets};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let lm = CharLm::load(&artifacts)?;
    let corpus = std::path::Path::new(&artifacts).join("corpus.txt");

    let sets = load_eval_sets(&corpus, 8, 128, 0, 1, 0.0, 44)?;
    let eval = &sets[0];

    let float = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
    let float_bpc: f64 = eval.sequences.iter().map(|s| float.bits_per_char(s)).sum::<f64>()
        / eval.sequences.len() as f64;
    println!("float baseline: {float_bpc:.4} bpc\n");
    println!("{:>10} {:>12} {:>12}", "calib size", "integer bpc", "Δ vs float");

    let mut at_100 = f64::NAN;
    let mut at_1 = f64::NAN;
    for &n in &[1usize, 2, 5, 10, 25, 50, 100, 200] {
        let calib = calibration_sequences(&corpus, n, 64, 11)?;
        let stats = lm.calibrate(&calib);
        let integer = lm.engine(StackEngine::Integer, Some(&stats), QuantizeOptions::default());
        let bpc: f64 = eval.sequences.iter().map(|s| integer.bits_per_char(s)).sum::<f64>()
            / eval.sequences.len() as f64;
        println!("{n:>10} {bpc:>12.4} {:>+12.4}", bpc - float_bpc);
        if n == 100 {
            at_100 = bpc;
        }
        if n == 1 {
            at_1 = bpc;
        }
    }
    println!(
        "\npaper's claim: ~100 sequences suffice — Δ at 100 = {:+.4} bpc",
        at_100 - float_bpc
    );
    anyhow::ensure!(at_100 - float_bpc < 0.1, "100-sequence calibration degraded too much");
    // Tiny calibration sets should generally be no better (they can
    // get lucky, so this is informational only).
    println!("Δ at 1 = {:+.4} bpc (informational)", at_1 - float_bpc);
    println!("calibration_sweep OK");
    Ok(())
}
