"""Test configuration: the integer fixed-point mirrors need int64."""
import jax

jax.config.update("jax_enable_x64", True)
