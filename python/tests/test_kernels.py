"""Pallas kernel vs pure-jnp reference: must be bit-exact, across
shapes, dtypes ranges and variants (hypothesis-driven)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.qlstm import make_qlstm_step, qmatmul_rescale


def random_float_weights(rng, n_input, n_cell, n_output, *, peephole=False,
                         proj=False, cifg=False):
    def gate():
        return {
            "w": rng.normal(0, 1 / np.sqrt(n_input), (n_cell, n_input)),
            "r": rng.normal(0, 1 / np.sqrt(n_output), (n_cell, n_output)),
            "bias": rng.normal(0, 0.1, n_cell),
            "peephole": rng.normal(0, 0.1, n_cell) if peephole else None,
        }

    w = {name: gate() for name in (("f", "z", "o") if cifg else ("i", "f", "z", "o"))}
    w["z"]["peephole"] = None
    if proj:
        w["proj"] = (
            rng.normal(0, 1 / np.sqrt(n_cell), (n_output, n_cell)),
            rng.normal(0, 0.05, n_output),
        )
    return w


def make_params(rng, n_input, n_cell, n_output, **kw):
    fw = random_float_weights(rng, n_input, n_cell, n_output, **kw)
    stats = {
        "x": (-2.5, 2.5),
        "h": (-1.0, 1.0),
        "m": (-1.0, 1.0),
        "c_max_abs": 3.5,
    }
    return ref.quantize_params(fw, stats)


def random_state(rng, params, batch):
    qx = rng.integers(-128, 128, (batch, params.n_input)).astype(np.int8)
    c = rng.integers(-8000, 8000, (batch, params.n_cell)).astype(np.int16)
    h = rng.integers(-128, 128, (batch, params.n_output)).astype(np.int8)
    return qx, c, h


@pytest.mark.parametrize("variant", ["plain", "peephole", "proj", "cifg", "all"])
def test_pallas_step_matches_ref(variant):
    rng = np.random.default_rng(42)
    kw = {
        "plain": {},
        "peephole": {"peephole": True},
        "proj": {"proj": True},
        "cifg": {"cifg": True},
        "all": {"peephole": True, "proj": True, "cifg": True},
    }[variant]
    n_output = 12 if kw.get("proj") else 24
    params = make_params(rng, 16, 24, n_output, **kw)
    step = make_qlstm_step(params, tile_b=4, tile_n=8)
    qx, c, h = random_state(rng, params, 8)
    c1, h1 = step(jnp.asarray(qx), jnp.asarray(c), jnp.asarray(h))
    c2, h2 = ref.qlstm_step_ref(params, jnp.asarray(qx), jnp.asarray(c), jnp.asarray(h))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


@given(
    n_input=st.integers(min_value=1, max_value=40),
    n_cell=st.integers(min_value=1, max_value=48),
    batch=st.integers(min_value=1, max_value=9),
    tile_n=st.sampled_from([4, 8, 16, 128]),
    seed=st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=20, deadline=None)
def test_pallas_step_shape_sweep(n_input, n_cell, batch, tile_n, seed):
    rng = np.random.default_rng(seed)
    params = make_params(rng, n_input, n_cell, n_cell)
    step = make_qlstm_step(params, tile_b=4, tile_n=tile_n)
    qx, c, h = random_state(rng, params, batch)
    c1, h1 = step(jnp.asarray(qx), jnp.asarray(c), jnp.asarray(h))
    c2, h2 = ref.qlstm_step_ref(params, jnp.asarray(qx), jnp.asarray(c), jnp.asarray(h))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


def test_multi_step_recurrence_stays_exact():
    rng = np.random.default_rng(7)
    params = make_params(rng, 12, 16, 16)
    step = make_qlstm_step(params, tile_b=8, tile_n=16)
    qx, c, h = random_state(rng, params, 4)
    c_k, h_k, c_r, h_r = map(jnp.asarray, (c, h, c, h))
    for t in range(12):
        qxt = jnp.asarray(
            rng.integers(-128, 128, (4, params.n_input)).astype(np.int8)
        )
        c_k, h_k = step(qxt, c_k, h_k)
        c_r, h_r = ref.qlstm_step_ref(params, qxt, c_r, h_r)
        np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r), err_msg=f"t={t}")
        np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r), err_msg=f"t={t}")


def test_qmatmul_rescale_matches_ref():
    rng = np.random.default_rng(3)
    w = rng.integers(-127, 128, (24, 16)).astype(np.int8)
    bias = rng.integers(-(2**16), 2**16, 24).astype(np.int32)
    x = rng.integers(-128, 128, (5, 16)).astype(np.int8)
    from compile import fixedpoint as fp

    eff = fp.quantize_multiplier(3.1e-4)
    got = qmatmul_rescale(jnp.asarray(x), w, bias, eff, 3, tile_n=8)
    acc = x.astype(np.int64) @ w.astype(np.int64).T + bias[None, :]
    want = np.clip(
        np.asarray(
            fp.multiply_by_quantized_multiplier(jnp.asarray(acc, jnp.int32), *eff)
        )
        + 3,
        -128,
        127,
    ).astype(np.int8)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_integer_step_tracks_float_step():
    """End-to-end sanity: dequantized integer outputs track the float
    cell (the quality claim, in miniature)."""
    rng = np.random.default_rng(11)
    fw = random_float_weights(rng, 12, 24, 24)
    # Calibrate stats from an actual float rollout.
    x_seq = rng.normal(0, 1, (30, 6, 12)).astype(np.float32)
    c = jnp.zeros((6, 24))
    h = jnp.zeros((6, 24))
    jw = {
        k: {kk: (jnp.asarray(vv) if vv is not None else None) for kk, vv in v.items()}
        for k, v in fw.items()
    }
    c_lo = h_lo = 0.0
    c_hi = h_hi = 0.0
    for t in range(30):
        c, h = ref.float_lstm_step(jw, jnp.asarray(x_seq[t]), c, h)
        c_lo, c_hi = min(c_lo, float(c.min())), max(c_hi, float(c.max()))
        h_lo, h_hi = min(h_lo, float(h.min())), max(h_hi, float(h.max()))
    stats = {
        "x": (float(x_seq.min()), float(x_seq.max())),
        "h": (h_lo, h_hi),
        "m": (h_lo, h_hi),
        "c_max_abs": max(abs(c_lo), abs(c_hi)),
    }
    params = ref.quantize_params(fw, stats)

    qc = jnp.zeros((6, 24), jnp.int16)
    qh = jnp.full((6, 24), params.output_q.zero_point, jnp.int8)
    c = jnp.zeros((6, 24))
    h = jnp.zeros((6, 24))
    errs = []
    for t in range(30):
        qx = jnp.asarray(params.input_q.quantize(x_seq[t]))
        qc, qh = ref.qlstm_step_ref(params, qx, qc, qh)
        c, h = ref.float_lstm_step(jw, jnp.asarray(x_seq[t]), c, h)
        deq = params.output_q.dequantize(np.asarray(qh))
        errs.append(np.mean(np.abs(deq - np.asarray(h))))
    assert np.mean(errs) < 0.03, f"mean divergence {np.mean(errs)}"
