"""Fixed-point mirror tests: the jnp implementations must match the
mathematical definitions (and hence the Rust side, which is asserted
bit-exactly against the same definitions)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import fixedpoint as fp


def test_srdhm_reference_cases():
    a = jnp.array([1 << 30, 1 << 30, 123456789, -123456789, 0], jnp.int32)
    b = jnp.array([1 << 30, -(1 << 30), 987654321, 987654321, 7], jnp.int32)
    got = np.asarray(fp.srdhm(a, b))
    want = np.round(2.0 * np.asarray(a, np.float64) * np.asarray(b, np.float64) / 2.0**32)
    assert np.all(np.abs(got - want) <= 1)


def test_srdhm_saturation():
    a = jnp.array([fp.I32_MIN], jnp.int32)
    assert int(fp.srdhm(a, a)[0]) == fp.I32_MAX


@given(
    x=st.integers(min_value=-(2**31), max_value=2**31 - 1),
    e=st.integers(min_value=0, max_value=20),
)
@settings(max_examples=200, deadline=None)
def test_rounding_divide_by_pot_matches_float(x, e):
    got = int(fp.rounding_divide_by_pot(jnp.array([x], jnp.int32), e)[0])
    want = x / 2.0**e
    # Round half away from zero.
    want_r = math.floor(want + 0.5) if want >= 0 else math.ceil(want - 0.5)
    assert got == want_r


@given(st.floats(min_value=1e-8, max_value=100.0, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_quantize_multiplier_roundtrip(scale):
    mult, shift = fp.quantize_multiplier(scale)
    approx = mult / 2.0**31 * 2.0**shift
    assert approx == pytest.approx(scale, rel=1e-6)
    assert mult >= 2**30


@given(
    scale=st.floats(min_value=1e-6, max_value=4.0),
    x=st.integers(min_value=-(2**20), max_value=2**20),
)
@settings(max_examples=200, deadline=None)
def test_multiply_by_quantized_multiplier(scale, x):
    mult, shift = fp.quantize_multiplier(scale)
    got = int(
        fp.multiply_by_quantized_multiplier(jnp.array([x], jnp.int32), mult, shift)[0]
    )
    assert got == pytest.approx(x * scale, abs=1.0 + abs(x * scale) * 1e-6)


@pytest.mark.parametrize("ib", [0, 1, 2, 3, 4, 5, 6])
def test_exp_accuracy(ib):
    xs = np.linspace(-(2.0**ib), 0.0, 997)
    raw = np.clip(np.round(xs * 2.0 ** (31 - ib)), fp.I32_MIN, 0).astype(np.int32)
    got = np.asarray(fp.exp_on_negative_values(jnp.asarray(raw), ib), np.float64) / 2.0**31
    want = np.exp(raw.astype(np.float64) * 2.0 ** (ib - 31))
    assert np.max(np.abs(got - want)) < 2e-6


@pytest.mark.parametrize("ib", [0, 1, 2, 3, 4, 5, 6])
def test_tanh_q15_accuracy(ib):
    x = np.arange(-32768, 32768, 7, dtype=np.int32).astype(np.int16)
    got = np.asarray(fp.tanh_q15(jnp.asarray(x), ib), np.float64) / 32768.0
    want = np.tanh(x.astype(np.float64) * 2.0 ** (ib - 15))
    assert np.max(np.abs(got - want)) * 32768.0 <= 4.0


@pytest.mark.parametrize("ib", [0, 1, 2, 3, 4, 5, 6])
def test_sigmoid_q15_accuracy(ib):
    x = np.arange(-32768, 32768, 7, dtype=np.int32).astype(np.int16)
    got = np.asarray(fp.sigmoid_q15(jnp.asarray(x), ib), np.float64) / 32768.0
    want = 1.0 / (1.0 + np.exp(-x.astype(np.float64) * 2.0 ** (ib - 15)))
    assert np.max(np.abs(got - want)) * 32768.0 <= 4.0


def test_tanh_odd_and_monotone():
    x = np.arange(-32768, 32768, 11, dtype=np.int32).astype(np.int16)
    y = np.asarray(fp.tanh_q15(jnp.asarray(x), 3), np.int32)
    assert np.all(np.diff(y) >= 0)
    yneg = np.asarray(
        fp.tanh_q15(jnp.asarray((-x.astype(np.int32)).clip(-32768, 32767).astype(np.int16)), 3),
        np.int32,
    )
    assert np.all(np.abs(y + yneg) <= 1)


def test_sigmoid_complement():
    x = np.array([-30000, -5000, -100, 100, 5000, 30000], np.int16)
    p = np.asarray(fp.sigmoid_q15(jnp.asarray(x), 3), np.int32)
    n = np.asarray(fp.sigmoid_q15(jnp.asarray(-x), 3), np.int32)
    assert np.all(np.abs(p + n - 32768) <= 2)
