"""Build-time training of the char-LM used by the end-to-end experiments.

Writes to ``artifacts/``:
  * ``corpus.txt``       — the synthetic training/eval corpus,
  * ``charlm.bin``       — trained float weights (rust binary format),
  * ``charlm.json``      — model config,
  * ``train_log.json``   — the loss curve (recorded in EXPERIMENTS.md).

Usage: ``python -m compile.train --out ../artifacts [--steps 400]``
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def batches(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    max_start = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, max_start, batch)
        yield np.stack([tokens[s : s + seq + 1] for s in starts])


def train(out_dir: str, steps: int, hidden: int, depth: int, batch: int,
          seq: int, corpus_chars: int, lr: float, seed: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    cfg = M.CharLmConfig(hidden=hidden, depth=depth)

    corpus_path = os.path.join(out_dir, "corpus.txt")
    if os.path.exists(corpus_path):
        text = open(corpus_path).read()
        if len(text) < corpus_chars:
            text = M.generate_corpus(corpus_chars, seed=1234)
            open(corpus_path, "w").write(text)
    else:
        text = M.generate_corpus(corpus_chars, seed=1234)
        open(corpus_path, "w").write(text)
    tokens = M.tokenize(text)

    params = M.init_params(cfg, seed=seed)
    opt = M.adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks):
        loss, grads = jax.value_and_grad(M.lm_loss)(params, toks, cfg)
        params, opt = M.adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    log = []
    t0 = time.time()
    for i, toks in enumerate(batches(tokens, batch, seq, steps, seed + 1)):
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks))
        if i % 20 == 0 or i == steps - 1:
            entry = {
                "step": i,
                "loss_nats": float(loss),
                "bits_per_char": float(loss) / np.log(2.0),
                "elapsed_s": round(time.time() - t0, 1),
            }
            log.append(entry)
            print(
                f"step {i:4d}  loss {entry['loss_nats']:.4f} nats "
                f"({entry['bits_per_char']:.3f} bpc)  {entry['elapsed_s']}s"
            )

    params = jax.device_get(params)
    M.export_charlm(params, cfg, os.path.join(out_dir, "charlm.bin"))
    with open(os.path.join(out_dir, "charlm.json"), "w") as f:
        f.write(cfg.to_json())
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    return {"final_loss": log[-1]["loss_nats"], "log": log}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--hidden", type=int, default=192)
    p.add_argument("--depth", type=int, default=2)
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--corpus-chars", type=int, default=400_000)
    p.add_argument("--lr", type=float, default=2e-3)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    result = train(
        args.out, args.steps, args.hidden, args.depth, args.batch,
        args.seq, args.corpus_chars, args.lr, args.seed,
    )
    print(f"final loss: {result['final_loss']:.4f} nats")


if __name__ == "__main__":
    main()
