"""Integer fixed-point arithmetic in JAX — the L1/L2 mirror of
``rust/src/fixedpoint`` and ``rust/src/nonlin``.

Everything here operates on integer dtypes only (int32/int64), matching
the Rust implementation bit-for-bit so cross-layer tests can assert
exact equality. The algorithms are the gemmlowp family: saturating
rounding doubling high multiply, rounding power-of-two shifts,
barrel-shifted exponential, Newton-Raphson reciprocal.

Requires ``jax_enable_x64`` (set in ``conftest.py`` / ``aot.py``).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

I32_MAX = 2**31 - 1
I32_MIN = -(2**31)


def _trunc_div(a, b):
    """C-style truncating division on integer arrays (jnp // floors)."""
    q = jnp.abs(a) // jnp.abs(b)
    return jnp.where((a < 0) != (b < 0), -q, q).astype(a.dtype)


def srdhm(a, b):
    """Saturating rounding doubling high mul of int32 arrays."""
    a64 = a.astype(jnp.int64)
    b64 = b.astype(jnp.int64)
    ab = a64 * b64
    nudge = jnp.where(ab >= 0, 1 << 30, 1 - (1 << 30)).astype(jnp.int64)
    result = _trunc_div(ab + nudge, jnp.int64(1 << 31))
    overflow = (a == I32_MIN) & (b == I32_MIN)
    return jnp.where(overflow, I32_MAX, result).astype(jnp.int32)


def rounding_divide_by_pot(x, exponent: int):
    """Rounding (ties away from zero) right shift of int32 arrays."""
    if exponent == 0:
        return x
    mask = jnp.int32((1 << exponent) - 1)
    remainder = jnp.bitwise_and(x, mask)
    threshold = (mask >> 1) + jnp.where(x < 0, 1, 0).astype(jnp.int32)
    return (x >> exponent) + jnp.where(remainder > threshold, 1, 0).astype(
        jnp.int32
    )


def saturating_rounding_multiply_by_pot(x, exponent: int):
    """Multiply int32 arrays by 2^exponent, saturating."""
    if exponent == 0:
        return x
    if exponent < 0:
        return rounding_divide_by_pot(x, -exponent)
    lo = jnp.int32(I32_MIN >> exponent)
    hi = jnp.int32(I32_MAX >> exponent)
    clamped = jnp.clip(x, lo, hi)
    shifted = (clamped.astype(jnp.int64) << exponent).astype(jnp.int32)
    return jnp.where(x > hi, I32_MAX, jnp.where(x < lo, I32_MIN, shifted))


def rounding_half_sum(a, b):
    s = a.astype(jnp.int64) + b.astype(jnp.int64)
    sign = jnp.where(s >= 0, 1, -1).astype(jnp.int64)
    return _trunc_div(s + sign, jnp.int64(2)).astype(jnp.int32)


def quantize_multiplier(scale: float) -> tuple[int, int]:
    """Decompose a real scale into (int32 multiplier, shift); mirrors
    ``Rescale::from_scale``."""
    assert scale >= 0.0 and math.isfinite(scale)
    if scale == 0.0:
        return 0, 0
    shift = math.floor(math.log2(scale)) + 1
    q = scale / (2.0**shift)
    q_fixed = round(q * (2.0**31))
    if q_fixed == 2**31:
        q_fixed //= 2
        shift += 1
    if shift < -31:
        return 0, 0
    if shift > 30:
        return I32_MAX, 30
    return int(q_fixed), int(shift)


def multiply_by_quantized_multiplier(x, multiplier: int, shift: int):
    """Apply (multiplier, shift) to an int32 array; mirrors
    ``Rescale::apply`` including the saturating pre-shift."""
    left = max(shift, 0)
    right = max(-shift, 0)
    if left:
        x = saturating_rounding_multiply_by_pot(x, left)
    prod = srdhm(x, jnp.int32(multiplier))
    return rounding_divide_by_pot(prod, right) if right else prod


# ---------------------------------------------------------------------------
# Integer transcendentals (Q_{ib.15-ib} int16 -> Q0.15 int16).
# ---------------------------------------------------------------------------

_EXP_BARREL = [
    (-2, 1672461947),
    (-1, 1302514674),
    (0, 790015084),
    (1, 290630308),
    (2, 39332535),
    (3, 720401),
    (4, 242),
]
_CONSTANT_TERM = 1895147668  # exp(-1/8) in Q0.31
_CONSTANT_1_OVER_3 = 715827883
_CONSTANT_48_OVER_17 = 1515870810
_CONSTANT_NEG_32_OVER_17 = -1010580540


def _exp_interval(a):
    """exp(a) for a in [-1/4, 0), Q0.31."""
    x = a + jnp.int32(1 << 28)  # + 1/8
    x2 = srdhm(x, x)
    x3 = srdhm(x2, x)
    x4 = srdhm(x2, x2)
    x4_over_4 = rounding_divide_by_pot(x4, 2)
    inner = srdhm(x4_over_4 + x3, jnp.int32(_CONSTANT_1_OVER_3)) + x2
    poly = rounding_divide_by_pot(inner, 1)
    ct = jnp.int32(_CONSTANT_TERM)
    return ct + srdhm(ct, x + poly)


def exp_on_negative_values(a, ib: int):
    """exp(a) for a <= 0; input raw int32 with 31-ib fractional bits,
    output Q0.31."""
    frac_bits = 31 - ib
    one_quarter = jnp.int32(1 << (frac_bits - 2))
    mask = one_quarter - 1
    a_mod = jnp.bitwise_and(a, mask) - one_quarter
    interval_in = saturating_rounding_multiply_by_pot(a_mod, ib)
    result = _exp_interval(interval_in)
    remainder = (a_mod - a).astype(jnp.int32)
    for exponent, multiplier in _EXP_BARREL:
        if ib > exponent:
            pos = frac_bits + exponent
            if 0 <= pos < 31:
                fire = jnp.bitwise_and(remainder, jnp.int32(1 << pos)) != 0
                result = jnp.where(
                    fire, srdhm(result, jnp.int32(multiplier)), result
                )
    if ib > 5:
        clamp_raw = jnp.int32(-(1 << (frac_bits + 5)))
        result = jnp.where(a < clamp_raw, 0, result)
    return jnp.where(a == 0, I32_MAX, result)


def _one_minus_over_one_plus(a):
    """(1-x)/(1+x) for x in [0,1], Q0.31 -> Q0.31 (Newton-Raphson)."""
    half_denominator = rounding_half_sum(a, jnp.int32(I32_MAX))
    x = jnp.int32(_CONSTANT_48_OVER_17) + srdhm(
        half_denominator, jnp.int32(_CONSTANT_NEG_32_OVER_17)
    )
    for _ in range(3):
        hdx = srdhm(half_denominator, x)
        one_minus = jnp.int32(1 << 29) - hdx
        delta = saturating_rounding_multiply_by_pot(srdhm(x, one_minus), 2)
        x = x + delta
    # x ≈ 2/(1+a) in Q2.29; subtract one, widen to Q0.31.
    return saturating_rounding_multiply_by_pot(x - jnp.int32(1 << 29), 2)


def _one_over_one_plus(a):
    """1/(1+x) for x in [0,1], Q0.31 -> Q0.31."""
    half_denominator = rounding_half_sum(a, jnp.int32(I32_MAX))
    x = jnp.int32(_CONSTANT_48_OVER_17) + srdhm(
        half_denominator, jnp.int32(_CONSTANT_NEG_32_OVER_17)
    )
    for _ in range(3):
        hdx = srdhm(half_denominator, x)
        one_minus = jnp.int32(1 << 29) - hdx
        delta = saturating_rounding_multiply_by_pot(srdhm(x, one_minus), 2)
        x = x + delta
    # x ≈ 2/(1+a) in Q2.29; halve then widen to Q0.31.
    return saturating_rounding_multiply_by_pot(rounding_divide_by_pot(x, 1), 2)


def _q31_to_q15_i16(raw):
    q15 = rounding_divide_by_pot(raw, 16)
    return jnp.clip(q15, -32768, 32767).astype(jnp.int16)


def tanh_q15(x, ib: int):
    """Integer tanh: int16 Q_{ib.15-ib} -> int16 Q0.15. Bit-exact mirror
    of ``nonlin::tanh_q15``."""
    widened = (x.astype(jnp.int32) << 16).astype(jnp.int32)
    neg_abs = -jnp.abs(widened)
    # Exact doubling = reinterpret with one more integer bit.
    e = exp_on_negative_values(neg_abs, ib + 1)
    t = _one_minus_over_one_plus(e)
    out = jnp.where(widened == 0, 0, jnp.where(widened < 0, -t, t))
    return _q31_to_q15_i16(out)


def sigmoid_q15(x, ib: int):
    """Integer sigmoid: int16 Q_{ib.15-ib} -> int16 Q0.15. Bit-exact
    mirror of ``nonlin::sigmoid_q15``."""
    widened = (x.astype(jnp.int32) << 16).astype(jnp.int32)
    neg_abs = -jnp.abs(widened)
    e = exp_on_negative_values(neg_abs, ib)
    pos = _one_over_one_plus(e)
    out = jnp.where(widened >= 0, pos, jnp.int32(I32_MAX) - pos)
    return _q31_to_q15_i16(out)
