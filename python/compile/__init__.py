"""Build-time compile package: JAX model, Pallas kernels, AOT lowering."""
