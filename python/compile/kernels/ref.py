"""Pure-jnp correctness oracle for the integer LSTM step.

Mirrors ``rust/src/lstm/integer_cell.rs`` bit-for-bit for the
plain / peephole / projection / CIFG variants: the Pallas kernels in
this package are asserted equal to this reference, and the same
quantized parameters + golden vectors are exported for the Rust side
(``aot.py --golden``), closing the three-layer loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .. import fixedpoint as fp


# ---------------------------------------------------------------------------
# Quantization parameter derivation (mirrors rust quant::params / quantize).
# ---------------------------------------------------------------------------


@dataclass
class AsymQuant:
    scale: float
    zero_point: int

    @staticmethod
    def from_min_max(lo: float, hi: float) -> "AsymQuant":
        lo = min(lo, 0.0)
        hi = max(hi, 0.0)
        if lo == hi:
            return AsymQuant(1.0 / 255.0, 0)
        scale = (hi - lo) / 255.0
        zp = int(np.clip(round(-128.0 - lo / scale), -128, 127))
        return AsymQuant(scale, zp)

    def quantize(self, v: np.ndarray) -> np.ndarray:
        q = np.round(v / self.scale) + self.zero_point
        return np.clip(q, -128, 127).astype(np.int8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return (q.astype(np.float64) - self.zero_point) * self.scale

    @property
    def folding_zp(self) -> int:
        return -self.zero_point


def sym_scale_i8(max_abs: float) -> float:
    return (max_abs if max_abs > 0 else 1.0) / 127.0


def sym_quant_i8(w: np.ndarray) -> tuple[np.ndarray, float]:
    s = sym_scale_i8(float(np.max(np.abs(w))) if w.size else 0.0)
    return np.clip(np.round(w / s), -127, 127).astype(np.int8), s


def sym_quant_i16(v: np.ndarray) -> tuple[np.ndarray, float]:
    m = float(np.max(np.abs(v))) if v.size else 0.0
    s = (m if m > 0 else 1.0) / 32767.0
    return np.clip(np.round(v / s), -32767, 32767).astype(np.int16), s


def pot_integer_bits(max_abs: float) -> int:
    m = 0
    while 2.0**m < max_abs and m < 15:
        m += 1
    return m


def fold_zero_point(w_q: np.ndarray, zp: int) -> np.ndarray:
    """§6: bias'[i] = zp * Σ_j W[i, j] (int32)."""
    return (w_q.astype(np.int64).sum(axis=1) * zp).astype(np.int32)


# ---------------------------------------------------------------------------
# Quantized parameter bundle (plain variant + optional PH / proj / CIFG).
# ---------------------------------------------------------------------------


@dataclass
class QGate:
    w: np.ndarray  # int8 [n_cell, n_input]
    r: np.ndarray  # int8 [n_cell, n_output]
    w_bias: np.ndarray  # int32 [n_cell]
    r_bias: np.ndarray  # int32 [n_cell] (zp fold + quantized bias)
    eff_x: tuple[int, int]  # (multiplier, shift)
    eff_h: tuple[int, int]
    peephole: np.ndarray | None = None  # int16 [n_cell]
    eff_c: tuple[int, int] | None = None


@dataclass
class QLstmParams:
    n_input: int
    n_cell: int
    n_output: int
    cifg: bool
    gates: dict = field(default_factory=dict)  # name -> QGate (i/f/z/o)
    input_q: AsymQuant | None = None
    output_q: AsymQuant | None = None
    hidden_q: AsymQuant | None = None
    eff_hidden: tuple[int, int] = (0, 0)
    cell_ib: int = 0
    w_proj: np.ndarray | None = None  # int8 [n_output, n_cell]
    proj_bias: np.ndarray | None = None  # int32 [n_output]
    eff_proj: tuple[int, int] | None = None


def quantize_params(float_weights: dict, stats: dict) -> QLstmParams:
    """Apply the Table-2 recipe (no-LN variants) to float weights.

    ``float_weights``: gate name -> dict(w, r, bias[, peephole]);
    optionally 'proj' -> (w_proj, b_proj). ``stats``: observed ranges
    dict(x=(lo,hi), h=(lo,hi), m=(lo,hi), c_max_abs=float).
    """
    gate_names = [n for n in ("i", "f", "z", "o") if n in float_weights]
    any_gate = float_weights[gate_names[0]]
    n_cell, n_input = any_gate["w"].shape
    n_output = any_gate["r"].shape[1]
    has_proj = "proj" in float_weights

    input_q = AsymQuant.from_min_max(*stats["x"])
    output_q = AsymQuant.from_min_max(*stats["h"])
    hidden_q = AsymQuant.from_min_max(*stats["m"]) if has_proj else output_q
    cell_ib = pot_integer_bits(stats["c_max_abs"])
    s_c = 2.0 ** (cell_ib - 15)
    q312 = 2.0**-12

    params = QLstmParams(
        n_input=n_input,
        n_cell=n_cell,
        n_output=n_output,
        cifg="i" not in float_weights,
        input_q=input_q,
        output_q=output_q,
        hidden_q=hidden_q,
        eff_hidden=fp.quantize_multiplier(2.0**-30 / hidden_q.scale),
        cell_ib=cell_ib,
    )

    for name in gate_names:
        g = float_weights[name]
        w_q, s_w = sym_quant_i8(g["w"])
        r_q, s_r = sym_quant_i8(g["r"])
        w_bias = fold_zero_point(w_q, input_q.folding_zp)
        r_bias = fold_zero_point(r_q, output_q.folding_zp)
        s_bias = s_r * output_q.scale
        r_bias = (
            r_bias.astype(np.int64)
            + np.clip(
                np.round(g["bias"] / s_bias), -(2**31 - 1), 2**31 - 1
            ).astype(np.int64)
        ).astype(np.int32)
        qg = QGate(
            w=w_q,
            r=r_q,
            w_bias=w_bias,
            r_bias=r_bias,
            eff_x=fp.quantize_multiplier(s_w * input_q.scale / q312),
            eff_h=fp.quantize_multiplier(s_r * output_q.scale / q312),
        )
        if g.get("peephole") is not None and name != "z":
            p_q, s_p = sym_quant_i16(g["peephole"])
            qg.peephole = p_q
            qg.eff_c = fp.quantize_multiplier(s_p * s_c / q312)
        params.gates[name] = qg

    if has_proj:
        w_proj, b_proj = float_weights["proj"]
        wp_q, s_wp = sym_quant_i8(w_proj)
        s_bias = s_wp * hidden_q.scale
        bias = fold_zero_point(wp_q, hidden_q.folding_zp).astype(np.int64)
        if b_proj is not None:
            bias = bias + np.clip(
                np.round(b_proj / s_bias), -(2**31 - 1), 2**31 - 1
            ).astype(np.int64)
        params.w_proj = wp_q
        params.proj_bias = bias.astype(np.int32)
        params.eff_proj = fp.quantize_multiplier(s_bias / output_q.scale)

    return params


# ---------------------------------------------------------------------------
# The integer step itself (pure jnp; batch-first).
# ---------------------------------------------------------------------------


def _matmul_i32(x_i8, w_i8, bias_i32):
    """x [B, K] int8 @ w.T [K, N] -> [B, N] int32 + bias."""
    acc = jnp.matmul(x_i8.astype(jnp.int32), w_i8.astype(jnp.int32).T)
    return acc + bias_i32[None, :].astype(jnp.int32)


def _gate_pre(g: QGate, qx, qh, c_for_ph):
    acc_x = _matmul_i32(qx, g.w, g.w_bias)
    acc_h = _matmul_i32(qh, g.r, g.r_bias)
    pre = fp.multiply_by_quantized_multiplier(
        acc_x, *g.eff_x
    ) + fp.multiply_by_quantized_multiplier(acc_h, *g.eff_h)
    if g.peephole is not None:
        pc = g.peephole[None, :].astype(jnp.int32) * c_for_ph.astype(jnp.int32)
        pre = pre + fp.multiply_by_quantized_multiplier(pc, *g.eff_c)
    return jnp.clip(pre, -32768, 32767).astype(jnp.int16)


def qlstm_step_ref(params: QLstmParams, qx, c, h):
    """One integer LSTM step. qx [B, n_input] int8; c [B, n_cell] int16;
    h [B, n_output] int8. Returns (c', h') with identical dtypes.

    This is the oracle the Pallas kernel is tested against, and the
    bit-exact mirror of ``IntegerLstm::step_q``."""
    f_pre = _gate_pre(params.gates["f"], qx, h, c)
    z_pre = _gate_pre(params.gates["z"], qx, h, c)
    f_act = fp.sigmoid_q15(f_pre, 3)
    z_act = fp.tanh_q15(z_pre, 3)
    if params.cifg:
        i_act = jnp.minimum(32768 - f_act.astype(jnp.int32), 32767).astype(
            jnp.int16
        )
    else:
        i_pre = _gate_pre(params.gates["i"], qx, h, c)
        i_act = fp.sigmoid_q15(i_pre, 3)

    iz = i_act.astype(jnp.int32) * z_act.astype(jnp.int32)
    fc = f_act.astype(jnp.int32) * c.astype(jnp.int32)
    c_new32 = fp.rounding_divide_by_pot(
        iz, 15 + params.cell_ib
    ) + fp.rounding_divide_by_pot(fc, 15)
    c_new = jnp.clip(c_new32, -32768, 32767).astype(jnp.int16)

    o_pre = _gate_pre(params.gates["o"], qx, h, c_new)
    o_act = fp.sigmoid_q15(o_pre, 3)

    tanh_c = fp.tanh_q15(c_new, params.cell_ib)
    prod = o_act.astype(jnp.int32) * tanh_c.astype(jnp.int32)
    m = jnp.clip(
        fp.multiply_by_quantized_multiplier(prod, *params.eff_hidden)
        + params.hidden_q.zero_point,
        -128,
        127,
    ).astype(jnp.int8)

    if params.w_proj is not None:
        acc = _matmul_i32(m, params.w_proj, params.proj_bias)
        h_new = jnp.clip(
            fp.multiply_by_quantized_multiplier(acc, *params.eff_proj)
            + params.output_q.zero_point,
            -128,
            127,
        ).astype(jnp.int8)
    else:
        h_new = m
    return c_new, h_new


# ---------------------------------------------------------------------------
# Float reference step (training / calibration / the float HLO artifact).
# ---------------------------------------------------------------------------


def float_lstm_step(weights: dict, x, c, h):
    """Float LSTM step matching ``FloatLstm::step`` for the plain /
    peephole / projection / CIFG variants. Batch-first jnp arrays."""

    def pre(g, c_for_ph):
        out = x @ g["w"].T + h @ g["r"].T
        if g.get("peephole") is not None:
            out = out + g["peephole"][None, :] * c_for_ph
        return out + g["bias"][None, :]

    def sigmoid(v):
        return 1.0 / (1.0 + jnp.exp(-v))

    f = sigmoid(pre(weights["f"], c))
    z = jnp.tanh(pre(weights["z"], c))
    if "i" in weights:
        i = sigmoid(pre(weights["i"], c))
    else:
        i = 1.0 - f
    c_new = i * z + f * c
    o = sigmoid(pre(weights["o"], c_new))
    m = o * jnp.tanh(c_new)
    if "proj" in weights:
        w_proj, b_proj = weights["proj"]
        h_new = m @ w_proj.T
        if b_proj is not None:
            h_new = h_new + b_proj[None, :]
    else:
        h_new = m
    return c_new, h_new
