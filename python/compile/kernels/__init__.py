"""Layer-1 kernels: Pallas integer LSTM + pure-jnp reference."""
