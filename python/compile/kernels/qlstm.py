"""Layer-1 Pallas kernels: the integer LSTM step, tiled for TPU.

Hardware adaptation (DESIGN.md §5): the paper targets CPU SIMD / integer
accelerators; on TPU the gate computation maps onto the MXU as
int8×int8→int32 matmuls and the rescale/activation chain onto the VPU,
with `BlockSpec` expressing the HBM↔VMEM tiling (weight panels of
`[4, TILE_N, K]` stay resident in VMEM across the batch tile).

The quantized parameters (multipliers, shifts, zero points) are *static*
closure constants — exactly like the paper's precomputed scales — so the
kernel body contains no dynamic control flow (principle #2 of §3).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls; numerics are validated through the
interpret path against ``ref.py`` and against the Rust implementation
via golden vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import fixedpoint as fp
from .ref import QLstmParams

# Gate order inside the stacked weight tensors.
GATE_ORDER = ("i", "f", "z", "o")


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _stack_gates(params: QLstmParams, attr: str, fill_shape, dtype):
    """Stack a per-gate tensor into [4, ...]; absent gates (CIFG input
    gate) are zero-filled and skipped statically in the kernel."""
    out = []
    for name in GATE_ORDER:
        g = params.gates.get(name)
        v = getattr(g, attr) if g is not None else None
        out.append(np.zeros(fill_shape, dtype) if v is None else v.astype(dtype))
    return np.stack(out, axis=0)


def make_qlstm_step(params: QLstmParams, tile_b: int = 8, tile_n: int = 128):
    """Build the fused integer-LSTM-step function backed by Pallas.

    Returns ``step(qx, c, h) -> (c_new, h_new)`` operating on int8/int16
    arrays of shape [B, n_input], [B, n_cell], [B, n_output].
    """
    n_in, n_cell, n_out = params.n_input, params.n_cell, params.n_output
    tile_n = min(tile_n, n_cell)

    w_all = _stack_gates(params, "w", (n_cell, n_in), np.int8)
    r_all = _stack_gates(params, "r", (n_cell, n_out), np.int8)
    wb_all = _stack_gates(params, "w_bias", (n_cell,), np.int32)
    rb_all = _stack_gates(params, "r_bias", (n_cell,), np.int32)
    ph_all = _stack_gates(params, "peephole", (n_cell,), np.int16)

    eff = {}
    for name in GATE_ORDER:
        g = params.gates.get(name)
        if g is not None:
            eff[name] = (g.eff_x, g.eff_h, g.eff_c)
    zp_m = int(params.hidden_q.zero_point)
    eff_hidden = params.eff_hidden
    cell_ib = params.cell_ib
    cifg = params.cifg

    def gate_pre(gname: str, gi: int, x32, h32, w_ref, r_ref, wb_ref, rb_ref,
                 ph_ref, c_for_ph):
        eff_x, eff_h, eff_c = eff[gname]
        acc_x = jnp.dot(x32, w_ref[gi].astype(jnp.int32).T) + wb_ref[gi][None, :]
        acc_h = jnp.dot(h32, r_ref[gi].astype(jnp.int32).T) + rb_ref[gi][None, :]
        pre = fp.multiply_by_quantized_multiplier(acc_x, *eff_x)
        pre = pre + fp.multiply_by_quantized_multiplier(acc_h, *eff_h)
        if eff_c is not None:
            pc = ph_ref[gi][None, :].astype(jnp.int32) * c_for_ph
            pre = pre + fp.multiply_by_quantized_multiplier(pc, *eff_c)
        return jnp.clip(pre, -32768, 32767).astype(jnp.int16)

    def cell_kernel(qx_ref, c_ref, h_ref, w_ref, r_ref, wb_ref, rb_ref,
                    ph_ref, c_out_ref, m_out_ref):
        # MXU part: int8 matmuls with int32 accumulation.
        x32 = qx_ref[...].astype(jnp.int32)
        h32 = h_ref[...].astype(jnp.int32)
        c32 = c_ref[...].astype(jnp.int32)

        f_pre = gate_pre("f", 1, x32, h32, w_ref, r_ref, wb_ref, rb_ref, ph_ref, c32)
        z_pre = gate_pre("z", 2, x32, h32, w_ref, r_ref, wb_ref, rb_ref, ph_ref, c32)
        f_act = fp.sigmoid_q15(f_pre, 3)
        z_act = fp.tanh_q15(z_pre, 3)
        if cifg:
            i_act = jnp.minimum(32768 - f_act.astype(jnp.int32), 32767).astype(jnp.int16)
        else:
            i_pre = gate_pre("i", 0, x32, h32, w_ref, r_ref, wb_ref, rb_ref, ph_ref, c32)
            i_act = fp.sigmoid_q15(i_pre, 3)

        iz = i_act.astype(jnp.int32) * z_act.astype(jnp.int32)
        fc = f_act.astype(jnp.int32) * c32
        c_new32 = fp.rounding_divide_by_pot(iz, 15 + cell_ib) + \
            fp.rounding_divide_by_pot(fc, 15)
        c_new = jnp.clip(c_new32, -32768, 32767).astype(jnp.int16)
        c_out_ref[...] = c_new

        o_pre = gate_pre("o", 3, x32, h32, w_ref, r_ref, wb_ref, rb_ref, ph_ref,
                         c_new.astype(jnp.int32))
        o_act = fp.sigmoid_q15(o_pre, 3)
        tanh_c = fp.tanh_q15(c_new, cell_ib)
        prod = o_act.astype(jnp.int32) * tanh_c.astype(jnp.int32)
        m = jnp.clip(
            fp.multiply_by_quantized_multiplier(prod, *eff_hidden) + zp_m,
            -128, 127,
        ).astype(jnp.int8)
        m_out_ref[...] = m

    @functools.partial(jax.jit, static_argnums=())
    def step(qx, c, h):
        b = qx.shape[0]
        tb = min(tile_b, b)
        grid = (_cdiv(b, tb), _cdiv(n_cell, tile_n))
        c_new, m = pl.pallas_call(
            cell_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tb, n_in), lambda i, j: (i, 0)),
                pl.BlockSpec((tb, tile_n), lambda i, j: (i, j)),
                pl.BlockSpec((tb, n_out), lambda i, j: (i, 0)),
                pl.BlockSpec((4, tile_n, n_in), lambda i, j: (0, j, 0)),
                pl.BlockSpec((4, tile_n, n_out), lambda i, j: (0, j, 0)),
                pl.BlockSpec((4, tile_n), lambda i, j: (0, j)),
                pl.BlockSpec((4, tile_n), lambda i, j: (0, j)),
                pl.BlockSpec((4, tile_n), lambda i, j: (0, j)),
            ],
            out_specs=[
                pl.BlockSpec((tb, tile_n), lambda i, j: (i, j)),
                pl.BlockSpec((tb, tile_n), lambda i, j: (i, j)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((b, n_cell), jnp.int16),
                jax.ShapeDtypeStruct((b, n_cell), jnp.int8),
            ],
            interpret=True,
        )(qx, c, h, w_all, r_all, wb_all, rb_all, ph_all)

        if params.w_proj is not None:
            h_new = qmatmul_rescale(
                m, params.w_proj, params.proj_bias, params.eff_proj,
                int(params.output_q.zero_point),
            )
        else:
            h_new = m
        return c_new, h_new

    return step


def qmatmul_rescale(x_i8, w_q, bias_i32, eff, zp_out, tile_n: int = 128):
    """Generic int8 matmul + rescale + zero-point Pallas kernel
    (projection layer, LM output head): `clip(rescale(W(x+zp)+b) + zp)`.

    `x_i8` [B, K] int8; `w_q` [N, K] int8; returns [B, N] int8.
    """
    n, k = w_q.shape
    b = x_i8.shape[0]
    tile_n = min(tile_n, n)
    mult, shift = eff

    def kernel(x_ref, w_ref, b_ref, o_ref):
        acc = jnp.dot(
            x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32).T
        ) + b_ref[...][None, :]
        out = fp.multiply_by_quantized_multiplier(acc, mult, shift) + zp_out
        o_ref[...] = jnp.clip(out, -128, 127).astype(jnp.int8)

    return pl.pallas_call(
        kernel,
        grid=(_cdiv(n, tile_n),),
        in_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((tile_n, k), lambda j: (j, 0)),
            pl.BlockSpec((tile_n,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((b, tile_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int8),
        interpret=True,
    )(x_i8, jnp.asarray(w_q), jnp.asarray(bias_i32))
