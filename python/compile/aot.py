"""AOT lowering: JAX -> HLO text artifacts for the Rust runtime.

Emits HLO **text** (never ``.serialize()``): jax >= 0.5 writes protos
with 64-bit instruction ids that the image's xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts:
  * ``model_b{1,8}.hlo.txt`` — the trained char-LM serving step
    (weights baked as constants): (x_onehot, c0, h0, c1, h1, ...) ->
    (logits, new states). Executed by ``rust/src/runtime`` on the
    float serving path.
  * ``qlstm_step.hlo.txt`` — the Pallas integer LSTM step (interpret
    mode) with baked quantized parameters, for the cross-layer
    numerical check.
  * ``golden_qstep.bin`` — the same quantized parameters plus golden
    input/output vectors, consumed by the Rust integration test that
    asserts the Rust integer cell is bit-identical to the L1 kernel.

Usage: ``python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model as M  # noqa: E402
from .kernels import ref  # noqa: E402
from .kernels.qlstm import make_qlstm_step  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked weights must survive the text
    # round-trip (the default elides them as `constant({...})`).
    return comp.as_hlo_text(print_large_constants=True)


# ---------------------------------------------------------------------------
# Char-LM serving step.
# ---------------------------------------------------------------------------


def load_trained(out_dir: str):
    import json

    cfg_d = json.load(open(os.path.join(out_dir, "charlm.json")))
    cfg = M.CharLmConfig(**cfg_d)
    flat = dict(np.load(os.path.join(out_dir, "charlm.npz")))
    layers = []
    for d in range(cfg.depth):
        layer = {}
        for g in ("i", "f", "z", "o"):
            layer[g] = {
                "w": jnp.asarray(flat[f"layer{d}.{g}.w"]),
                "r": jnp.asarray(flat[f"layer{d}.{g}.r"]),
                "bias": jnp.asarray(flat[f"layer{d}.{g}.bias"]),
            }
        layers.append(layer)
    params = {
        "layers": layers,
        "out_w": jnp.asarray(flat["out.w"]),
        "out_b": jnp.asarray(flat["out.b"]),
    }
    return cfg, params


def lower_charlm_step(out_dir: str, batch: int) -> str:
    cfg, params = load_trained(out_dir)

    def step(x_onehot, *flat_states):
        states = [
            (flat_states[2 * i], flat_states[2 * i + 1]) for i in range(cfg.depth)
        ]
        logits, new_states = M.lm_step(params, x_onehot, states)
        outs = [logits]
        for c, h in new_states:
            outs.extend([c, h])
        return tuple(outs)

    spec_x = jax.ShapeDtypeStruct((batch, cfg.vocab), jnp.float32)
    spec_s = jax.ShapeDtypeStruct((batch, cfg.hidden), jnp.float32)
    lowered = jax.jit(step).lower(spec_x, *([spec_s] * (2 * cfg.depth)))
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# Integer (Pallas) step + golden vectors.
# ---------------------------------------------------------------------------

GOLDEN_N_INPUT = 32
GOLDEN_N_CELL = 64
GOLDEN_BATCH = 4
GOLDEN_STEPS = 6


def golden_params(seed: int = 2024) -> ref.QLstmParams:
    rng = np.random.default_rng(seed)

    def gate():
        return {
            "w": rng.normal(0, 1 / np.sqrt(GOLDEN_N_INPUT), (GOLDEN_N_CELL, GOLDEN_N_INPUT)),
            "r": rng.normal(0, 1 / np.sqrt(GOLDEN_N_CELL), (GOLDEN_N_CELL, GOLDEN_N_CELL)),
            "bias": rng.normal(0, 0.2, GOLDEN_N_CELL),
            "peephole": rng.normal(0, 0.1, GOLDEN_N_CELL),
        }

    fw = {n: gate() for n in ("i", "f", "z", "o")}
    fw["z"]["peephole"] = None
    stats = {"x": (-2.0, 2.5), "h": (-1.0, 1.0), "m": (-1.0, 1.0), "c_max_abs": 3.0}
    return ref.quantize_params(fw, stats)


def lower_qlstm_step(params: ref.QLstmParams) -> str:
    step = make_qlstm_step(params, tile_b=4, tile_n=32)
    spec_qx = jax.ShapeDtypeStruct((GOLDEN_BATCH, params.n_input), jnp.int8)
    spec_c = jax.ShapeDtypeStruct((GOLDEN_BATCH, params.n_cell), jnp.int16)
    spec_h = jax.ShapeDtypeStruct((GOLDEN_BATCH, params.n_output), jnp.int8)
    lowered = jax.jit(step).lower(spec_qx, spec_c, spec_h)
    return to_hlo_text(lowered)


def dump_golden(params: ref.QLstmParams, path: str, seed: int = 77) -> None:
    rng = np.random.default_rng(seed)
    tensors: dict[str, np.ndarray] = {
        "meta.dims": np.array(
            [params.n_input, params.n_cell, params.n_output], np.int32
        ),
        "meta.cell_ib": np.array([params.cell_ib], np.int32),
        "meta.cifg": np.array([int(params.cifg)], np.int32),
        "meta.zp": np.array(
            [
                params.input_q.zero_point,
                params.output_q.zero_point,
                params.hidden_q.zero_point,
            ],
            np.int32,
        ),
        "meta.eff_hidden": np.array(list(params.eff_hidden), np.int32),
    }
    for name, g in params.gates.items():
        tensors[f"gate.{name}.w"] = g.w
        tensors[f"gate.{name}.r"] = g.r
        tensors[f"gate.{name}.w_bias"] = g.w_bias
        tensors[f"gate.{name}.r_bias"] = g.r_bias
        tensors[f"gate.{name}.eff_x"] = np.array(list(g.eff_x), np.int32)
        tensors[f"gate.{name}.eff_h"] = np.array(list(g.eff_h), np.int32)
        if g.peephole is not None:
            tensors[f"gate.{name}.peephole"] = g.peephole
            tensors[f"gate.{name}.eff_c"] = np.array(list(g.eff_c), np.int32)

    # Golden trajectory: several recurrent steps to exercise state flow.
    qx = rng.integers(-128, 128, (GOLDEN_STEPS, GOLDEN_BATCH, params.n_input)).astype(np.int8)
    c = np.zeros((GOLDEN_BATCH, params.n_cell), np.int16)
    h = np.full((GOLDEN_BATCH, params.n_output), params.output_q.zero_point, np.int8)
    tensors["golden.qx"] = qx
    tensors["golden.c0"] = c.copy()
    tensors["golden.h0"] = h.copy()
    cs, hs = [], []
    cj, hj = jnp.asarray(c), jnp.asarray(h)
    for t in range(GOLDEN_STEPS):
        cj, hj = ref.qlstm_step_ref(params, jnp.asarray(qx[t]), cj, hj)
        cs.append(np.asarray(cj))
        hs.append(np.asarray(hj))
    tensors["golden.c_out"] = np.stack(cs)
    tensors["golden.h_out"] = np.stack(hs)
    M.write_tensors(path, tensors)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--skip-charlm", action="store_true",
                   help="only emit the integer-step artifacts")
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)

    params = golden_params()
    qpath = os.path.join(args.out, "qlstm_step.hlo.txt")
    with open(qpath, "w") as f:
        f.write(lower_qlstm_step(params))
    print(f"wrote {qpath}")
    gpath = os.path.join(args.out, "golden_qstep.bin")
    dump_golden(params, gpath)
    print(f"wrote {gpath}")

    if not args.skip_charlm:
        for batch in (1, 8):
            text = lower_charlm_step(args.out, batch)
            path = os.path.join(args.out, f"model_b{batch}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
