"""Layer-2 JAX model: a character-level LSTM language model.

This is the "small real model" of the end-to-end experiments: trained at
build time (``train.py``), lowered to HLO for the Rust runtime
(``aot.py``), and exported as a weight file the Rust engines load for
the Table-1 quality comparison.

The LSTM cell here is the *same* plain-variant cell as
``kernels/ref.py:float_lstm_step`` (and therefore as the Rust
``FloatLstm``): weight layouts are `[n_cell, n_input]` row-major, gates
i/f/z/o, forget bias +1.
"""

from __future__ import annotations

import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.ref import float_lstm_step

# ---------------------------------------------------------------------------
# Character vocabulary — shared with rust/src/workload/corpus.rs.
# ---------------------------------------------------------------------------

VOCAB = 96  # '\n' + ASCII 32..126


def tokenize(text: str) -> np.ndarray:
    ids = np.empty(len(text), np.int32)
    for k, ch in enumerate(text):
        o = ord(ch)
        if ch == "\n":
            ids[k] = 0
        elif 32 <= o < 127:
            ids[k] = o - 31
        else:
            ids[k] = 1  # space
    return ids


def detokenize(ids) -> str:
    return "".join("\n" if i == 0 else chr(int(i) + 31) for i in ids)


# ---------------------------------------------------------------------------
# Model definition.
# ---------------------------------------------------------------------------


@dataclass
class CharLmConfig:
    vocab: int = VOCAB
    hidden: int = 256
    depth: int = 2

    def to_json(self) -> str:
        return json.dumps(
            {"vocab": self.vocab, "hidden": self.hidden, "depth": self.depth}
        )


def init_params(cfg: CharLmConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)

    def gate(n_in, n_cell, forget=0.0):
        return {
            "w": rng.normal(0, 1 / np.sqrt(n_in), (n_cell, n_in)).astype(np.float32),
            "r": rng.normal(0, 1 / np.sqrt(n_cell), (n_cell, n_cell)).astype(np.float32),
            "bias": (forget + rng.normal(0, 0.1, n_cell)).astype(np.float32),
        }

    layers = []
    for d in range(cfg.depth):
        n_in = cfg.vocab if d == 0 else cfg.hidden
        layers.append(
            {
                "i": gate(n_in, cfg.hidden),
                "f": gate(n_in, cfg.hidden, forget=1.0),
                "z": gate(n_in, cfg.hidden),
                "o": gate(n_in, cfg.hidden),
            }
        )
    out_w = rng.normal(0, 1 / np.sqrt(cfg.hidden), (cfg.vocab, cfg.hidden)).astype(
        np.float32
    )
    out_b = np.zeros(cfg.vocab, np.float32)
    return {"layers": layers, "out_w": out_w, "out_b": out_b}


def zero_state(cfg: CharLmConfig, batch: int):
    return [
        (jnp.zeros((batch, cfg.hidden)), jnp.zeros((batch, cfg.hidden)))
        for _ in range(cfg.depth)
    ]


def lm_step(params: dict, x_onehot, states):
    """One step: x_onehot [B, V] -> (logits [B, V], new states)."""
    inp = x_onehot
    new_states = []
    for layer, (c, h) in zip(params["layers"], states):
        c, h = float_lstm_step(layer, inp, c, h)
        new_states.append((c, h))
        inp = h
    logits = inp @ params["out_w"].T + params["out_b"][None, :]
    return logits, new_states


def lm_forward(params: dict, tokens, cfg: CharLmConfig):
    """tokens [B, T] int32 -> logits [B, T, V] via scan over time."""
    batch = tokens.shape[0]

    def scan_fn(carry, x_t):
        logits, new_states = lm_step(params, x_t, carry)
        return new_states, logits

    onehot = jax.nn.one_hot(tokens, cfg.vocab, axis=-1)  # [B, T, V]
    xs = jnp.swapaxes(onehot, 0, 1)  # [T, B, V]
    _, logits = jax.lax.scan(scan_fn, zero_state(cfg, batch), xs)
    return jnp.swapaxes(logits, 0, 1)  # [B, T, V]


def lm_loss(params: dict, tokens, cfg: CharLmConfig):
    """Next-character cross-entropy in nats."""
    logits = lm_forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Hand-rolled Adam (optax is not available in the offline image).
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Weight export: the binary format rust/src/model/weights.rs reads.
# ---------------------------------------------------------------------------

_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1,
           np.dtype(np.int16): 2, np.dtype(np.int32): 3}
MAGIC = 0x49515257  # "IQRW"


def write_tensors(path: str, tensors: dict):
    """Write named tensors in the little-endian format shared with Rust."""
    import struct

    with open(path, "wb") as f:
        f.write(struct.pack("<II", MAGIC, 1))
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _DTYPES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def flatten_charlm(params: dict) -> dict:
    tensors = {}
    for li, layer in enumerate(params["layers"]):
        for gname, g in layer.items():
            for part in ("w", "r", "bias"):
                tensors[f"layer{li}.{gname}.{part}"] = np.asarray(g[part], np.float32)
    tensors["out.w"] = np.asarray(params["out_w"], np.float32)
    tensors["out.b"] = np.asarray(params["out_b"], np.float32)
    return tensors


def export_charlm(params: dict, cfg: CharLmConfig, path: str):
    tensors = flatten_charlm(params)
    write_tensors(path, tensors)
    # npz twin for python-side reloading (aot.py).
    np.savez(path.replace(".bin", ".npz"), **tensors)


# ---------------------------------------------------------------------------
# Synthetic corpus generator (the data substitution of DESIGN.md §3):
# a stochastic grammar with enough structure for a char-LM to learn.
# ---------------------------------------------------------------------------

_SUBJECTS = [
    "the encoder", "a decoder", "the quantizer", "our model", "the gate",
    "a kernel", "the scheduler", "this layer", "the cell state",
    "the accumulator", "a tensor", "the compiler", "our pipeline",
    "the server", "a stream", "the batch", "that request", "the profile",
]
_VERBS = [
    "computes", "accumulates", "rescales", "quantizes", "normalizes",
    "saturates", "clamps", "projects", "propagates", "emits", "folds",
    "multiplies", "shifts", "stores", "loads", "schedules", "decodes",
]
_OBJECTS = [
    "eight bit integers", "the hidden state", "a power of two scale",
    "the forget gate", "an int32 accumulator", "the zero point",
    "a fixed point product", "the output projection", "sixteen bit values",
    "the peephole connection", "a calibration range", "the layer norm",
    "the recurrent weights", "a saturating shift", "the effective scale",
]
_ADVERBS = [
    "quickly", "safely", "exactly", "twice", "without overflow",
    "in place", "per channel", "at runtime", "offline", "on device",
]


def generate_corpus(n_chars: int, seed: int = 1234) -> str:
    rng = np.random.default_rng(seed)
    parts: list[str] = []
    total = 0
    while total < n_chars:
        s = _SUBJECTS[rng.integers(len(_SUBJECTS))]
        v = _VERBS[rng.integers(len(_VERBS))]
        o = _OBJECTS[rng.integers(len(_OBJECTS))]
        sent = f"{s} {v} {o}"
        if rng.random() < 0.4:
            sent += f" {_ADVERBS[rng.integers(len(_ADVERBS))]}"
        if rng.random() < 0.25:
            sent += f" and {_VERBS[rng.integers(len(_VERBS))]} {_OBJECTS[rng.integers(len(_OBJECTS))]}"
        if rng.random() < 0.1:
            sent += f" {int(rng.integers(1, 32768))} times"
        sent = sent[0].upper() + sent[1:] + "."
        parts.append(sent)
        total += len(sent) + 1
        parts.append("\n" if rng.random() < 0.2 else " ")
    return "".join(parts)[:n_chars]
