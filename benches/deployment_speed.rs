//! E4 — §6 deployment speed: integer vs hybrid vs float execution time
//! (RT factor), plus the zero-point-folding ablation.
//!
//! Paper's shape: integer ≈ 5% faster than hybrid and ≈ 2x faster than
//! float in RT factor; folding the zero points into the bias offline is
//! what removes the per-element zero-point work from the inner loop.
//! Run: `cargo bench --bench deployment_speed`.

use iqrnn::coordinator::{
    chrome_trace_string, jsonl_string, shard_home, simulate_multi_shard_trace,
    simulate_shard_trace, simulate_trace, ModelId, SchedulerMode, ShardConfig,
    TraceConfig, TraceLevel,
};
use iqrnn::eval::metrics::RtFactor;
use iqrnn::lstm::{
    FloatState, IntegerState, LstmSpec, QuantizeOptions, StackEngine, StackWeights,
};
use iqrnn::lstm::{LayerState, LstmStack};
use iqrnn::model::lm::{CharLm, VOCAB};
use iqrnn::tensor::qmatmul::{fold_zero_point, matvec_i8_i32, matvec_i8_i32_unfolded};
use iqrnn::tensor::Matrix;
use iqrnn::util::timer::{bench, fmt_secs};
use iqrnn::util::Pcg32;
use iqrnn::workload::synth::RequestTrace;

/// Batch sizes of the batch-major sweep. Includes the ragged widths
/// (3, 5) that continuous batching actually produces after compaction —
/// the shapes the lane-padding + packed-kernel work targets.
const BATCH_SWEEP: [usize; 7] = [1, 3, 4, 5, 8, 16, 32];

/// CI smoke mode (`PALLAS_BENCH_QUICK=1`): shrink every sweep so the
/// whole bench runs in seconds. The point of the quick run is not
/// numbers — it proves the bench binary executes end to end and emits
/// every `BENCH_*.json` artifact on every PR.
fn quick() -> bool {
    iqrnn::util::env_flag("PALLAS_BENCH_QUICK")
}

fn engine_stack(
    weights: &StackWeights,
    engine: StackEngine,
    calib: &[Vec<Vec<f32>>],
) -> LstmStack {
    let stats = weights.calibrate(calib);
    LstmStack::build(weights, engine, Some(&stats), QuantizeOptions::default())
}

fn time_stack(stack: &LstmStack, xs: &[Vec<f32>], reps: usize) -> f64 {
    let n_out = stack.n_output();
    let mut out = vec![0f32; n_out];
    let sw = bench(1, reps, || {
        let mut states = stack.zero_state();
        for x in xs {
            stack.step(x, &mut states, &mut out);
        }
        out[0]
    });
    sw.median_secs()
}

fn main() {
    let mut rng = Pcg32::seeded(4);
    let quick = quick();
    if quick {
        println!("(quick mode: CI smoke sweep, numbers are not comparable)\n");
    }
    println!("== E4: engine speed (single stream, per-step wall clock) ==\n");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "config", "float", "hybrid", "integer", "int/float", "int/hybrid"
    );

    let speed_cfgs: &[(usize, usize, usize, usize)] = if quick {
        &[(32, 64, 1, 8)]
    } else {
        &[(64, 256, 1, 64), (256, 512, 2, 32), (96, 192, 2, 64)]
    };
    let reps = if quick { 3 } else { 9 };
    for &(n_input, hidden, depth, steps) in speed_cfgs {
        let spec = LstmSpec::plain(n_input, hidden);
        let weights = StackWeights::random(n_input, spec, depth, &mut rng);
        let calib: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|_| {
                (0..16)
                    .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect();
        let xs: Vec<Vec<f32>> = (0..steps)
            .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();

        let mut med = Vec::new();
        for engine in StackEngine::ALL {
            let stack = engine_stack(&weights, engine, &calib);
            med.push(time_stack(&stack, &xs, reps) / steps as f64);
        }
        println!(
            "{:<22} {:>12} {:>12} {:>12} {:>9.2}x {:>9.2}x",
            format!("{depth}x{hidden} in={n_input}"),
            fmt_secs(med[0]),
            fmt_secs(med[1]),
            fmt_secs(med[2]),
            med[0] / med[2],
            med[1] / med[2],
        );
    }

    // RT factor on the standard config (paper reports RT factors).
    {
        let n_input = 96;
        let hidden = if quick { 48 } else { 192 };
        let spec = LstmSpec::plain(n_input, hidden);
        let weights = StackWeights::random(n_input, spec, 2, &mut rng);
        let calib: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|_| {
                (0..16)
                    .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect();
        let tokens = if quick { 32usize } else { 512usize };
        let xs: Vec<Vec<f32>> = (0..tokens)
            .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        println!("\n== RT factor (nominal {} tok/s stream) ==", RtFactor::NOMINAL_TOKENS_PER_SEC);
        for engine in StackEngine::ALL {
            let stack = engine_stack(&weights, engine, &calib);
            let secs = time_stack(&stack, &xs, if quick { 2 } else { 5 });
            let rt = RtFactor::from_tokens(secs, tokens);
            println!("  {:<8} RT factor {:.4}", engine.label(), rt.value());
        }
    }

    // Batch-major sweep: tokens/sec vs batch for every engine through
    // `step_batch` — the perf trajectory of the batch-major refactor.
    // Emits BENCH_batch.json for trend tracking.
    {
        let n_input = 64usize;
        // Quick mode keeps a ragged hidden width so the CI smoke run
        // exercises the packed kernel's padded K path.
        let hidden = if quick { 40usize } else { 256 };
        let depth = 1usize;
        let steps = if quick { 8usize } else { 32 };
        let spec = LstmSpec::plain(n_input, hidden);
        let weights = StackWeights::random(n_input, spec, depth, &mut rng);
        let calib: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|_| {
                (0..16)
                    .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect();
        println!("\n== batch-major sweep ({depth}x{hidden} in={n_input}, tokens/sec) ==");
        println!("{:<8} {:>6} {:>12} {:>14}", "engine", "batch", "per-token", "tokens/sec");
        let mut entries: Vec<String> = Vec::new();
        for engine in StackEngine::ALL {
            let stack = engine_stack(&weights, engine, &calib);
            for &batch in BATCH_SWEEP.iter().filter(|&&b| !quick || b <= 8) {
                let xs: Vec<Matrix<f32>> = (0..steps)
                    .map(|_| {
                        let mut m = Matrix::<f32>::zeros(batch, n_input);
                        rng.fill_uniform_f32(&mut m.data, -1.5, 1.5);
                        m
                    })
                    .collect();
                let mut out = Matrix::<f32>::zeros(batch, stack.n_output());
                let secs = bench(1, if quick { 3 } else { 7 }, || {
                    let mut states = stack.zero_batch_state(batch);
                    for x in &xs {
                        stack.step_batch(x, &mut states, &mut out);
                    }
                    out.at(0, 0)
                })
                .median_secs();
                let tokens = (batch * steps) as f64;
                let tps = tokens / secs;
                println!(
                    "{:<8} {:>6} {:>12} {:>13.0}",
                    engine.label(),
                    batch,
                    fmt_secs(secs / tokens),
                    tps
                );
                entries.push(format!(
                    "    {{\"engine\": \"{}\", \"batch\": {}, \"tokens_per_sec\": {:.1}}}",
                    engine.label(),
                    batch,
                    tps
                ));
            }
        }
        let json = format!(
            "{{\n  \"bench\": \"batch_sweep\",\n  \"config\": {{\"n_input\": {n_input}, \
             \"hidden\": {hidden}, \"depth\": {depth}, \"steps\": {steps}}},\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            entries.join(",\n")
        );
        match std::fs::write("BENCH_batch.json", &json) {
            Ok(()) => println!("wrote BENCH_batch.json"),
            Err(e) => eprintln!("could not write BENCH_batch.json: {e}"),
        }
    }

    // Continuous-batching sweep: deterministic virtual-time replay of
    // Poisson / bursty / staggered traces through the lane scheduler,
    // wave-at-a-time vs continuous. Occupancy here is exactly
    // reproducible (no threads, no wall clock); tokens/sec is the
    // compute-side throughput of the replay. Emits BENCH_continuous.json.
    {
        let mut rng2 = Pcg32::seeded(7);
        // Quick mode uses a ragged hidden width (packed-K coverage) and
        // small traces.
        let hidden = if quick { 40usize } else { 96 };
        let spec = LstmSpec::plain(VOCAB, hidden);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng2);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, hidden);
        rng2.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        let lm = CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden, depth: 1 };
        let calib: Vec<Vec<usize>> = (0..if quick { 3 } else { 6 })
            .map(|_| (0..48).map(|_| rng2.below(VOCAB as u32) as usize).collect())
            .collect();
        let stats = lm.calibrate(&calib);
        let engine = lm.engine(StackEngine::Integer, Some(&stats), QuantizeOptions::default());

        let traces: Vec<(&str, RequestTrace)> = if quick {
            vec![
                ("poisson", RequestTrace::generate(24, 300.0, 16, VOCAB, 5)),
                ("bursty", RequestTrace::generate_bursty(3, 8, 30.0, 16, VOCAB, 6)),
                ("staggered", RequestTrace::generate_staggered(12, 6.0, 20, VOCAB, 7)),
            ]
        } else {
            vec![
                ("poisson", RequestTrace::generate(96, 900.0, 48, VOCAB, 5)),
                ("bursty", RequestTrace::generate_bursty(6, 16, 30.0, 48, VOCAB, 6)),
                ("staggered", RequestTrace::generate_staggered(24, 6.0, 64, VOCAB, 7)),
            ]
        };
        println!("\n== continuous batching vs wave-at-a-time (8 lanes, Integer) ==");
        println!(
            "{:<10} {:<11} {:>12} {:>10} {:>8} {:>8} {:>6}",
            "trace", "mode", "tokens/sec", "occupancy", "padded", "steps", "peak"
        );
        let mut entries: Vec<String> = Vec::new();
        for (name, trace) in &traces {
            let mut occs = Vec::new();
            for mode in [SchedulerMode::Wave, SchedulerMode::Continuous] {
                let t0 = std::time::Instant::now();
                let (sched, done) = simulate_trace(&engine, trace, 8, mode, 1.0);
                let secs = t0.elapsed().as_secs_f64();
                assert_eq!(done.len(), trace.requests.len());
                let st = sched.stats();
                let tps = st.lane_steps as f64 / secs;
                println!(
                    "{:<10} {:<11} {:>12.0} {:>10.3} {:>8.3} {:>8} {:>6}",
                    name,
                    mode.label(),
                    tps,
                    st.mean_occupancy(),
                    st.padded_occupancy(),
                    st.batched_steps,
                    st.peak_lanes
                );
                entries.push(format!(
                    "    {{\"trace\": \"{}\", \"mode\": \"{}\", \"tokens_per_sec\": {:.1}, \
                     \"occupancy\": {:.4}, \"padded_occupancy\": {:.4}, \
                     \"batched_steps\": {}, \"peak_lanes\": {}}}",
                    name,
                    mode.label(),
                    tps,
                    st.mean_occupancy(),
                    st.padded_occupancy(),
                    st.batched_steps,
                    st.peak_lanes
                ));
                occs.push(st.mean_occupancy());
            }
            if occs[1] > occs[0] {
                println!(
                    "  -> {name}: continuous lifts occupancy {:.3} -> {:.3} ({:+.1}%)",
                    occs[0],
                    occs[1],
                    (occs[1] / occs[0] - 1.0) * 100.0
                );
            }
        }
        let json = format!(
            "{{\n  \"bench\": \"continuous_batching\",\n  \"config\": {{\"hidden\": {hidden}, \
             \"depth\": 1, \"max_lanes\": 8, \"tick_ms\": 1.0}},\n  \"results\": [\n{}\n  ]\n}}\n",
            entries.join(",\n")
        );
        match std::fs::write("BENCH_continuous.json", &json) {
            Ok(()) => println!("wrote BENCH_continuous.json"),
            Err(e) => eprintln!("could not write BENCH_continuous.json: {e}"),
        }

        // Sharded-serving sweep: the same deterministic replay through
        // a whole worker pool (workers 1–8), under uniform vs skewed
        // session routing, with work stealing on and off. Pool
        // occupancy (lane-steps per worker-tick) and makespan ticks are
        // exactly reproducible; tokens/sec is the compute-side
        // throughput of the replay. Emits BENCH_shard.json.
        println!("\n== sharded serving sweep (8 lanes/worker, Integer) ==");
        println!(
            "{:<8} {:<8} {:<6} {:>12} {:>10} {:>8} {:>7}",
            "workers", "routing", "steal", "tokens/sec", "pool occ", "ticks", "steals"
        );
        let base = if quick {
            RequestTrace::generate(32, 400.0, 16, VOCAB, 11)
        } else {
            RequestTrace::generate(128, 1200.0, 48, VOCAB, 11)
        };
        let worker_sweep: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
        let mut entries: Vec<String> = Vec::new();
        for &workers in worker_sweep {
            for routing in ["uniform", "skewed"] {
                let mut trace = base.clone();
                if routing == "skewed" {
                    // Every session hash-homes to worker 0.
                    trace.reassign_ids(|id| shard_home(id, workers) == 0);
                }
                let mut occs = Vec::new();
                for steal in [false, true] {
                    let cfg = ShardConfig {
                        workers,
                        max_lanes: 8,
                        steal,
                        ..ShardConfig::default()
                    };
                    let t0 = std::time::Instant::now();
                    let (_scheds, rep) = simulate_shard_trace(&engine, &trace, &cfg);
                    let secs = t0.elapsed().as_secs_f64();
                    assert_eq!(rep.completions.len(), trace.requests.len());
                    let tps = rep.lane_steps() as f64 / secs;
                    println!(
                        "{:<8} {:<8} {:<6} {:>12.0} {:>10.3} {:>8} {:>7}",
                        workers,
                        routing,
                        if steal { "on" } else { "off" },
                        tps,
                        rep.pool_occupancy(),
                        rep.ticks,
                        rep.total_stolen()
                    );
                    entries.push(format!(
                        "    {{\"workers\": {}, \"routing\": \"{}\", \"steal\": {}, \
                         \"tokens_per_sec\": {:.1}, \"pool_occupancy\": {:.4}, \
                         \"ticks\": {}, \"stolen_sessions\": {}}}",
                        workers,
                        routing,
                        steal,
                        tps,
                        rep.pool_occupancy(),
                        rep.ticks,
                        rep.total_stolen()
                    ));
                    occs.push(rep.pool_occupancy());
                }
                if workers > 1 && routing == "skewed" && occs[1] > occs[0] {
                    println!(
                        "  -> {workers} workers skewed: stealing lifts pool occupancy \
                         {:.3} -> {:.3} ({:+.1}%)",
                        occs[0],
                        occs[1],
                        (occs[1] / occs[0] - 1.0) * 100.0
                    );
                }
            }
        }
        let json = format!(
            "{{\n  \"bench\": \"shard_sweep\",\n  \"config\": {{\"hidden\": {hidden}, \
             \"depth\": 1, \"max_lanes\": 8, \"tick_ms\": 1.0, \"requests\": {}}},\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            base.requests.len(),
            entries.join(",\n")
        );
        match std::fs::write("BENCH_shard.json", &json) {
            Ok(()) => println!("wrote BENCH_shard.json"),
            Err(e) => eprintln!("could not write BENCH_shard.json: {e}"),
        }

        // Multi-model sweep: 1/2/4 resident model variants sharing one
        // pool (the registry serving shape), swept over worker counts.
        // Each variant is an integer engine instance of the same
        // weights, so the sweep isolates the scheduling cost of wave
        // multiplexing: per-model occupancy falls as variants split the
        // lane budget, while pool occupancy and bit-exactness hold.
        // Emits BENCH_multimodel.json.
        println!("\n== multi-model sweep (8 lanes/worker, Integer x N variants) ==");
        println!(
            "{:<8} {:<8} {:>12} {:>10} {:>10} {:>8} {:>7}",
            "models", "workers", "tokens/sec", "pool occ", "model occ", "ticks", "steals"
        );
        let mm_trace_base = if quick {
            RequestTrace::generate(24, 400.0, 12, VOCAB, 13)
        } else {
            RequestTrace::generate(96, 1200.0, 32, VOCAB, 13)
        };
        let model_sweep: &[usize] = &[1, 2, 4];
        let mm_workers: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
        let mut entries: Vec<String> = Vec::new();
        for &n_models in model_sweep {
            let engines: Vec<_> = (0..n_models)
                .map(|_| {
                    lm.engine(StackEngine::Integer, Some(&stats), QuantizeOptions::default())
                })
                .collect();
            for &workers in mm_workers {
                let residency: Vec<Vec<usize>> =
                    (0..n_models).map(|_| (0..workers).collect()).collect();
                let mut trace = mm_trace_base.clone();
                trace.assign_models(|id| (id % n_models as u64) as ModelId);
                let cfg = ShardConfig { workers, max_lanes: 8, ..ShardConfig::default() };
                let t0 = std::time::Instant::now();
                let (_scheds, rep) =
                    simulate_multi_shard_trace(&engines, &residency, &trace, &cfg);
                let secs = t0.elapsed().as_secs_f64();
                assert_eq!(rep.completions.len(), trace.requests.len());
                let tps = rep.lane_steps() as f64 / secs;
                let model_occ: f64 = rep
                    .per_model
                    .iter()
                    .map(|s| s.mean_occupancy())
                    .sum::<f64>()
                    / n_models as f64;
                println!(
                    "{:<8} {:<8} {:>12.0} {:>10.3} {:>10.3} {:>8} {:>7}",
                    n_models,
                    workers,
                    tps,
                    rep.pool_occupancy(),
                    model_occ,
                    rep.ticks,
                    rep.total_stolen()
                );
                entries.push(format!(
                    "    {{\"models\": {}, \"workers\": {}, \"tokens_per_sec\": {:.1}, \
                     \"pool_occupancy\": {:.4}, \"mean_model_occupancy\": {:.4}, \
                     \"ticks\": {}, \"stolen_sessions\": {}}}",
                    n_models,
                    workers,
                    tps,
                    rep.pool_occupancy(),
                    model_occ,
                    rep.ticks,
                    rep.total_stolen()
                ));
            }
        }
        let json = format!(
            "{{\n  \"bench\": \"multimodel_sweep\",\n  \"config\": {{\"hidden\": {hidden}, \
             \"depth\": 1, \"max_lanes\": 8, \"tick_ms\": 1.0, \"requests\": {}}},\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            mm_trace_base.requests.len(),
            entries.join(",\n")
        );
        match std::fs::write("BENCH_multimodel.json", &json) {
            Ok(()) => println!("wrote BENCH_multimodel.json"),
            Err(e) => eprintln!("could not write BENCH_multimodel.json: {e}"),
        }

        // Hibernation sweep: the same deterministic replay under a
        // tightening per-worker byte budget. `enforce_state_budget`
        // spills the coldest idle sessions into the cold tier between
        // token positions and admission restores them transparently, so
        // tightening the budget trades spill/restore traffic (and
        // replay throughput) for a bounded resident-state peak — while
        // the token stream stays bit-identical to the unbounded run.
        // Swept for both spill codecs (exact f32 image vs per-vector
        // int8). Emits BENCH_hibernate.json.
        println!("\n== hibernation sweep (byte-budgeted cold tier, Integer) ==");
        println!(
            "{:<10} {:<6} {:>12} {:>8} {:>9} {:>11} {:>11}",
            "budget", "codec", "tokens/sec", "spills", "restores", "peak bytes", "cold bytes"
        );
        let sb = engine.state_bytes();
        let mut hib_trace = if quick {
            RequestTrace::generate(24, 500.0, 12, VOCAB, 17)
        } else {
            RequestTrace::generate(96, 900.0, 32, VOCAB, 17)
        };
        // Fold the unique request ids onto a smaller session id space
        // so sessions span several chunks — a returning session is what
        // turns a spill into a restore (not just a parked state).
        let streams: u64 = if quick { 8 } else { 24 };
        for r in &mut hib_trace.requests {
            r.id %= streams;
        }
        let hib_lanes = 4usize;
        let budgets: &[(&str, Option<usize>)] = &[
            ("unbounded", None),
            ("16x", Some(16 * sb)),
            ("8x", Some(8 * sb)),
            ("4x", Some(4 * sb)),
        ];
        let mut baseline: Option<Vec<String>> = None;
        let mut entries: Vec<String> = Vec::new();
        for &(label, budget) in budgets {
            for quantized in [false, true] {
                if budget.is_none() && quantized {
                    continue; // nothing spills: the codec is irrelevant
                }
                let cfg = ShardConfig {
                    workers: 2,
                    max_lanes: hib_lanes,
                    state_budget: budget,
                    spill_quantized: quantized,
                    ..ShardConfig::default()
                };
                let t0 = std::time::Instant::now();
                let (scheds, rep) = simulate_shard_trace(&engine, &hib_trace, &cfg);
                let secs = t0.elapsed().as_secs_f64();
                assert_eq!(rep.completions.len(), hib_trace.requests.len());
                // The integer engine's token stream is bit-identical
                // under every budget and either codec: spills only park
                // idle sessions and restores precede re-admission.
                let tuples: Vec<String> = rep
                    .completions
                    .iter()
                    .map(|d| {
                        format!(
                            "{}:{}:{}:{}",
                            d.model,
                            d.session,
                            d.tokens,
                            d.nll_bits.to_bits()
                        )
                    })
                    .collect();
                match &baseline {
                    None => baseline = Some(tuples),
                    Some(base) => {
                        assert_eq!(base, &tuples, "byte budget changed the token stream")
                    }
                }
                let tps = rep.lane_steps() as f64 / secs;
                let peak = rep
                    .worker_stats
                    .iter()
                    .map(|st| st.peak_resident_state_bytes)
                    .max()
                    .unwrap_or(0);
                let cold: usize = scheds.iter().map(|s| s.hibernated_state_bytes()).sum();
                let codec = if quantized { "int8" } else { "exact" };
                println!(
                    "{:<10} {:<6} {:>12.0} {:>8} {:>9} {:>11} {:>11}",
                    label,
                    codec,
                    tps,
                    rep.total_spilled(),
                    rep.total_restored(),
                    peak,
                    cold
                );
                entries.push(format!(
                    "    {{\"budget\": \"{}\", \"budget_bytes\": {}, \"codec\": \"{}\", \
                     \"tokens_per_sec\": {:.1}, \"spills\": {}, \"restores\": {}, \
                     \"peak_resident_bytes\": {}, \"final_cold_bytes\": {}, \"ticks\": {}}}",
                    label,
                    budget.map(|b| b as i64).unwrap_or(-1),
                    codec,
                    tps,
                    rep.total_spilled(),
                    rep.total_restored(),
                    peak,
                    cold,
                    rep.ticks
                ));
            }
        }
        let json = format!(
            "{{\n  \"bench\": \"hibernate_sweep\",\n  \"config\": {{\"hidden\": {hidden}, \
             \"depth\": 1, \"workers\": 2, \"max_lanes\": {hib_lanes}, \
             \"state_bytes\": {sb}, \"requests\": {}, \"streams\": {streams}}},\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            hib_trace.requests.len(),
            entries.join(",\n")
        );
        match std::fs::write("BENCH_hibernate.json", &json) {
            Ok(()) => println!("wrote BENCH_hibernate.json"),
            Err(e) => eprintln!("could not write BENCH_hibernate.json: {e}"),
        }

        // Trace-overhead sweep: the observability cost contract. The
        // same deterministic replay at every trace level — the token
        // stream must be bit-identical across levels (tracing never
        // perturbs the schedule) and the Counters level must cost no
        // more than 5% throughput over Off. The Full run's event log is
        // written out as the sample Chrome-trace + JSONL artifacts CI
        // uploads next to the BENCH_*.json series. Emits
        // BENCH_trace.json, TRACE_shard.json, TRACE_shard.jsonl.
        println!("\n== trace overhead sweep (2 workers, 8 lanes, Integer) ==");
        println!(
            "{:<10} {:>12} {:>9} {:>9}",
            "level", "tokens/sec", "events", "stage n"
        );
        let tr_trace = if quick {
            RequestTrace::generate(24, 500.0, 12, VOCAB, 19)
        } else {
            RequestTrace::generate(96, 900.0, 32, VOCAB, 19)
        };
        let tr_reps = if quick { 3 } else { 5 };
        let mut level_secs: Vec<f64> = Vec::new();
        let mut entries: Vec<String> = Vec::new();
        let mut baseline: Option<Vec<String>> = None;
        let mut full_events = Vec::new();
        for level in TraceLevel::ALL {
            let cfg = ShardConfig {
                workers: 2,
                max_lanes: 8,
                trace: TraceConfig { level, ..TraceConfig::default() },
                ..ShardConfig::default()
            };
            let mut best = f64::INFINITY;
            let mut last = None;
            for _ in 0..tr_reps {
                let t0 = std::time::Instant::now();
                let (_scheds, rep) = simulate_shard_trace(&engine, &tr_trace, &cfg);
                best = best.min(t0.elapsed().as_secs_f64());
                assert_eq!(rep.completions.len(), tr_trace.requests.len());
                last = Some(rep);
            }
            let rep = last.expect("at least one rep");
            let tuples: Vec<String> = rep
                .completions
                .iter()
                .map(|d| {
                    format!(
                        "{}:{}:{}:{}",
                        d.model,
                        d.session,
                        d.tokens,
                        d.nll_bits.to_bits()
                    )
                })
                .collect();
            match &baseline {
                None => baseline = Some(tuples),
                Some(base) => assert_eq!(
                    base,
                    &tuples,
                    "trace level {} changed the token stream",
                    level.label()
                ),
            }
            let tps = rep.lane_steps() as f64 / best;
            println!(
                "{:<10} {:>12.0} {:>9} {:>9}",
                level.label(),
                tps,
                rep.trace_events.len(),
                rep.stage.execute.count()
            );
            entries.push(format!(
                "    {{\"level\": \"{}\", \"tokens_per_sec\": {:.1}, \"events\": {}, \
                 \"ticks\": {}}}",
                level.label(),
                tps,
                rep.trace_events.len(),
                rep.ticks
            ));
            if level == TraceLevel::Full {
                full_events = rep.trace_events;
            }
            level_secs.push(best);
        }
        // The cost contract: Counters within 5% of Off. The 2 ms
        // absolute floor keeps the quick run's tiny timings from
        // flaking the assert on scheduler jitter.
        let (o_min, c_min) = (level_secs[0], level_secs[1]);
        assert!(
            c_min <= o_min * 1.05 + 0.002,
            "Counters tracing overhead above 5%: off {o_min:.4}s vs counters {c_min:.4}s"
        );
        let json = format!(
            "{{\n  \"bench\": \"trace_overhead\",\n  \"config\": {{\"workers\": 2, \
             \"max_lanes\": 8, \"requests\": {}, \"reps\": {tr_reps}}},\n  \
             \"counters_overhead_vs_off\": {:.4},\n  \"results\": [\n{}\n  ]\n}}\n",
            tr_trace.requests.len(),
            c_min / o_min,
            entries.join(",\n")
        );
        match std::fs::write("BENCH_trace.json", &json) {
            Ok(()) => println!("wrote BENCH_trace.json"),
            Err(e) => eprintln!("could not write BENCH_trace.json: {e}"),
        }
        match std::fs::write("TRACE_shard.json", chrome_trace_string(&full_events)) {
            Ok(()) => println!("wrote TRACE_shard.json ({} events)", full_events.len()),
            Err(e) => eprintln!("could not write TRACE_shard.json: {e}"),
        }
        match std::fs::write("TRACE_shard.jsonl", jsonl_string(&full_events)) {
            Ok(()) => println!("wrote TRACE_shard.jsonl"),
            Err(e) => eprintln!("could not write TRACE_shard.jsonl: {e}"),
        }

        // Network serving sweep: the same pool behind the loopback TCP
        // front, measured on the wall clock — first-token and per-token
        // latency percentiles as a streaming client would see them.
        // Unlike the virtual-time sweeps above these numbers are NOT
        // deterministic (threads + sockets), which is exactly the
        // point: this is the deployed-latency view the integer-only
        // serving line evaluates on. Runs in quick mode too; emits
        // BENCH_net.json.
        {
            use iqrnn::coordinator::{
                BatchPolicy, Frame, NetClient, NetConfig, NetServer, NetShutdown,
                Server, ServerConfig,
            };
            use std::time::Duration;

            let net_trace = if quick {
                RequestTrace::generate(24, 500.0, 16, VOCAB, 41)
            } else {
                RequestTrace::generate(120, 800.0, 48, VOCAB, 41)
            };
            let worker_sweep: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
            println!("\n== network serving sweep (loopback TCP, Integer) ==");
            println!(
                "{:<8} {:>12} {:>10} {:>10} {:>12} {:>10}",
                "workers", "tokens/sec", "ft p50", "ft p99", "per-tok p50", "e2e p99"
            );
            let mut entries: Vec<String> = Vec::new();
            for &workers in worker_sweep {
                let server = Server::new(
                    &lm,
                    Some(&stats),
                    ServerConfig {
                        workers,
                        batch: BatchPolicy {
                            max_batch: 8,
                            max_wait: Duration::from_millis(2),
                        },
                        engine: StackEngine::Integer,
                        ..ServerConfig::default()
                    },
                );
                let net = NetServer::bind(
                    &server,
                    NetConfig {
                        max_inflight_per_model: Some(net_trace.requests.len()),
                        ..NetConfig::default()
                    },
                )
                .expect("bind loopback");
                let addr = net.local_addr().expect("local addr");
                let stop = NetShutdown::new();
                let report = std::thread::scope(|s| {
                    let handle = s.spawn(|| net.serve(&stop).expect("serve"));
                    let mut client = NetClient::connect(addr).expect("connect");
                    for req in &net_trace.requests {
                        client.send(req.model, req.id, &req.tokens).expect("send");
                    }
                    client.finish().expect("half-close");
                    let streamed = client
                        .read_to_bye()
                        .expect("read streams")
                        .iter()
                        .filter(|f| matches!(f, Frame::Token { .. }))
                        .count();
                    assert_eq!(streamed, net_trace.total_tokens(), "tokens lost");
                    stop.shutdown();
                    handle.join().expect("serve thread")
                });
                let sv = &report.serving;
                println!(
                    "{:<8} {:>12.0} {:>8.2}ms {:>8.2}ms {:>10.3}ms {:>8.2}ms",
                    workers,
                    sv.throughput(),
                    sv.first_token_latency.percentile(50.0),
                    sv.first_token_latency.percentile(99.0),
                    sv.per_token_latency.percentile(50.0),
                    sv.latency.percentile(99.0),
                );
                entries.push(format!(
                    "    {{\"workers\": {}, \"requests\": {}, \"tokens\": {}, \
                     \"wall_secs\": {:.4}, \"tokens_per_sec\": {:.1}, \
                     \"first_token_p50_ms\": {:.3}, \"first_token_p95_ms\": {:.3}, \
                     \"first_token_p99_ms\": {:.3}, \"per_token_p50_ms\": {:.4}, \
                     \"per_token_p95_ms\": {:.4}, \"e2e_p50_ms\": {:.3}, \
                     \"e2e_p99_ms\": {:.3}, \"busy_rejections\": {}}}",
                    workers,
                    sv.requests,
                    sv.tokens,
                    sv.wall_secs,
                    sv.throughput(),
                    sv.first_token_latency.percentile(50.0),
                    sv.first_token_latency.percentile(95.0),
                    sv.first_token_latency.percentile(99.0),
                    sv.per_token_latency.percentile(50.0),
                    sv.per_token_latency.percentile(95.0),
                    sv.latency.percentile(50.0),
                    sv.latency.percentile(99.0),
                    report.busy_rejections
                ));
            }
            let json = format!(
                "{{\n  \"bench\": \"net_sweep\",\n  \"config\": {{\"hidden\": {hidden}, \
                 \"depth\": 1, \"max_lanes\": 8, \"requests\": {}, \"transport\": \
                 \"loopback-tcp\"}},\n  \"results\": [\n{}\n  ]\n}}\n",
                net_trace.requests.len(),
                entries.join(",\n")
            );
            match std::fs::write("BENCH_net.json", &json) {
                Ok(()) => println!("wrote BENCH_net.json"),
                Err(e) => eprintln!("could not write BENCH_net.json: {e}"),
            }
        }
    }

    // Block-sparse kernel sweep: the batched block-sparse GEMM vs the
    // dense packed GEMM vs the old per-lane scalar CSR fallback (what
    // `WeightMat::Sparse` executed before the block kernel), at the
    // paper-relevant sparsity levels. Block-structured pruning in the
    // kernel's own MR × K_BLOCK tile shape, so element sparsity is
    // what the kernel actually skips. Runs in quick mode too so CI
    // emits the artifact on every PR. Emits BENCH_sparse.json.
    {
        use iqrnn::quant::quantize_symmetric_i8;
        use iqrnn::sparse::{prune_block_structured, BlockSparseI8, SparseMatrixI8};
        use iqrnn::tensor::PackedWeightsI8;

        let (rows, cols) = if quick { (64usize, 64usize) } else { (256usize, 256usize) };
        let batch = 8usize;
        let reps = if quick { 3 } else { 11 };
        let inner = if quick { 20usize } else { 200 };
        println!("\n== block-sparse kernel sweep ({rows}x{cols}, batch {batch}) ==");
        println!(
            "{:<10} {:>14} {:>14} {:>14} {:>9} {:>9}",
            "sparsity", "dense tok/s", "bsr tok/s", "csr tok/s", "bsr/csr", "bsr/dense"
        );
        let mut entries: Vec<String> = Vec::new();
        for &sparsity in &[0.5f64, 0.75, 0.9] {
            let mut wf = Matrix::<f32>::zeros(rows, cols);
            rng.fill_uniform_f32(&mut wf.data, -1.0, 1.0);
            prune_block_structured(&mut wf, sparsity);
            let (w, _q) = quantize_symmetric_i8(&wf);
            let packed = PackedWeightsI8::pack(w.clone());
            let bsr = BlockSparseI8::from_dense(&w);
            let csr = SparseMatrixI8::from_dense(&w);
            let mut x = Matrix::<i8>::zeros(batch, cols);
            for v in &mut x.data {
                *v = rng.range_i32(-128, 127) as i8;
            }
            let mut out = Matrix::<i32>::zeros(batch, rows);
            let t_dense = bench(1, reps, || {
                for _ in 0..inner {
                    packed.gemm(&x, &[], &mut out);
                }
                out.at(0, 0)
            })
            .median_secs();
            let t_bsr = bench(1, reps, || {
                for _ in 0..inner {
                    bsr.gemm(&x, &[], &mut out);
                }
                out.at(0, 0)
            })
            .median_secs();
            // The pre-block-kernel serving fallback: one scalar CSR
            // matvec per live lane.
            let t_csr = bench(1, reps, || {
                for _ in 0..inner {
                    for b in 0..batch {
                        let or = &mut out.data[b * rows..(b + 1) * rows];
                        csr.matvec_i32(x.row(b), &[], or);
                    }
                }
                out.at(0, 0)
            })
            .median_secs();
            let toks = (batch * inner) as f64;
            let (d_tps, b_tps, c_tps) = (toks / t_dense, toks / t_bsr, toks / t_csr);
            println!(
                "{:<10} {:>14.0} {:>14.0} {:>14.0} {:>8.2}x {:>8.2}x",
                format!("{:.0}%", sparsity * 100.0),
                d_tps,
                b_tps,
                c_tps,
                b_tps / c_tps,
                b_tps / d_tps
            );
            entries.push(format!(
                "    {{\"sparsity\": {:.2}, \"block_density\": {:.4}, \
                 \"dense_tokens_per_sec\": {:.1}, \"bsr_tokens_per_sec\": {:.1}, \
                 \"csr_per_lane_tokens_per_sec\": {:.1}}}",
                sparsity,
                bsr.block_density(),
                d_tps,
                b_tps,
                c_tps
            ));
        }
        let json = format!(
            "{{\n  \"bench\": \"sparse_sweep\",\n  \"config\": {{\"rows\": {rows}, \
             \"cols\": {cols}, \"batch\": {batch}}},\n  \"results\": [\n{}\n  ]\n}}\n",
            entries.join(",\n")
        );
        match std::fs::write("BENCH_sparse.json", &json) {
            Ok(()) => println!("wrote BENCH_sparse.json"),
            Err(e) => eprintln!("could not write BENCH_sparse.json: {e}"),
        }
    }

    // Int4 nibble-weight sweep: the dense int8 panel kernel vs the
    // nibble-packed int4 kernel at matched shapes (throughput + packed
    // bytes), then the Table-1-style accuracy view — float vs integer
    // int8 vs integer int4 bits/char with the weight footprint each
    // pays. Runs in quick mode too so CI emits the artifact on every
    // PR. Emits BENCH_int4.json.
    {
        use iqrnn::lstm::WeightBits;
        use iqrnn::quant::{quantize_symmetric_i4, quantize_symmetric_i8};
        use iqrnn::tensor::{PackedWeightsI4, PackedWeightsI8};

        let batch = 8usize;
        let reps = if quick { 3 } else { 11 };
        let inner = if quick { 20usize } else { 200 };
        let shapes: &[(usize, usize)] =
            if quick { &[(64, 64)] } else { &[(256, 256), (512, 512)] };
        println!("\n== int4 nibble kernel sweep (batch {batch}) ==");
        println!(
            "{:<10} {:>14} {:>14} {:>9} {:>11} {:>11}",
            "shape", "int8 tok/s", "int4 tok/s", "int4/int8", "int8 bytes", "int4 bytes"
        );
        let mut kernel_entries: Vec<String> = Vec::new();
        for &(rows, cols) in shapes {
            let mut wf = Matrix::<f32>::zeros(rows, cols);
            rng.fill_uniform_f32(&mut wf.data, -1.0, 1.0);
            let (w8, _) = quantize_symmetric_i8(&wf);
            let (w4, _) = quantize_symmetric_i4(&wf);
            let packed8 = PackedWeightsI8::pack(w8);
            let packed4 = PackedWeightsI4::pack(&w4);
            let mut x = Matrix::<i8>::zeros(batch, cols);
            for v in &mut x.data {
                *v = rng.range_i32(-128, 127) as i8;
            }
            let mut out = Matrix::<i32>::zeros(batch, rows);
            let t8 = bench(1, reps, || {
                for _ in 0..inner {
                    packed8.gemm(&x, &[], &mut out);
                }
                out.at(0, 0)
            })
            .median_secs();
            let t4 = bench(1, reps, || {
                for _ in 0..inner {
                    packed4.gemm(&x, &[], &mut out);
                }
                out.at(0, 0)
            })
            .median_secs();
            let toks = (batch * inner) as f64;
            let (tps8, tps4) = (toks / t8, toks / t4);
            println!(
                "{:<10} {:>14.0} {:>14.0} {:>8.2}x {:>11} {:>11}",
                format!("{rows}x{cols}"),
                tps8,
                tps4,
                tps4 / tps8,
                packed8.storage_bytes(),
                packed4.storage_bytes()
            );
            kernel_entries.push(format!(
                "    {{\"rows\": {}, \"cols\": {}, \"int8_tokens_per_sec\": {:.1}, \
                 \"int4_tokens_per_sec\": {:.1}, \"int8_bytes\": {}, \"int4_bytes\": {}}}",
                rows,
                cols,
                tps8,
                tps4,
                packed8.storage_bytes(),
                packed4.storage_bytes()
            ));
        }

        // Table-1-style accuracy: same model, same calibration, weight
        // bits swept. Synthetic weights and text, so the absolute
        // bits/char is not a corpus number — the tracked quantity is
        // the int4-vs-int8 delta at the halved footprint.
        let mut rng4 = Pcg32::seeded(23);
        let hidden = if quick { 40usize } else { 96 };
        let spec = LstmSpec::plain(VOCAB, hidden);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng4);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, hidden);
        rng4.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        let lm = CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden, depth: 1 };
        let calib: Vec<Vec<usize>> = (0..if quick { 3 } else { 6 })
            .map(|_| (0..48).map(|_| rng4.below(VOCAB as u32) as usize).collect())
            .collect();
        let stats = lm.calibrate(&calib);
        let eval: Vec<Vec<usize>> = (0..if quick { 4 } else { 12 })
            .map(|_| (0..64).map(|_| rng4.below(VOCAB as u32) as usize).collect())
            .collect();
        println!("\n== int4 accuracy/size (Table-1 style, {hidden}h synthetic) ==");
        println!("{:<10} {:<6} {:>10} {:>12}", "engine", "bits", "bits/char", "weight bytes");
        let mut model_entries: Vec<String> = Vec::new();
        let rows: &[(StackEngine, WeightBits)] = &[
            (StackEngine::Float, WeightBits::Int8),
            (StackEngine::Integer, WeightBits::Int8),
            (StackEngine::Integer, WeightBits::Int4),
        ];
        for &(engine_kind, bits) in rows {
            let opts = QuantizeOptions { weight_bits: bits, ..Default::default() };
            let e = lm.engine(engine_kind, Some(&stats), opts);
            let bpc: f64 = eval.iter().map(|s| e.bits_per_char(s)).sum::<f64>()
                / eval.len() as f64;
            let label = if engine_kind == StackEngine::Float {
                "fp32".to_string()
            } else {
                bits.label().to_string()
            };
            println!(
                "{:<10} {:<6} {:>10.4} {:>12}",
                engine_kind.label(),
                label,
                bpc,
                e.weight_bytes()
            );
            model_entries.push(format!(
                "    {{\"engine\": \"{}\", \"weight_bits\": \"{}\", \
                 \"bits_per_char\": {:.4}, \"weight_bytes\": {}}}",
                engine_kind.label(),
                label,
                bpc,
                e.weight_bytes()
            ));
        }
        let json = format!(
            "{{\n  \"bench\": \"int4_sweep\",\n  \"config\": {{\"batch\": {batch}, \
             \"hidden\": {hidden}, \"depth\": 1}},\n  \"kernel\": [\n{}\n  ],\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            kernel_entries.join(",\n"),
            model_entries.join(",\n")
        );
        match std::fs::write("BENCH_int4.json", &json) {
            Ok(()) => println!("wrote BENCH_int4.json"),
            Err(e) => eprintln!("could not write BENCH_int4.json: {e}"),
        }
    }

    // §6 ablation: folded vs unfolded zero-point handling in the gate
    // matmul inner loop.
    println!("\n== §6 ablation: zero-point folding in the int8 matvec ==");
    let ablation_cfgs: &[(usize, usize)] = if quick {
        &[(128, 128)]
    } else {
        &[(256, 256), (512, 512), (1024, 1024)]
    };
    for &(rows, cols) in ablation_cfgs {
        let mut w = Matrix::<i8>::zeros(rows, cols);
        for v in &mut w.data {
            *v = rng.range_i32(-127, 127) as i8;
        }
        let x: Vec<i8> = (0..cols).map(|_| rng.range_i32(-128, 127) as i8).collect();
        let bias: Vec<i32> = (0..rows).map(|_| rng.range_i32(-1000, 1000)).collect();
        let zp = 12;
        let folded = fold_zero_point(&w, &bias, zp);
        let mut out = vec![0i32; rows];
        let t_folded = bench(3, 31, || {
            matvec_i8_i32(&w, &x, &folded, &mut out);
            out[0]
        })
        .median_secs();
        let t_unfolded = bench(3, 31, || {
            matvec_i8_i32_unfolded(&w, &x, &bias, zp, &mut out);
            out[0]
        })
        .median_secs();
        println!(
            "  {rows}x{cols}: folded {} unfolded {} ({:.2}x — \"about 5%\" class win)",
            fmt_secs(t_folded),
            fmt_secs(t_unfolded),
            t_unfolded / t_folded
        );
    }

    // State copy cost: confirm integer state (int16+int8) is 3x smaller
    // than float state — the memory-bandwidth side of the speedup.
    {
        let hidden = if quick { 64 } else { 512 };
        let spec = LstmSpec::plain(64, hidden);
        let weights = StackWeights::random(64, spec, 1, &mut rng);
        let calib: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.5; 64]; 4]];
        let stats = weights.calibrate(&calib);
        let integer = LstmStack::build(
            &weights,
            StackEngine::Integer,
            Some(&stats),
            QuantizeOptions::default(),
        );
        let float_state_bytes = hidden * 4 * 2;
        let st = integer.zero_state();
        let int_state_bytes = match &st[0] {
            LayerState::Integer(s) => s.c.len() * 2 + s.h.len(),
            LayerState::Float(s) => (s.c.len() + s.h.len()) * 4,
        };
        println!(
            "\nper-stream state: float {}B vs integer {}B ({:.2}x smaller)",
            float_state_bytes,
            int_state_bytes,
            float_state_bytes as f64 / int_state_bytes as f64
        );
        let _ = (FloatState::zeros(&spec), IntegerState { c: vec![], h: vec![] });
    }
    println!("\npaper shape: integer ≥ hybrid > float in speed; ~2x vs float.");
}
