//! Ablation benches (E2, E5, E6, E9-adjacent): the design-choice
//! experiments DESIGN.md calls out.
//!
//! * E2 — prints the Table-2 recipe for all 8 variants and checks the
//!   size arithmetic;
//! * E5 — integer layer norm with vs without the `s' = 2^-10` factor
//!   (quality collapse without it);
//! * E6 — the §3.1.1 accumulator safe-depth table;
//! * batching-policy sweep on the serving stack;
//! * dense vs block-sparse serving sweep at 50/75/90% sparsity
//!   (tokens/s, effective-FLOP speedup, retained bits/char), with the
//!   computed effective-FLOP column cross-checked against measured
//!   MACs from the kernel counters (divergence >1% is flagged);
//! * int8 vs int4 measured-MAC attribution by format.
//!
//! Run: `cargo bench --bench ablations`.

use std::time::Duration;

use iqrnn::coordinator::{BatchPolicy, SchedulerMode, Server, ServerConfig};
use iqrnn::lstm::{
    FloatLstm, FloatState, IntegerState, LstmSpec, LstmWeights, QuantizeOptions,
    StackEngine, StackWeights,
};
use iqrnn::lstm::quantize_lstm;
use iqrnn::lstm::CalibrationStats;
use iqrnn::model::lm::{CharLm, VOCAB};
use iqrnn::quant::overflow::safe_accumulation_depth;
use iqrnn::quant::recipe::{Gate, LstmRecipe, TensorRole, VariantFlags};
use iqrnn::tensor::Matrix;
use iqrnn::util::Pcg32;
use iqrnn::workload::synth::RequestTrace;

fn recipe_table() {
    println!("== E2: Table 2 — the quantization recipe (bits per tensor) ==\n");
    let variants = VariantFlags::all_eight();
    print!("{:<10}", "tensor");
    for v in &variants {
        print!("{:>10}", v.label());
    }
    println!();
    let roles: Vec<(String, TensorRole)> = {
        let mut r: Vec<(String, TensorRole)> = vec![
            ("x".into(), TensorRole::Input),
            ("W_i".into(), TensorRole::InputWeight(Gate::Input)),
            ("R_i".into(), TensorRole::RecurrentWeight(Gate::Input)),
            ("P_i".into(), TensorRole::Peephole(Gate::Input)),
            ("b_i".into(), TensorRole::Bias(Gate::Input)),
            ("W_proj".into(), TensorRole::ProjectionWeight),
            ("b_proj".into(), TensorRole::ProjectionBias),
            ("h".into(), TensorRole::Output),
            ("c".into(), TensorRole::CellState),
            ("L_i".into(), TensorRole::LayerNormWeight(Gate::Input)),
            ("g_i".into(), TensorRole::GateOutput(Gate::Input)),
            ("m".into(), TensorRole::Hidden),
        ];
        r.drain(..).collect()
    };
    for (name, role) in roles {
        print!("{name:<10}");
        for v in &variants {
            let e = LstmRecipe::new(*v).entry(role);
            if e.exists() {
                print!("{:>10}", e.bits);
            } else {
                print!("{:>10}", "—");
            }
        }
        println!();
    }
    // Size arithmetic (Table 1 size column driver).
    let plain = LstmRecipe::new(VariantFlags::plain());
    let q = plain.weight_bytes(512, 2048, 2048);
    let f = plain.float_weight_bytes(512, 2048, 2048);
    println!(
        "\nsize check (2048-cell layer): float {:.1}MB -> integer {:.1}MB ({:.2}x; paper: 466->117MB ≈ 3.98x)\n",
        f as f64 / 1e6,
        q as f64 / 1e6,
        f as f64 / q as f64
    );
}

fn layernorm_ablation() {
    println!("== E5: integer layer norm with vs without s' = 2^-10 ==\n");
    let mut rng = Pcg32::seeded(21);
    let spec = LstmSpec::plain(24, 48).with_layer_norm();
    let weights = LstmWeights::random(spec, &mut rng);
    let float = FloatLstm::new(weights.clone());
    let calib: Vec<Vec<Vec<f32>>> = (0..8)
        .map(|_| {
            (0..24)
                .map(|_| (0..24).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect()
        })
        .collect();
    let stats = CalibrationStats::collect(&float, &calib);
    let good = quantize_lstm(&weights, &stats, QuantizeOptions::default());
    let naive = quantize_lstm(
        &weights,
        &stats,
        QuantizeOptions { naive_layernorm: true, ..Default::default() },
    );

    let eval: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..24).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let mut fs = FloatState::zeros(&spec);
    let fo = float.run_sequence(&eval, &mut fs);
    let mut err_good = 0f64;
    let mut err_naive = 0f64;
    let mut n = 0usize;
    let mut gs = IntegerState::zeros(&good);
    let go = good.run_sequence(&eval, &mut gs);
    let mut ns = IntegerState::zeros(&naive);
    let no = naive.run_sequence(&eval, &mut ns);
    for t in 0..eval.len() {
        for j in 0..spec.n_output {
            err_good += f64::from((fo[t][j] - go[t][j]).abs());
            err_naive += f64::from((fo[t][j] - no[t][j]).abs());
            n += 1;
        }
    }
    println!(
        "  mean |float − integer| divergence: with s' = {:.5}, without s' = {:.5} ({:.0}x worse)",
        err_good / n as f64,
        err_naive / n as f64,
        err_naive / err_good.max(1e-12)
    );
    println!(
        "  paper: without the factor, normalized values collapse to ~2.8 bits — \
         \"catastrophic accuracy degradation\".\n"
    );
    assert!(err_naive > 3.0 * err_good);
}

fn overflow_table() {
    println!("== E6: §3.1.1 accumulator safe-depth model ==\n");
    println!("{:>12} {:>12} {:>16}", "input bits", "acc bits", "safe depth");
    for &(ib, ab) in &[(8u32, 32u32), (8, 24), (8, 16 + 1), (16, 48), (4, 24)] {
        println!("{:>12} {:>12} {:>16}", ib, ab, safe_accumulation_depth(ib, ab));
    }
    println!("\npaper: int8→int32 safe to 2^15 = {}; 24-bit acc only 2^7 = {}\n",
             1 << 15, 1 << 7);
    assert_eq!(safe_accumulation_depth(8, 32), 1 << 15);
    assert_eq!(safe_accumulation_depth(8, 24), 1 << 7);
}

fn batching_sweep() {
    println!("== batching policy sweep (integer engine, 2 workers) ==\n");
    let mut rng = Pcg32::seeded(5);
    let spec = LstmSpec::plain(VOCAB, 96);
    let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
    let mut out_w = Matrix::<f32>::zeros(VOCAB, 96);
    rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
    let lm = CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 96, depth: 1 };
    let calib: Vec<Vec<usize>> = (0..8)
        .map(|_| (0..48).map(|_| rng.below(VOCAB as u32) as usize).collect())
        .collect();
    let stats = lm.calibrate(&calib);
    let trace = RequestTrace::generate(120, 2000.0, 40, VOCAB, 6);
    println!(
        "{:>10} {:>12} {:>10} {:>10} {:>10}",
        "max_batch", "tput tok/s", "p50 ms", "p99 ms", "mean batch"
    );
    for &mb in &[1usize, 2, 4, 8, 16] {
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: mb, max_wait: Duration::from_millis(2) },
                engine: StackEngine::Integer,
                opts: QuantizeOptions::default(),
                mode: SchedulerMode::Continuous,
                ..ServerConfig::default()
            },
        );
        let report = server.run_trace(&trace, 50.0).unwrap();
        println!(
            "{:>10} {:>12.0} {:>10.2} {:>10.2} {:>10.2}",
            mb,
            report.throughput(),
            report.latency.percentile(50.0),
            report.latency.percentile(99.0),
            report.mean_batch
        );
    }
    println!();
}

/// Dense vs block-sparse serving at the paper-relevant sparsity
/// levels: prune every weight matrix block-structured (the kernel's
/// MR × K_BLOCK tiles), quantize with block-sparse storage, and report
/// batched throughput, effective-FLOP speedup (dense MACs / surviving
/// MACs), and retained accuracy (bits/char vs the dense model).
///
/// Since PR 10 the effective-FLOP column is cross-checked against the
/// kernel counters: one counted replay of the batched loop measures
/// the MACs the GEMMs actually executed, and any >1% divergence
/// between the computed ratio and the measured one is flagged.
fn sparsity_sweep() {
    use iqrnn::model::lm::nll_bits;
    use iqrnn::sparse::{prune_block_structured, sparsity_of};
    use iqrnn::tensor::kernel_counters;
    use iqrnn::util::timer::bench;

    println!("== dense vs block-sparse serving (integer engine) ==\n");
    let hidden = 64usize;
    let make_lm = |sparsity: f64| {
        let mut rng = Pcg32::seeded(31);
        let spec = LstmSpec::plain(VOCAB, hidden);
        let mut stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, hidden);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        let mut pruned = 0f64;
        let mut mats = 0usize;
        for layer in &mut stack_weights.layers {
            for g in layer.gates.iter_mut().flatten() {
                prune_block_structured(&mut g.w, sparsity);
                prune_block_structured(&mut g.r, sparsity);
                pruned += sparsity_of(&g.w) + sparsity_of(&g.r);
                mats += 2;
            }
        }
        prune_block_structured(&mut out_w, sparsity);
        pruned += sparsity_of(&out_w);
        mats += 1;
        let lm = CharLm {
            stack_weights,
            out_w,
            out_b: vec![0.0; VOCAB],
            hidden,
            depth: 1,
        };
        (lm, pruned / mats as f64)
    };
    let mut rng = Pcg32::seeded(32);
    let calib: Vec<Vec<usize>> = (0..4)
        .map(|_| (0..32).map(|_| rng.below(VOCAB as u32) as usize).collect())
        .collect();
    let eval: Vec<usize> =
        (0..1200).map(|_| rng.below(VOCAB as u32) as usize).collect();
    let batch = 8usize;
    let steps = 48usize;

    println!(
        "{:<10} {:>12} {:>10} {:>10} {:>10} {:>10} {:>11} {:>10}",
        "sparsity", "tok/s (b8)", "vs dense", "eff-FLOP", "meas MMAC", "meas eff", "bits/char", "Δ bpc"
    );
    let mut dense_tps = 0f64;
    let mut dense_bpc = 0f64;
    let mut dense_macs = 0u64;
    for &sparsity in &[0.0f64, 0.5, 0.75, 0.9] {
        let (lm, measured) = make_lm(sparsity);
        let stats = lm.calibrate(&calib);
        let opts = QuantizeOptions {
            sparse_weights: sparsity > 0.0,
            ..Default::default()
        };
        let engine = lm.engine(StackEngine::Integer, Some(&stats), opts);

        // Batched throughput: 8 lanes of synthetic streams.
        let streams: Vec<Vec<usize>> = (0..batch)
            .map(|s| (0..steps).map(|t| (5 * s + 3 * t + 1) % VOCAB).collect())
            .collect();
        let secs = bench(1, 5, || {
            let mut bs = engine.new_batch_state(0);
            for _ in 0..batch {
                let fresh = engine.new_state();
                engine.admit_lane(&fresh, &mut bs);
            }
            for t in 0..steps {
                let toks: Vec<usize> = streams.iter().map(|s| s[t]).collect();
                engine.step_tokens(&toks, &mut bs);
            }
            bs.h.at(0, 0)
        })
        .median_secs();
        let tps = (batch * steps) as f64 / secs;

        // Measured MACs: one counted replay of the same batched loop
        // through the kernel counters. The dense pass records logical
        // dims via the int8 GEMM; sparse passes record executed MACs
        // (stored blocks only) via the BSR kernel.
        kernel_counters::reset();
        {
            let mut bs = engine.new_batch_state(0);
            for _ in 0..batch {
                let fresh = engine.new_state();
                engine.admit_lane(&fresh, &mut bs);
            }
            for t in 0..steps {
                let toks: Vec<usize> = streams.iter().map(|s| s[t]).collect();
                engine.step_tokens(&toks, &mut bs);
            }
        }
        let macs = kernel_counters::take();
        assert!(
            !macs.is_empty(),
            "counted replay recorded no GEMMs — kernel counters broken"
        );

        // Accuracy: next-char bits on a fixed eval stream.
        let mut st = engine.new_state();
        let mut nll = 0f64;
        for (t, &tok) in eval.iter().enumerate() {
            engine.step_token(tok, &mut st);
            if let Some(&next) = eval.get(t + 1) {
                nll += nll_bits(&st.logits, next);
            }
        }
        let bpc = nll / (eval.len() - 1) as f64;
        if sparsity == 0.0 {
            dense_tps = tps;
            dense_bpc = bpc;
            dense_macs = macs.total_macs();
        }
        let eff_flop = if measured < 1.0 { 1.0 / (1.0 - measured) } else { f64::INFINITY };
        let meas_eff = dense_macs as f64 / macs.total_macs() as f64;
        // The computed ratio assumes the kernel skips exactly the
        // pruned tile fraction; the counters say what it actually did.
        let flag = if (meas_eff / eff_flop - 1.0).abs() > 0.01 { " (>1% off computed!)" } else { "" };
        println!(
            "{:<10} {:>12.0} {:>9.2}x {:>9.2}x {:>10.2} {:>9.2}x {:>11.3} {:>+10.3}{flag}",
            format!("{:.0}%", sparsity * 100.0),
            tps,
            tps / dense_tps,
            eff_flop,
            macs.total_macs() as f64 / 1e6,
            meas_eff,
            bpc,
            bpc - dense_bpc
        );
    }
    println!(
        "\n  eff-FLOP = dense MACs / surviving MACs (block-structured, so the \
         kernel skips exactly this fraction);\n  meas MMAC / meas eff = the \
         kernel counters' measured MACs for the same loop and the speedup they \
         imply — divergence >1% from the computed column is flagged;\n  Δ bpc \
         is the accuracy cost of pruning on this random-weight proxy model.\n"
    );
}

/// Int8 vs int4 measured-MAC attribution: the same batched loop run
/// under both weight formats must execute the same *logical* MACs —
/// the counters just attribute them to a different format column.
/// Any >1% divergence between the two totals means a kernel is doing
/// (or counting) work the other is not, and gets flagged loudly.
fn format_attribution() {
    use iqrnn::lstm::WeightBits;
    use iqrnn::tensor::kernel_counters;

    println!("== int8 vs int4: measured MACs by format ==\n");
    let hidden = 64usize;
    let mut rng = Pcg32::seeded(41);
    let spec = LstmSpec::plain(VOCAB, hidden);
    let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
    let mut out_w = Matrix::<f32>::zeros(VOCAB, hidden);
    rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
    let lm = CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden, depth: 1 };
    let calib: Vec<Vec<usize>> = (0..4)
        .map(|_| (0..32).map(|_| rng.below(VOCAB as u32) as usize).collect())
        .collect();
    let stats = lm.calibrate(&calib);
    let batch = 8usize;
    let steps = 48usize;
    let streams: Vec<Vec<usize>> = (0..batch)
        .map(|s| (0..steps).map(|t| (5 * s + 3 * t + 1) % VOCAB).collect())
        .collect();

    println!(
        "{:<8} {:>10} {:>12} {:>10} {:>12} {:>12}",
        "format", "gemm i8", "MMAC i8", "gemm i4", "MMAC i4", "total MMAC"
    );
    let mut totals = Vec::new();
    for (label, bits) in [("int8", WeightBits::Int8), ("int4", WeightBits::Int4)] {
        let opts = QuantizeOptions { weight_bits: bits, ..Default::default() };
        let engine = lm.engine(StackEngine::Integer, Some(&stats), opts);
        kernel_counters::reset();
        let mut bs = engine.new_batch_state(0);
        for _ in 0..batch {
            let fresh = engine.new_state();
            engine.admit_lane(&fresh, &mut bs);
        }
        for t in 0..steps {
            let toks: Vec<usize> = streams.iter().map(|s| s[t]).collect();
            engine.step_tokens(&toks, &mut bs);
        }
        let macs = kernel_counters::take();
        println!(
            "{:<8} {:>10} {:>12.2} {:>10} {:>12.2} {:>12.2}",
            label,
            macs.gemm_i8,
            macs.macs_i8 as f64 / 1e6,
            macs.gemm_i4,
            macs.macs_i4 as f64 / 1e6,
            macs.total_macs() as f64 / 1e6
        );
        totals.push(macs);
    }
    let (i8_run, i4_run) = (&totals[0], &totals[1]);
    assert_eq!(i8_run.macs_i4, 0, "int8 run must not touch the int4 kernel");
    assert!(i4_run.gemm_i4 > 0, "int4 run never hit the int4 kernel");
    let ratio = i4_run.total_macs() as f64 / i8_run.total_macs() as f64;
    let flag = if (ratio - 1.0).abs() > 0.01 { "  <-- >1% DIVERGENCE" } else { "" };
    println!(
        "\n  int4/int8 logical-MAC ratio: {ratio:.4} (same schedule, so 1.0000 expected){flag}\n"
    );
}

fn main() {
    recipe_table();
    layernorm_ablation();
    overflow_table();
    batching_sweep();
    sparsity_sweep();
    format_attribution();
    println!("ablations OK");
}
