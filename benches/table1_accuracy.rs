//! E1 — Table 1: float vs hybrid vs integer quality and model size
//! across model variants (LSTM, 50%-sparse LSTM, 50%-sparse CIFG) and
//! eval sets (Short/Long/Noisy — the VoiceSearch/YouTube/Telephony
//! analogs).
//!
//! Paper's shape to reproduce: quantization preserves quality within a
//! small delta of each variant's float baseline (including on long
//! streams), at ~4x smaller size; sparse variants trade quality for
//! another ~2x. Run: `cargo bench --bench table1_accuracy`.

use iqrnn::lstm::{LstmWeights, QuantizeOptions, StackEngine};
use iqrnn::model::lm::CharLm;
use iqrnn::sparse::prune_magnitude;
use iqrnn::workload::corpus::{calibration_sequences, load_eval_sets};

/// Derive a model variant from the trained master weights.
fn variant(lm: &CharLm, sparsity: f64, cifg: bool) -> CharLm {
    let mut layers: Vec<LstmWeights> = lm.stack_weights.layers.clone();
    for layer in &mut layers {
        if cifg {
            layer.gates[0] = None;
            layer.spec.flags.cifg = true;
        }
        if sparsity > 0.0 {
            for g in layer.gates.iter_mut().flatten() {
                prune_magnitude(&mut g.w, sparsity);
                prune_magnitude(&mut g.r, sparsity);
            }
        }
    }
    CharLm {
        stack_weights: iqrnn::lstm::StackWeights { layers },
        out_w: lm.out_w.clone(),
        out_b: lm.out_b.clone(),
        hidden: lm.hidden,
        depth: lm.depth,
    }
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("IQRNN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let master = CharLm::load(&artifacts)?;
    let corpus = std::path::Path::new(&artifacts).join("corpus.txt");
    let calib = calibration_sequences(&corpus, 100, 64, 11)?;
    let sets = load_eval_sets(&corpus, 8, 128, 2, 1500, 0.05, 21)?;

    println!("== Table 1 analog: quality (bits/char) and size by engine ==\n");
    println!(
        "{:<14} {:<8} {:>9} | {:>8} {:>8} {:>8}",
        "model", "engine", "size", "Short", "Long", "Noisy"
    );

    let rows: [(&str, f64, bool); 3] = [
        ("LSTM 0%", 0.0, false),
        ("Sparse LSTM", 0.5, false),
        ("Sparse CIFG", 0.5, true),
    ];
    for (name, sparsity, cifg) in rows {
        let lm = variant(&master, sparsity, cifg);
        let stats = lm.calibrate(&calib);
        for engine in StackEngine::ALL {
            let opts = QuantizeOptions {
                sparse_weights: sparsity > 0.0 && engine == StackEngine::Integer,
                ..Default::default()
            };
            let e = lm.engine(engine, Some(&stats), opts);
            let size_mb = e.weight_bytes() as f64 / 1e6;
            let mut bpc = Vec::new();
            for set in &sets {
                let v: f64 = set.sequences.iter().map(|s| e.bits_per_char(s)).sum::<f64>()
                    / set.sequences.len() as f64;
                bpc.push(v);
            }
            println!(
                "{:<14} {:<8} {:>7.2}MB | {:>8.4} {:>8.4} {:>8.4}",
                if engine == StackEngine::Float { name } else { "" },
                e.engine_label(),
                size_mb,
                bpc[0],
                bpc[1],
                bpc[2]
            );
        }
        println!();
    }
    println!(
        "paper shape: integer ≈ float quality per variant (Δ small even on Long); \
         integer size ≈ 1/4 float; CIFG ≈ 3/4 of LSTM."
    );
    Ok(())
}
