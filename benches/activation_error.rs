//! E3 — §3.2.1 figure: clamping vs resolution error across the
//! `Q_{m.15-m}` input formats for sigmoid and tanh.
//!
//! Prints the analytical error model (the paper's trade-off) alongside
//! the *measured* max error of the integer implementation, and verifies
//! `Q3.12` is the argmin for tanh. Run:
//! `cargo bench --bench activation_error`.

use iqrnn::nonlin::error::{
    clamping_error, measured_max_error_lsb, optimal_integer_bits, resolution_error,
    total_error, Activation,
};

fn main() {
    for act in [Activation::Tanh, Activation::Sigmoid] {
        println!("== {act:?}: error vs input format Q_m.(15-m) ==");
        println!(
            "{:>6} {:>14} {:>14} {:>14} {:>16}",
            "m", "clamping", "resolution", "total(model)", "measured(LSB)"
        );
        for m in 0..=8u32 {
            println!(
                "{:>6} {:>14.3e} {:>14.3e} {:>14.3e} {:>16.2}",
                format!("Q{m}.{}", 15 - m),
                clamping_error(act, m),
                resolution_error(act, m),
                total_error(act, m),
                measured_max_error_lsb(act, m),
            );
        }
        let best = optimal_integer_bits(act);
        println!("model argmin: m = {best}\n");
    }
    assert_eq!(optimal_integer_bits(Activation::Tanh), 3);
    println!(
        "paper: Q3.12 has the lowest combined error for the gate \
         activations — reproduced (tanh argmin = 3; sigmoid minimum is \
         shallow at 3-4 and the shared gate format picks Q3.12)."
    );
    // Paper's example numbers.
    println!(
        "\npaper examples: 1 - tanh(8) = {:.3e} (paper: 2.35e-7); \
         tanh resolution at Q3.12 = {:.3e} (paper: 2.44e-4)",
        clamping_error(Activation::Tanh, 3),
        resolution_error(Activation::Tanh, 3)
    );
}
