//! Hot-path microbenchmarks: per-component cost of the integer cell —
//! the profile that drives the §Perf optimization log in
//! EXPERIMENTS.md. Run: `cargo bench --bench cell_microbench`.

use iqrnn::fixedpoint::Rescale;
use iqrnn::lstm::{
    CalibrationStats, FloatLstm, FloatState, IntegerState, LstmSpec, LstmWeights,
    QuantizeOptions,
};
use iqrnn::lstm::quantize_lstm;
use iqrnn::nonlin::{sigmoid_q15_slice, tanh_q15_slice};
use iqrnn::sparse::SparseMatrixI8;
use iqrnn::tensor::qmatmul::matvec_i8_i32;
use iqrnn::tensor::{matvec_f32, Matrix};
use iqrnn::util::timer::{bench, fmt_secs};
use iqrnn::util::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(12);
    let n = 512usize;

    println!("== matvec kernels ({n}x{n}) ==");
    let mut wf = Matrix::<f32>::zeros(n, n);
    rng.fill_uniform_f32(&mut wf.data, -0.1, 0.1);
    let xf: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut of = vec![0f32; n];
    let t = bench(3, 51, || {
        matvec_f32(&wf, &xf, &mut of);
        of[0]
    })
    .median_secs();
    println!("  f32 matvec        {}", fmt_secs(t));

    let mut wq = Matrix::<i8>::zeros(n, n);
    for v in &mut wq.data {
        *v = rng.range_i32(-127, 127) as i8;
    }
    let xq: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
    let bias = vec![0i32; n];
    let mut oq = vec![0i32; n];
    let t_i8 = bench(3, 51, || {
        matvec_i8_i32(&wq, &xq, &bias, &mut oq);
        oq[0]
    })
    .median_secs();
    println!("  i8 matvec         {}  ({:.2}x vs f32)", fmt_secs(t_i8), t / t_i8);

    // 50% sparse CSR.
    let mut ws = wq.clone();
    for v in ws.data.iter_mut() {
        if rng.next_f64() < 0.5 {
            *v = 0;
        }
    }
    let sp = SparseMatrixI8::from_dense(&ws);
    let t_sp = bench(3, 51, || {
        sp.matvec_i32(&xq, &bias, &mut oq);
        oq[0]
    })
    .median_secs();
    println!(
        "  i8 CSR 50% matvec {}  ({:.2}x vs dense i8, nnz={})",
        fmt_secs(t_sp),
        t_i8 / t_sp,
        sp.nnz()
    );

    println!("\n== elementwise pipeline (len {n}) ==");
    let xin: Vec<i16> = (0..n).map(|_| rng.range_i32(-32768, 32767) as i16).collect();
    let mut out16 = vec![0i16; n];
    let t_sig = bench(3, 101, || {
        sigmoid_q15_slice(&xin, 3, &mut out16);
        out16[0]
    })
    .median_secs();
    let t_tanh = bench(3, 101, || {
        tanh_q15_slice(&xin, 3, &mut out16);
        out16[0]
    })
    .median_secs();
    println!("  sigmoid_q15       {} ({:.1} ns/elem)", fmt_secs(t_sig), t_sig / n as f64 * 1e9);
    println!("  tanh_q15          {} ({:.1} ns/elem)", fmt_secs(t_tanh), t_tanh / n as f64 * 1e9);

    let acc: Vec<i32> = (0..n).map(|_| rng.range_i32(-1 << 20, 1 << 20)).collect();
    let r = Rescale::from_scale(3.1e-4);
    let mut out32 = vec![0i32; n];
    let t_rescale = bench(3, 201, || {
        for (o, &a) in out32.iter_mut().zip(&acc) {
            *o = r.apply(a);
        }
        out32[0]
    })
    .median_secs();
    println!("  rescale           {} ({:.1} ns/elem)", fmt_secs(t_rescale), t_rescale / n as f64 * 1e9);

    println!("\n== full cell step (float vs integer) ==");
    for &(n_input, n_cell) in &[(64usize, 128usize), (128, 256), (256, 512)] {
        let spec = LstmSpec::plain(n_input, n_cell);
        let weights = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(weights.clone());
        let calib: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|_| {
                (0..8)
                    .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect();
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&weights, &stats, QuantizeOptions::default());
        let x: Vec<f32> = (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let qx: Vec<i8> = x.iter().map(|&v| integer.input_q.quantize(f64::from(v))).collect();

        let mut fs = FloatState::zeros(&spec);
        let t_f = bench(3, 31, || {
            float.step(&x, &mut fs);
            fs.h[0]
        })
        .median_secs();
        let mut is = IntegerState::zeros(&integer);
        let t_i = bench(3, 31, || {
            integer.step_q(&qx, &mut is);
            is.h[0]
        })
        .median_secs();
        println!(
            "  {n_input:>4}x{n_cell:<4} float {} integer {} ({:.2}x)",
            fmt_secs(t_f),
            fmt_secs(t_i),
            t_f / t_i
        );
    }
}
