//! Hot-path microbenchmarks: per-component cost of the integer cell —
//! the profile that drives the §Perf optimization log in
//! EXPERIMENTS.md. Run: `cargo bench --bench cell_microbench`.

use iqrnn::fixedpoint::Rescale;
use iqrnn::lstm::{
    CalibrationStats, FloatLstm, FloatState, IntegerBatchState, IntegerState,
    LstmSpec, LstmWeights, QuantizeOptions,
};
use iqrnn::lstm::quantize_lstm;
use iqrnn::nonlin::{sigmoid_q15_slice, tanh_q15_slice};
use iqrnn::sparse::SparseMatrixI8;
use iqrnn::tensor::qmatmul::{gemm_i8_i32, matvec_i8_i32, PackedWeightsI8};
use iqrnn::tensor::{matvec_f32, Matrix};
use iqrnn::util::timer::{bench, fmt_secs};
use iqrnn::util::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(12);
    let n = 512usize;

    println!("== matvec kernels ({n}x{n}) ==");
    let mut wf = Matrix::<f32>::zeros(n, n);
    rng.fill_uniform_f32(&mut wf.data, -0.1, 0.1);
    let xf: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut of = vec![0f32; n];
    let t = bench(3, 51, || {
        matvec_f32(&wf, &xf, &mut of);
        of[0]
    })
    .median_secs();
    println!("  f32 matvec        {}", fmt_secs(t));

    let mut wq = Matrix::<i8>::zeros(n, n);
    for v in &mut wq.data {
        *v = rng.range_i32(-127, 127) as i8;
    }
    let xq: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
    let bias = vec![0i32; n];
    let mut oq = vec![0i32; n];
    let t_i8 = bench(3, 51, || {
        matvec_i8_i32(&wq, &xq, &bias, &mut oq);
        oq[0]
    })
    .median_secs();
    println!("  i8 matvec         {}  ({:.2}x vs f32)", fmt_secs(t_i8), t / t_i8);

    // 50% sparse CSR.
    let mut ws = wq.clone();
    for v in ws.data.iter_mut() {
        if rng.next_f64() < 0.5 {
            *v = 0;
        }
    }
    let sp = SparseMatrixI8::from_dense(&ws);
    let t_sp = bench(3, 51, || {
        sp.matvec_i32(&xq, &bias, &mut oq);
        oq[0]
    })
    .median_secs();
    println!(
        "  i8 CSR 50% matvec {}  ({:.2}x vs dense i8, nnz={})",
        fmt_secs(t_sp),
        t_i8 / t_sp,
        sp.nnz()
    );

    // Batch-major GEMM vs per-lane matvec: the amortization that the
    // batch-major refactor rides on.
    println!("\n== i8 GEMM vs per-lane matvec ({n}x{n}) ==");
    for &batch in &[1usize, 4, 8, 16, 32] {
        let mut xb = Matrix::<i8>::zeros(batch, n);
        for v in &mut xb.data {
            *v = rng.range_i32(-128, 127) as i8;
        }
        let mut ob = Matrix::<i32>::zeros(batch, n);
        let t_gemm = bench(3, 31, || {
            gemm_i8_i32(&wq, &xb, &bias, &mut ob);
            ob.at(0, 0)
        })
        .median_secs();
        let t_lanes = bench(3, 31, || {
            for b in 0..batch {
                let or = &mut ob.data[b * n..(b + 1) * n];
                matvec_i8_i32(&wq, &xb.data[b * n..(b + 1) * n], &bias, or);
            }
            ob.at(0, 0)
        })
        .median_secs();
        println!(
            "  batch {batch:>2}: gemm {} per-lane {} ({:.2}x, {:.1} ns/row-token)",
            fmt_secs(t_gemm),
            fmt_secs(t_lanes),
            t_lanes / t_gemm,
            t_gemm / batch as f64 * 1e9
        );
    }

    // Packed panel kernel vs the unpacked blocked kernel, on the ragged
    // shapes continuous batching actually produces (odd live widths,
    // n_cell off the 32-byte grid) — where the unpacked kernel decays
    // into scalar tails and the packed one doesn't.
    println!("\n== packed panel GEMM vs unpacked blocked GEMM ==");
    for &(rows, cols) in &[(512usize, 512usize), (513, 511), (192, 200)] {
        let mut wr = Matrix::<i8>::zeros(rows, cols);
        for v in &mut wr.data {
            *v = rng.range_i32(-127, 127) as i8;
        }
        let packed = PackedWeightsI8::pack(wr.clone());
        let biasr = vec![0i32; rows];
        for &batch in &[1usize, 3, 5, 7, 8] {
            let mut xb = Matrix::<i8>::zeros(batch, cols);
            for v in &mut xb.data {
                *v = rng.range_i32(-128, 127) as i8;
            }
            let mut ob = Matrix::<i32>::zeros(batch, rows);
            let t_packed = bench(3, 31, || {
                packed.gemm(&xb, &biasr, &mut ob);
                ob.at(0, 0)
            })
            .median_secs();
            let t_unpacked = bench(3, 31, || {
                gemm_i8_i32(&wr, &xb, &biasr, &mut ob);
                ob.at(0, 0)
            })
            .median_secs();
            println!(
                "  {rows}x{cols} batch {batch}: packed {} unpacked {} ({:.2}x)",
                fmt_secs(t_packed),
                fmt_secs(t_unpacked),
                t_unpacked / t_packed
            );
        }
    }

    println!("\n== elementwise pipeline (len {n}) ==");
    let xin: Vec<i16> = (0..n).map(|_| rng.range_i32(-32768, 32767) as i16).collect();
    let mut out16 = vec![0i16; n];
    let t_sig = bench(3, 101, || {
        sigmoid_q15_slice(&xin, 3, &mut out16);
        out16[0]
    })
    .median_secs();
    let t_tanh = bench(3, 101, || {
        tanh_q15_slice(&xin, 3, &mut out16);
        out16[0]
    })
    .median_secs();
    println!("  sigmoid_q15       {} ({:.1} ns/elem)", fmt_secs(t_sig), t_sig / n as f64 * 1e9);
    println!("  tanh_q15          {} ({:.1} ns/elem)", fmt_secs(t_tanh), t_tanh / n as f64 * 1e9);

    let acc: Vec<i32> = (0..n).map(|_| rng.range_i32(-1 << 20, 1 << 20)).collect();
    let r = Rescale::from_scale(3.1e-4);
    let mut out32 = vec![0i32; n];
    let t_rescale = bench(3, 201, || {
        for (o, &a) in out32.iter_mut().zip(&acc) {
            *o = r.apply(a);
        }
        out32[0]
    })
    .median_secs();
    println!("  rescale           {} ({:.1} ns/elem)", fmt_secs(t_rescale), t_rescale / n as f64 * 1e9);

    println!("\n== full cell step (float vs integer) ==");
    for &(n_input, n_cell) in &[(64usize, 128usize), (128, 256), (256, 512)] {
        let spec = LstmSpec::plain(n_input, n_cell);
        let weights = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(weights.clone());
        let calib: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|_| {
                (0..8)
                    .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect();
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&weights, &stats, QuantizeOptions::default());
        let x: Vec<f32> = (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let qx: Vec<i8> = x.iter().map(|&v| integer.input_q.quantize(f64::from(v))).collect();

        let mut fs = FloatState::zeros(&spec);
        let t_f = bench(3, 31, || {
            float.step(&x, &mut fs);
            fs.h[0]
        })
        .median_secs();
        let mut is = IntegerState::zeros(&integer);
        let t_i = bench(3, 31, || {
            integer.step_q(&qx, &mut is);
            is.h[0]
        })
        .median_secs();
        println!(
            "  {n_input:>4}x{n_cell:<4} float {} integer {} ({:.2}x)",
            fmt_secs(t_f),
            fmt_secs(t_i),
            t_f / t_i
        );
    }

    // Batched integer cell: per-token cost of step_batch_q vs repeated
    // step_q at growing batch sizes.
    println!("\n== integer cell step_batch_q (per-token cost) ==");
    {
        let (n_input, n_cell) = (128usize, 256usize);
        let spec = LstmSpec::plain(n_input, n_cell);
        let weights = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(weights.clone());
        let calib: Vec<Vec<Vec<f32>>> = (0..2)
            .map(|_| {
                (0..8)
                    .map(|_| (0..n_input).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect();
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&weights, &stats, QuantizeOptions::default());
        for &batch in &[1usize, 4, 8, 16, 32] {
            let mut qx = Matrix::<i8>::zeros(batch, n_input);
            for v in &mut qx.data {
                *v = rng.range_i32(-128, 127) as i8;
            }
            let mut bstate = IntegerBatchState::zeros(&integer, batch);
            let t_batch = bench(2, 15, || {
                integer.step_batch_q(&qx, &mut bstate);
                bstate.h.at(0, 0)
            })
            .median_secs();
            let mut states: Vec<IntegerState> =
                (0..batch).map(|_| IntegerState::zeros(&integer)).collect();
            let t_seq = bench(2, 15, || {
                for (b, st) in states.iter_mut().enumerate() {
                    integer.step_q(qx.row(b), st);
                }
                states[0].h[0]
            })
            .median_secs();
            println!(
                "  batch {batch:>2}: batched {} sequential {} ({:.2}x, {:.1} us/token)",
                fmt_secs(t_batch),
                fmt_secs(t_seq),
                t_seq / t_batch,
                t_batch / batch as f64 * 1e6
            );
        }
    }
}
