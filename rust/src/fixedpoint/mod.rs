//! Fixed-point arithmetic primitives for integer-only inference.
//!
//! This module implements the `Q_{m.n}` number format of §3.1.2 of the
//! paper and the saturating integer arithmetic every integer kernel in
//! the library is built from:
//!
//! * [`mul`] — saturating rounding doubling high multiply (the core
//!   "multiply two fixed-point numbers" primitive) and rounding
//!   power-of-two shifts,
//! * [`q`] — the `Q_{m.n}` format helpers (ranges, resolution,
//!   power-of-two extension of measured ranges per §3.2.2),
//! * [`rescale`] — precomputed effective-scale rescaling (int32
//!   multiplier + shift), the mechanism that moves values between the
//!   int32 accumulator domain and each tensor's quantized domain with
//!   *no* floating point at inference time (floats appear only at
//!   quantization/calibration time, when the multipliers are derived).
//!
//! The arithmetic follows the widely deployed gemmlowp/TFLite fixed-point
//! semantics, which is also what the paper's production implementation
//! (TensorFlow Lite integer LSTM) uses.

pub mod mul;
pub mod q;
pub mod rescale;

pub use mul::{
    rounding_divide_by_pot, saturating_rounding_doubling_high_mul,
    saturating_rounding_multiply_by_pot,
};
pub use q::QFormat;
pub use rescale::{
    multiply_by_quantized_multiplier, quantize_multiplier, Rescale,
};
