//! Core saturating rounding integer multiply/shift primitives.
//!
//! These are the three operations out of which every fixed-point
//! computation in the library is composed. Semantics match gemmlowp's
//! `fixedpoint.h` (and therefore TFLite's reference kernels), which is
//! the de-facto specification for the integer LSTM the paper describes.

/// Saturating rounding doubling high multiply.
///
/// Returns the high 32 bits of `2 * a * b`, rounded to nearest. This is
/// the product of two fixed-point numbers with 31 fractional bits in a
/// 32-bit register (ARM's `SQRDMULH`). The only overflow case,
/// `a == b == i32::MIN`, saturates to `i32::MAX`.
#[inline]
pub fn saturating_rounding_doubling_high_mul(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX;
    }
    let ab = i64::from(a) * i64::from(b);
    let nudge: i64 = if ab >= 0 { 1 << 30 } else { 1 - (1 << 30) };
    // Truncating division (not an arithmetic shift): rounds to nearest,
    // ties away from zero — matches gemmlowp/ARM SQRDMULH exactly.
    ((ab + nudge) / (1i64 << 31)) as i32
}

/// Rounding (to nearest, ties away from zero) arithmetic right shift.
#[inline]
pub fn rounding_divide_by_pot(x: i32, exponent: i32) -> i32 {
    debug_assert!((0..=31).contains(&exponent));
    if exponent == 0 {
        return x;
    }
    // Mask of the low `exponent` bits, computed in unsigned space: the
    // signed form `(1 << 31) - 1` would overflow at the boundary
    // exponent 31 (reachable via `Rescale` shifts of -31).
    let mask: i32 = ((1u32 << exponent) - 1) as i32;
    let remainder = x & mask;
    let threshold = (mask >> 1) + i32::from(x < 0);
    (x >> exponent) + i32::from(remainder > threshold)
}

/// Rounding right shift for 64-bit accumulators (layer norm, bias adds).
#[inline]
pub fn rounding_divide_by_pot_i64(x: i64, exponent: i32) -> i64 {
    debug_assert!((0..=63).contains(&exponent));
    if exponent == 0 {
        return x;
    }
    // Unsigned-space mask — see `rounding_divide_by_pot`.
    let mask: i64 = ((1u64 << exponent) - 1) as i64;
    let remainder = x & mask;
    let threshold = (mask >> 1) + i64::from(x < 0);
    (x >> exponent) + i64::from(remainder > threshold)
}

/// Multiply by a power of two with saturation.
///
/// `exponent > 0` is a saturating left shift; `exponent < 0` is a
/// rounding right shift; `exponent == 0` is the identity.
#[inline]
pub fn saturating_rounding_multiply_by_pot(x: i32, exponent: i32) -> i32 {
    if exponent == 0 {
        x
    } else if exponent < 0 {
        rounding_divide_by_pot(x, -exponent)
    } else {
        debug_assert!(exponent <= 31);
        let min = i32::MIN >> exponent;
        let max = i32::MAX >> exponent;
        if x > max {
            i32::MAX
        } else if x < min {
            i32::MIN
        } else {
            x << exponent
        }
    }
}

/// Rounding half-sum `(a + b) / 2`, exact in 64-bit intermediate.
#[inline]
pub fn rounding_half_sum(a: i32, b: i32) -> i32 {
    let sum = i64::from(a) + i64::from(b);
    // Round to nearest, ties away from zero.
    let sign: i64 = if sum >= 0 { 1 } else { -1 };
    ((sum + sign) / 2) as i32
}

/// Saturating cast of an i64 accumulator to i32.
#[inline]
pub fn saturate_i64_to_i32(x: i64) -> i32 {
    x.clamp(i64::from(i32::MIN), i64::from(i32::MAX)) as i32
}

/// Saturating cast of an i32 to i16 (the ubiquitous "store as int16").
#[inline]
pub fn saturate_i32_to_i16(x: i32) -> i16 {
    x.clamp(i32::from(i16::MIN), i32::from(i16::MAX)) as i16
}

/// Saturating cast of an i32 to i8.
#[inline]
pub fn saturate_i32_to_i8(x: i32) -> i8 {
    x.clamp(i32::from(i8::MIN), i32::from(i8::MAX)) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srdhm_matches_double_reference() {
        let cases: [(i32, i32); 8] = [
            (1 << 30, 1 << 30),
            (1 << 30, -(1 << 30)),
            (123456789, 987654321),
            (-123456789, 987654321),
            (i32::MAX, i32::MAX),
            (i32::MIN + 1, i32::MAX),
            (0, i32::MAX),
            (3, 3),
        ];
        for (a, b) in cases {
            let got = saturating_rounding_doubling_high_mul(a, b);
            let want = ((2.0 * a as f64 * b as f64) / 2f64.powi(32)).round();
            assert!(
                (f64::from(got) - want).abs() <= 1.0,
                "srdhm({a},{b}) = {got}, want ~{want}"
            );
        }
    }

    #[test]
    fn srdhm_saturates_min_min() {
        assert_eq!(
            saturating_rounding_doubling_high_mul(i32::MIN, i32::MIN),
            i32::MAX
        );
    }

    #[test]
    fn rdbp_rounds_to_nearest() {
        assert_eq!(rounding_divide_by_pot(5, 1), 3); // 2.5 -> 3 (away from zero)
        assert_eq!(rounding_divide_by_pot(-5, 1), -3);
        assert_eq!(rounding_divide_by_pot(4, 1), 2);
        assert_eq!(rounding_divide_by_pot(7, 2), 2); // 1.75 -> 2
        assert_eq!(rounding_divide_by_pot(-7, 2), -2);
        assert_eq!(rounding_divide_by_pot(1024, 10), 1);
        assert_eq!(rounding_divide_by_pot(1535, 10), 1); // 1.499 -> 1
        assert_eq!(rounding_divide_by_pot(1536, 10), 2); // 1.5 -> 2
    }

    #[test]
    fn rdbp_i64_agrees_with_i32() {
        for &x in &[-1_000_000i32, -5, -4, -1, 0, 1, 4, 5, 1_000_000] {
            for e in 0..16 {
                assert_eq!(
                    i64::from(rounding_divide_by_pot(x, e)),
                    rounding_divide_by_pot_i64(i64::from(x), e),
                    "x={x} e={e}"
                );
            }
        }
    }

    #[test]
    fn srmbp_left_shift_saturates() {
        assert_eq!(saturating_rounding_multiply_by_pot(1 << 30, 2), i32::MAX);
        assert_eq!(
            saturating_rounding_multiply_by_pot(-(1 << 30), 2),
            i32::MIN
        );
        assert_eq!(saturating_rounding_multiply_by_pot(3, 4), 48);
        assert_eq!(saturating_rounding_multiply_by_pot(3, 0), 3);
        assert_eq!(saturating_rounding_multiply_by_pot(48, -4), 3);
    }

    #[test]
    fn half_sum_rounds_away_from_zero() {
        assert_eq!(rounding_half_sum(3, 4), 4); // 3.5 -> 4
        assert_eq!(rounding_half_sum(-3, -4), -4);
        assert_eq!(rounding_half_sum(i32::MAX, i32::MAX), i32::MAX);
        assert_eq!(rounding_half_sum(i32::MIN, i32::MIN), i32::MIN);
        assert_eq!(rounding_half_sum(0, 0), 0);
    }

    #[test]
    fn saturating_casts() {
        assert_eq!(saturate_i32_to_i16(40000), i16::MAX);
        assert_eq!(saturate_i32_to_i16(-40000), i16::MIN);
        assert_eq!(saturate_i32_to_i16(123), 123);
        assert_eq!(saturate_i32_to_i8(300), i8::MAX);
        assert_eq!(saturate_i32_to_i8(-300), i8::MIN);
        assert_eq!(saturate_i64_to_i32(1 << 40), i32::MAX);
        assert_eq!(saturate_i64_to_i32(-(1 << 40)), i32::MIN);
    }
}
