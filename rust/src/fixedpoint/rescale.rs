//! Effective-scale rescaling: the only place floating point touches the
//! pipeline, and it happens *offline* (at quantization time).
//!
//! The paper's integer execution repeatedly rescales int32 accumulators
//! into a target quantized domain with an *effective scale* such as
//! `s_effx = 2^12 * s_W * s_x` (§3.2.4). At build time each effective
//! scale is decomposed into a normalized int32 multiplier in
//! `[2^30, 2^31)` and a power-of-two shift; at inference time the
//! rescale is one saturating rounding doubling high multiply plus one
//! rounding shift — no floats, no division, no lookup table.

use super::mul::{
    rounding_divide_by_pot, saturating_rounding_doubling_high_mul,
};

/// A precomputed fixed-point rescale: `x -> x * multiplier * 2^shift`
/// with `multiplier` normalized into `[2^30, 2^31)` (or 0 for scale 0).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rescale {
    pub multiplier: i32,
    /// Left shift if positive, right shift if negative.
    pub shift: i32,
}

impl Rescale {
    /// Identity rescale (scale 1.0).
    pub const IDENTITY: Rescale = Rescale { multiplier: 1 << 30, shift: 1 };

    /// Decompose a real effective scale into (multiplier, shift).
    pub fn from_scale(scale: f64) -> Self {
        let (multiplier, shift) = quantize_multiplier(scale);
        Rescale { multiplier, shift }
    }

    /// Apply the rescale to an int32 accumulator value.
    #[inline]
    pub fn apply(&self, x: i32) -> i32 {
        multiply_by_quantized_multiplier(x, self.multiplier, self.shift)
    }

    /// The real scale this rescale approximates (for tests/debugging).
    pub fn to_scale(&self) -> f64 {
        f64::from(self.multiplier) / 2f64.powi(31) * 2f64.powi(self.shift)
    }
}

/// Decompose `scale` into a normalized int32 multiplier and shift such
/// that `scale ≈ multiplier / 2^31 * 2^shift` with
/// `multiplier ∈ [2^30, 2^31)`.
///
/// Matches TFLite's `QuantizeMultiplier`.
pub fn quantize_multiplier(scale: f64) -> (i32, i32) {
    assert!(scale.is_finite() && scale >= 0.0, "scale must be >= 0, got {scale}");
    if scale == 0.0 {
        return (0, 0);
    }
    let (mut q, mut shift) = {
        // frexp: scale = q * 2^shift with q in [0.5, 1).
        let shift = scale.log2().floor() as i32 + 1;
        let q = scale / 2f64.powi(shift);
        (q, shift)
    };
    let mut q_fixed = (q * 2f64.powi(31)).round() as i64;
    debug_assert!(q_fixed <= 1i64 << 31);
    if q_fixed == 1i64 << 31 {
        q /= 2.0;
        let _ = q;
        q_fixed /= 2;
        shift += 1;
    }
    if shift < -31 {
        // Underflow: the scale is so small every output rounds to zero.
        return (0, 0);
    }
    if shift > 30 {
        // Saturate enormous scales (should not occur for sane models).
        return (i32::MAX, 30);
    }
    (q_fixed as i32, shift)
}

/// Apply a quantized multiplier: `x * multiplier * 2^shift`, rounding,
/// saturating. Matches TFLite's `MultiplyByQuantizedMultiplier`.
#[inline]
pub fn multiply_by_quantized_multiplier(x: i32, multiplier: i32, shift: i32) -> i32 {
    let left_shift = if shift > 0 { shift } else { 0 };
    let right_shift = if shift > 0 { 0 } else { -shift };
    // The left shift can overflow for large accumulators with big scales;
    // saturate rather than wrap (the paper's §3.1.1 overflow discipline).
    let shifted = if left_shift == 0 {
        x
    } else if left_shift >= 31 {
        if x > 0 { i32::MAX } else if x < 0 { i32::MIN } else { 0 }
    } else {
        let min = i32::MIN >> left_shift;
        let max = i32::MAX >> left_shift;
        if x > max {
            i32::MAX
        } else if x < min {
            i32::MIN
        } else {
            x << left_shift
        }
    };
    rounding_divide_by_pot(
        saturating_rounding_doubling_high_mul(shifted, multiplier),
        right_shift,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_scale(scale: f64) {
        let r = Rescale::from_scale(scale);
        assert!(
            (r.to_scale() - scale).abs() <= scale * 1e-6,
            "scale {scale} -> {:?} -> {}",
            r,
            r.to_scale()
        );
        if scale > 0.0 {
            assert!(r.multiplier >= 1 << 30, "normalized: {:?}", r);
        }
    }

    #[test]
    fn multiplier_decomposition_roundtrips() {
        for &s in &[
            1.0, 0.5, 0.25, 2.0, 0.0003921568, 1.5e-5, 0.9999, 1.0001,
            3.0517578125e-5, 123.456, 7.62939453125e-6,
        ] {
            check_scale(s);
        }
    }

    #[test]
    fn zero_scale_maps_to_zero() {
        let r = Rescale::from_scale(0.0);
        assert_eq!(r.apply(123456), 0);
        assert_eq!(r.apply(-123456), 0);
    }

    #[test]
    fn apply_matches_float_reference() {
        for &s in &[0.0007, 0.03, 0.5, 1.0, 1.7, 2.5e-4] {
            let r = Rescale::from_scale(s);
            for &x in &[-100_000i32, -1234, -1, 0, 1, 999, 65_535, 1_000_000] {
                let got = r.apply(x);
                let want = (f64::from(x) * s).round();
                assert!(
                    (f64::from(got) - want).abs() <= 1.0,
                    "x={x} s={s} got={got} want={want}"
                );
            }
        }
    }

    #[test]
    fn identity_rescale() {
        for &x in &[-5_000_000, -1, 0, 1, 5_000_000] {
            assert_eq!(Rescale::IDENTITY.apply(x), x);
        }
    }

    #[test]
    fn tiny_scale_underflows_to_zero() {
        let r = Rescale::from_scale(1e-30);
        assert_eq!(r.apply(i32::MAX), 0);
    }

    #[test]
    fn effective_scale_example_from_paper() {
        // s_effx = 2^12 * s_W * s_x for typical int8 scales.
        let s_w = 0.02; // max|W| = 2.54
        let s_x = 4.0 / 255.0;
        let eff = 2f64.powi(12) * s_w * s_x;
        let r = Rescale::from_scale(eff);
        // An accumulator of 1000 should land near 1000 * eff.
        let got = r.apply(1000);
        let want = (1000.0 * eff).round();
        assert!((f64::from(got) - want).abs() <= 1.0);
    }
}
