//! The `Q_{m.n}` signed fixed-point format of §3.1.2.
//!
//! `m` integer bits, `n` fractional bits, `m + n + 1 ==` bit width.
//! A `Q_{m.n}` value represents floats in `[-(2^m), 2^m - 2^-n]` with a
//! resolution of `2^-n`.

/// A `Q_{m.n}` format descriptor for a given storage width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits `m`.
    pub integer_bits: u32,
    /// Fractional bits `n`.
    pub fractional_bits: u32,
}

impl QFormat {
    /// `Q_{m.n}` with a total width of `m + n + 1` bits.
    pub const fn new(integer_bits: u32, fractional_bits: u32) -> Self {
        Self { integer_bits, fractional_bits }
    }

    /// The 16-bit format `Q_{m.15-m}` used for activations (§3.2.1).
    pub const fn q16(integer_bits: u32) -> Self {
        assert!(integer_bits <= 15);
        Self { integer_bits, fractional_bits: 15 - integer_bits }
    }

    /// Total storage width in bits (sign included).
    pub const fn bits(&self) -> u32 {
        self.integer_bits + self.fractional_bits + 1
    }

    /// Scale of one least-significant bit: `2^-n`.
    pub fn resolution(&self) -> f64 {
        2f64.powi(-(self.fractional_bits as i32))
    }

    /// Largest representable value `2^m - 2^-n`.
    pub fn max_value(&self) -> f64 {
        2f64.powi(self.integer_bits as i32) - self.resolution()
    }

    /// Smallest representable value `-(2^m)`.
    pub fn min_value(&self) -> f64 {
        -(2f64.powi(self.integer_bits as i32))
    }

    /// Quantize a float to the raw integer domain, saturating.
    pub fn quantize(&self, v: f64) -> i32 {
        let raw = (v / self.resolution()).round();
        let max = (1i64 << (self.bits() - 1)) - 1;
        let min = -(1i64 << (self.bits() - 1));
        (raw as i64).clamp(min, max) as i32
    }

    /// Dequantize a raw integer back to float.
    pub fn dequantize(&self, raw: i32) -> f64 {
        f64::from(raw) * self.resolution()
    }
}

/// Extend `max(|x|)` to the next power of two (the `POT(max)` rule used
/// for the cell state in §3.2.2 / Table 2). Returns the exponent `m`
/// such that the range fits in `[-2^m, 2^m)`, i.e. cell state is stored
/// as `Q_{m.15-m}` int16.
pub fn pot_integer_bits(max_abs: f64) -> u32 {
    assert!(max_abs.is_finite() && max_abs >= 0.0);
    // Cell state must at least cover the tanh input sweet spot; never go
    // below 1 integer bit so [-1, 1] products remain representable.
    let mut m = 0u32;
    while 2f64.powi(m as i32) < max_abs && m < 15 {
        m += 1;
    }
    m
}

/// Power-of-two extended scale for a measured cell-state range:
/// `POT(max) / 32768` (Table 2, row `c`).
pub fn pot_cell_scale(max_abs: f64) -> f64 {
    2f64.powi(pot_integer_bits(max_abs) as i32) / 32768.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q312_range_and_resolution() {
        let q = QFormat::q16(3); // Q3.12
        assert_eq!(q.bits(), 16);
        assert!((q.resolution() - 2f64.powi(-12)).abs() < 1e-18);
        assert!((q.max_value() - (8.0 - 2f64.powi(-12))).abs() < 1e-12);
        assert!((q.min_value() + 8.0).abs() < 1e-12);
    }

    #[test]
    fn q015_maps_unit_interval() {
        let q = QFormat::q16(0); // Q0.15: sigmoid/tanh outputs
        assert_eq!(q.quantize(1.0), 32767); // clamped to 32767/32768
        assert_eq!(q.quantize(-1.0), -32768);
        assert_eq!(q.quantize(0.5), 16384);
        assert!((q.dequantize(32767) - 32767.0 / 32768.0).abs() < 1e-12);
    }

    #[test]
    fn quantize_roundtrip_error_below_half_lsb() {
        let q = QFormat::q16(3);
        for i in -800..800 {
            let v = f64::from(i) / 100.0;
            let r = q.dequantize(q.quantize(v));
            assert!(
                (r - v).abs() <= q.resolution() / 2.0 + 1e-12,
                "v={v} r={r}"
            );
        }
    }

    #[test]
    fn pot_extension_examples_from_paper() {
        // Paper §3.2.2: measured range [-3.2, 10] -> extend to [-16, 16) -> Q4.11.
        assert_eq!(pot_integer_bits(10.0), 4);
        assert!((pot_cell_scale(10.0) - 16.0 / 32768.0).abs() < 1e-15);
        assert_eq!(pot_integer_bits(3.2), 2);
        assert_eq!(pot_integer_bits(8.0), 3);
        assert_eq!(pot_integer_bits(8.0001), 4);
        assert_eq!(pot_integer_bits(0.0), 0);
        assert_eq!(pot_integer_bits(1.0), 0);
    }

    #[test]
    fn q32_formats() {
        let q = QFormat::new(0, 31);
        assert_eq!(q.bits(), 32);
        assert_eq!(q.quantize(2.0), i32::MAX);
        assert_eq!(q.quantize(-2.0), i32::MIN);
    }
}
