//! The character-level LM assembled from the trained artifacts: an
//! LSTM stack (any engine) plus a dense softmax head — the Rust side of
//! the end-to-end quality experiments (Table 1 analog).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::lstm::{
    BatchLayerState, CalibrationStats, LayerState, LstmSpec, LstmStack,
    LstmWeights, QuantizeOptions, StackEngine, StackWeights, WeightBits,
    WeightMat,
};
use crate::quant::params::SymmetricQuant;
use crate::quant::{quantize_symmetric_i4, quantize_symmetric_i8};
use crate::tensor::{gemm_f32, matvec_f32, pad_lanes, Matrix};
use super::weights::TensorFile;

/// Character vocabulary shared with `python/compile/model.py`.
pub const VOCAB: usize = 96;

/// Tokenize a character (0 = newline, 1..95 = ASCII 32..126, other -> space).
pub fn tokenize_char(c: char) -> usize {
    match c {
        '\n' => 0,
        c if (' '..='~').contains(&c) => (c as usize) - 31,
        _ => 1,
    }
}

/// Tokenize a string.
pub fn tokenize(text: &str) -> Vec<usize> {
    text.chars().map(tokenize_char).collect()
}

/// Float master weights of the whole LM (stack + head).
pub struct CharLm {
    pub stack_weights: StackWeights,
    pub out_w: Matrix<f32>,
    pub out_b: Vec<f32>,
    pub hidden: usize,
    pub depth: usize,
}

/// The head under a given engine: float weights or quantized int8.
enum HeadEngine {
    Float,
    /// int8 symmetric weights — pre-packed for the tiled batched GEMM,
    /// or block-sparse when the model is pruned (`sparse_weights`);
    /// input h is requantized from f32 with the static head input
    /// scale; accumulator dequantized to float logits.
    Integer {
        w_q: WeightMat,
        w_scale: f64,
    },
}

/// A runnable LM: stack + head under one engine.
pub struct CharLmEngine {
    pub stack: LstmStack,
    head: HeadEngine,
    out_w: Matrix<f32>,
    out_b: Vec<f32>,
    kind: StackEngine,
}

/// Per-sequence state.
pub struct LmState {
    pub layers: Vec<LayerState>,
    /// Scratch: last hidden output.
    pub h: Vec<f32>,
    /// Scratch: logits.
    pub logits: Vec<f32>,
}

/// Batch-major LM state: lane `b` of every matrix is one session's
/// stream. Built by [`CharLmEngine::new_batch_state`], filled by
/// [`CharLmEngine::gather_session`], advanced by
/// [`CharLmEngine::step_tokens`], and drained by
/// [`CharLmEngine::scatter_session`].
///
/// # The SIMD padding contract
///
/// The physical lane count of every matrix is the live count rounded
/// up to the register-tile width ([`pad_lanes`]), so the batched GEMMs
/// always execute full lane tiles regardless of how many sessions are
/// actually live (the 3-, 5-, 7-lane widths continuous batching leaves
/// behind after compaction). Pad lanes are zero-initialized, advance as
/// zero-input streams when stepped, and are **never** gathered into,
/// scattered out, or read back — lane indices in the public API always
/// refer to the live prefix `0..batch()`.
pub struct LmBatchState {
    pub layers: Vec<BatchLayerState>,
    /// Last hidden outputs `[padded, n_output]`.
    pub h: Matrix<f32>,
    /// Next-char logits `[padded, VOCAB]`.
    pub logits: Matrix<f32>,
    /// Live lane count (`<=` the physical row count of every matrix).
    live: usize,
    /// One-hot input scratch `[padded, VOCAB]`.
    x: Matrix<f32>,
    /// Quantized-head scratch `[padded, n_output]`.
    qh: Matrix<i8>,
    /// Head accumulator scratch `[padded, VOCAB]`.
    acc: Matrix<i32>,
}

impl LmBatchState {
    /// Live lane count (the scheduler-facing batch width).
    pub fn batch(&self) -> usize {
        self.live
    }

    /// Physical lane count the GEMMs execute: [`Self::batch`] rounded
    /// up to the register-tile width. The padded-occupancy metrics
    /// report this against the live count.
    pub fn padded_batch(&self) -> usize {
        self.h.rows
    }
}

impl CharLm {
    /// Load the trained artifacts (`charlm.bin` + `charlm.json`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let cfg_text = std::fs::read_to_string(dir.join("charlm.json"))
            .context("reading charlm.json")?;
        let hidden = parse_json_usize(&cfg_text, "hidden")?;
        let depth = parse_json_usize(&cfg_text, "depth")?;
        let vocab = parse_json_usize(&cfg_text, "vocab")?;
        ensure!(vocab == VOCAB, "vocab mismatch: {vocab} != {VOCAB}");

        let tf = TensorFile::load(dir.join("charlm.bin"))?;
        let mut layers = Vec::with_capacity(depth);
        for d in 0..depth {
            let n_input = if d == 0 { VOCAB } else { hidden };
            let spec = LstmSpec::plain(n_input, hidden);
            let mut gates: [Option<crate::lstm::GateWeights>; 4] =
                [None, None, None, None];
            for (gi, gname) in ["i", "f", "z", "o"].iter().enumerate() {
                let w = tf.get(&format!("layer{d}.{gname}.w"))?;
                ensure!(w.shape == [hidden, n_input], "w shape for layer {d}");
                let r = tf.get(&format!("layer{d}.{gname}.r"))?;
                let bias = tf.get(&format!("layer{d}.{gname}.bias"))?;
                gates[gi] = Some(crate::lstm::GateWeights {
                    w: Matrix::from_vec(hidden, n_input, w.as_f32()?),
                    r: Matrix::from_vec(hidden, hidden, r.as_f32()?),
                    bias: bias.as_f32()?,
                    peephole: None,
                    ln_weight: None,
                });
            }
            layers.push(LstmWeights { spec, gates, w_proj: None, b_proj: None });
        }
        let out_w_t = tf.get("out.w")?;
        ensure!(out_w_t.shape == [VOCAB, hidden], "out.w shape");
        let out_w = Matrix::from_vec(VOCAB, hidden, out_w_t.as_f32()?);
        let out_b = tf.get("out.b")?.as_f32()?;
        Ok(CharLm {
            stack_weights: StackWeights { layers },
            out_w,
            out_b,
            hidden,
            depth,
        })
    }

    /// Calibrate on token sequences (one-hot encoded internally).
    pub fn calibrate(&self, token_seqs: &[Vec<usize>]) -> Vec<CalibrationStats> {
        let seqs: Vec<Vec<Vec<f32>>> =
            token_seqs.iter().map(|s| one_hot_seq(s)).collect();
        self.stack_weights.calibrate(&seqs)
    }

    /// Build a runnable engine.
    pub fn engine(
        &self,
        engine: StackEngine,
        stats: Option<&[CalibrationStats]>,
        opts: QuantizeOptions,
    ) -> CharLmEngine {
        let stack = LstmStack::build(&self.stack_weights, engine, stats, opts);
        let head = match engine {
            StackEngine::Float | StackEngine::Hybrid => HeadEngine::Float,
            StackEngine::Integer => match opts.weight_bits {
                WeightBits::Int4 => {
                    assert!(
                        !opts.sparse_weights,
                        "sparse_weights and int4 weights are mutually exclusive"
                    );
                    let (w_q, q) = quantize_symmetric_i4(&self.out_w);
                    HeadEngine::Integer { w_q: WeightMat::int4(&w_q), w_scale: q.scale }
                }
                WeightBits::Int8 => {
                    let (w_q, q) = quantize_symmetric_i8(&self.out_w);
                    let w_q = if opts.sparse_weights {
                        WeightMat::sparse(w_q)
                    } else {
                        WeightMat::dense(w_q)
                    };
                    HeadEngine::Integer { w_q, w_scale: q.scale }
                }
            },
        };
        CharLmEngine {
            stack,
            head,
            out_w: self.out_w.clone(),
            out_b: self.out_b.clone(),
            kind: engine,
        }
    }
}

impl CharLmEngine {
    pub fn engine_label(&self) -> &'static str {
        self.kind.label()
    }

    pub fn new_state(&self) -> LmState {
        LmState {
            layers: self.stack.zero_state(),
            h: vec![0.0; self.stack.n_output()],
            logits: vec![0.0; VOCAB],
        }
    }

    /// Feed one token; `state.logits` then holds next-char logits.
    pub fn step_token(&self, token: usize, state: &mut LmState) {
        debug_assert!(token < VOCAB);
        let mut x = vec![0f32; VOCAB];
        x[token] = 1.0;
        self.stack.step(&x, &mut state.layers, &mut state.h);
        match &self.head {
            HeadEngine::Float => {
                matvec_f32(&self.out_w, &state.h, &mut state.logits);
            }
            HeadEngine::Integer { w_q, w_scale } => {
                // Static symmetric requantization of h (scale from the
                // head weights' calibration-free rule: h ∈ [-1, 1] for
                // the plain LM). Accumulate int32, dequantize once.
                let s_h = 1.0 / 127.0;
                let hq = SymmetricQuant::with_scale(s_h);
                let mut qh = vec![0i8; state.h.len()];
                for (q, &v) in qh.iter_mut().zip(&state.h) {
                    *q = hq.quantize_i8(f64::from(v));
                }
                let mut acc = vec![0i32; VOCAB];
                w_q.matvec(&qh, &[], &mut acc);
                let k = (w_scale * s_h) as f32;
                for (l, &a) in state.logits.iter_mut().zip(&acc) {
                    *l = a as f32 * k;
                }
            }
        }
        for (l, &b) in state.logits.iter_mut().zip(&self.out_b) {
            *l += b;
        }
    }

    /// Fresh batch-major state for `batch` live lanes (physically
    /// padded to the register-tile width; pad lanes zeroed).
    pub fn new_batch_state(&self, batch: usize) -> LmBatchState {
        let n_out = self.stack.n_output();
        let physical = pad_lanes(batch);
        let mut layers = self.stack.zero_batch_state(physical);
        // Zero-state != all-zeroes for the integer engine (h sits at its
        // zero point); the padding contract wants pad lanes all-zero.
        self.stack.clear_pad_lanes(&mut layers, batch);
        LmBatchState {
            layers,
            h: Matrix::zeros(physical, n_out),
            logits: Matrix::zeros(physical, VOCAB),
            live: batch,
            x: Matrix::zeros(physical, VOCAB),
            qh: Matrix::zeros(physical, n_out),
            acc: Matrix::zeros(physical, VOCAB),
        }
    }

    /// Pack one session's state into lane `lane` of a batch state.
    pub fn gather_session(&self, s: &LmState, bs: &mut LmBatchState, lane: usize) {
        debug_assert!(lane < bs.live, "gather into pad lane {lane}");
        self.stack.gather_lane(&s.layers, &mut bs.layers, lane);
    }

    /// Unpack lane `lane` back into a session's state (recurrent layers
    /// plus the hidden/logits scratch, so the session observes exactly
    /// what sequential stepping would have left behind).
    pub fn scatter_session(&self, bs: &LmBatchState, s: &mut LmState, lane: usize) {
        debug_assert!(lane < bs.live, "scatter from pad lane {lane}");
        self.stack.scatter_lane(&bs.layers, &mut s.layers, lane);
        s.h.copy_from_slice(bs.h.row(lane));
        s.logits.copy_from_slice(bs.logits.row(lane));
    }

    /// Resize a batch state to `batch` live lanes in place, reusing
    /// every allocation (the serving loop reuses one state across
    /// waves). The physical width is rounded up to the register-tile
    /// width and the pad lanes are zeroed. Contents of grown *live*
    /// lanes are unspecified — callers must gather into every live lane
    /// before stepping.
    pub fn resize_batch_state(&self, bs: &mut LmBatchState, batch: usize) {
        let physical = pad_lanes(batch);
        if batch < bs.live {
            // Shrink to the live prefix first so the pad region comes
            // back zeroed when the matrices regrow below.
            self.stack.truncate_batch(&mut bs.layers, batch);
            bs.h.truncate_rows(batch);
            bs.logits.truncate_rows(batch);
            bs.x.truncate_rows(batch);
            bs.qh.truncate_rows(batch);
            bs.acc.truncate_rows(batch);
        }
        self.stack.resize_batch(&mut bs.layers, physical);
        bs.h.resize(physical, bs.h.cols);
        bs.logits.resize(physical, bs.logits.cols);
        bs.x.resize(physical, bs.x.cols);
        bs.qh.resize(physical, bs.qh.cols);
        bs.acc.resize(physical, bs.acc.cols);
        self.stack.clear_pad_lanes(&mut bs.layers, batch);
        bs.h.data[batch * bs.h.cols..].fill(0.0);
        bs.logits.data[batch * bs.logits.cols..].fill(0.0);
        bs.live = batch;
    }

    /// Admit a session into a fresh lane appended at the end of the
    /// batch — continuous batching's entry point: lanes join a live
    /// wave between token positions. Returns the new lane index.
    pub fn admit_lane(&self, s: &LmState, bs: &mut LmBatchState) -> usize {
        let lane = bs.batch();
        self.resize_batch_state(bs, lane + 1);
        self.gather_session(s, bs, lane);
        lane
    }

    /// Copy lane `src`'s recurrent state and output rows over lane
    /// `dst`. The pure scratch buffers (`x`, `qh`, `acc`) are rewritten
    /// from scratch every step and need no copy.
    pub fn copy_lane(&self, bs: &mut LmBatchState, src: usize, dst: usize) {
        debug_assert!(src < bs.live && dst < bs.live, "copy touches pad lanes");
        self.stack.copy_lane_batch(&mut bs.layers, src, dst);
        bs.h.copy_row_within(src, dst);
        bs.logits.copy_row_within(src, dst);
    }

    /// Retire one lane by swap-remove: the last lane moves into `lane`
    /// and the batch shrinks by one (scatter the retiring lane out
    /// first). Returns the index the moved lane came from, if any lane
    /// moved.
    pub fn retire_lane(&self, bs: &mut LmBatchState, lane: usize) -> Option<usize> {
        let last = bs.batch().checked_sub(1).expect("retire from empty batch");
        assert!(lane <= last, "lane {lane} out of range");
        let moved = if lane != last {
            self.copy_lane(bs, last, lane);
            Some(last)
        } else {
            None
        };
        self.truncate_batch(bs, last);
        moved
    }

    /// Order-preserving lane compaction: live lanes with `keep[lane]`
    /// survive, packed to the front; the rest are dropped (scatter them
    /// out first). The physical width re-pads to the register-tile
    /// width of the surviving count, with pad lanes zeroed. Returns the
    /// surviving (live) lane count.
    pub fn compact_lanes(&self, bs: &mut LmBatchState, keep: &[bool]) -> usize {
        assert_eq!(keep.len(), bs.batch(), "keep mask width");
        // Extend the mask over the physical pad lanes: always dropped
        // here, re-created zeroed by the resize below.
        let mut keep_phys = keep.to_vec();
        keep_phys.resize(bs.padded_batch(), false);
        let survivors = self.stack.compact_batch(&mut bs.layers, &keep_phys);
        let mut dst = 0;
        for (src, &k) in keep.iter().enumerate() {
            if k {
                if src != dst {
                    bs.h.copy_row_within(src, dst);
                    bs.logits.copy_row_within(src, dst);
                }
                dst += 1;
            }
        }
        debug_assert_eq!(dst, survivors);
        // bs.live still holds the pre-compaction count, so this takes
        // resize_batch_state's shrink path: every matrix truncates to
        // the survivor prefix, then re-pads zeroed.
        self.resize_batch_state(bs, dst);
        dst
    }

    /// Drop live lanes `k..` of a batch state (scatter them out first);
    /// the surviving prefix stays in place and the physical width
    /// re-pads to the register-tile width.
    pub fn truncate_batch(&self, bs: &mut LmBatchState, k: usize) {
        assert!(k <= bs.live, "truncate {k} > live {}", bs.live);
        self.resize_batch_state(bs, k);
    }

    /// Feed one token per live lane (`tokens.len()` must equal the live
    /// batch); row `b` of `state.logits` then holds lane `b`'s next-char
    /// logits. Bit-exact with per-lane [`Self::step_token`].
    ///
    /// Execution runs at the *physical* (tile-padded) width: pad lanes
    /// see an all-zero one-hot row and advance their zero stream, so
    /// every GEMM below processes full register tiles with no scalar
    /// remainders. Pad-lane outputs are never read.
    pub fn step_tokens(&self, tokens: &[usize], state: &mut LmBatchState) {
        assert_eq!(tokens.len(), state.live, "one token per live lane");
        let LmBatchState { layers, h, logits, x, qh, acc, .. } = state;
        let physical = h.rows;
        x.data.iter_mut().for_each(|v| *v = 0.0);
        for (b, &t) in tokens.iter().enumerate() {
            debug_assert!(t < VOCAB);
            x.row_mut(b)[t] = 1.0;
        }
        self.stack.step_batch(x, layers, h);
        match &self.head {
            HeadEngine::Float => gemm_f32(&self.out_w, h, logits),
            HeadEngine::Integer { w_q, w_scale } => {
                let s_h = 1.0 / 127.0;
                let hq = SymmetricQuant::with_scale(s_h);
                for (q, &v) in qh.data.iter_mut().zip(h.data.iter()) {
                    *q = hq.quantize_i8(f64::from(v));
                }
                w_q.matmul_batch(qh, &[], acc);
                let k = (w_scale * s_h) as f32;
                for (l, &a) in logits.data.iter_mut().zip(acc.data.iter()) {
                    *l = a as f32 * k;
                }
            }
        }
        for b in 0..physical {
            for (l, &bv) in logits.row_mut(b).iter_mut().zip(&self.out_b) {
                *l += bv;
            }
        }
    }

    /// Average next-char negative log2-likelihood over a token sequence
    /// (bits per character — the quality metric of the E1 experiment).
    pub fn bits_per_char(&self, tokens: &[usize]) -> f64 {
        assert!(tokens.len() >= 2);
        let mut state = self.new_state();
        let mut total = 0f64;
        for t in 0..tokens.len() - 1 {
            self.step_token(tokens[t], &mut state);
            total += nll_bits(&state.logits, tokens[t + 1]);
        }
        total / (tokens.len() - 1) as f64
    }

    /// Bytes of one stream's persistent state under this engine: the
    /// recurrent layer states plus the hidden/logits scratch an
    /// [`LmState`] carries. The registry multiplies this by resident
    /// session counts for the per-model memory accounting (state is
    /// the second resident cost after packed weights).
    pub fn state_bytes(&self) -> usize {
        self.stack.state_bytes() + (self.stack.n_output() + VOCAB) * 4
    }

    /// Weight bytes (stack + head) for the Table-1 size column.
    pub fn weight_bytes(&self) -> usize {
        let head = match &self.head {
            HeadEngine::Float => self.out_w.len() * 4,
            HeadEngine::Integer { w_q, .. } => w_q.storage_bytes(),
        };
        self.stack.weight_bytes() + head + self.out_b.len() * 4
    }
}

/// -log2 softmax probability of `target`.
pub fn nll_bits(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let sum_exp: f64 = logits.iter().map(|&v| f64::from(v - max).exp()).sum();
    let logp = f64::from(logits[target] - max) - sum_exp.ln();
    -logp / std::f64::consts::LN_2
}

/// One-hot encode a token sequence.
pub fn one_hot_seq(tokens: &[usize]) -> Vec<Vec<f32>> {
    tokens
        .iter()
        .map(|&t| {
            let mut v = vec![0f32; VOCAB];
            v[t] = 1.0;
            v
        })
        .collect()
}

/// Tiny JSON number extractor (the config file is machine-written;
/// avoids a JSON dependency).
fn parse_json_usize(text: &str, key: &str) -> Result<usize> {
    let pat = format!("\"{key}\":");
    let pos = text.find(&pat).with_context(|| format!("key {key}"))?;
    let rest = &text[pos + pat.len()..];
    let digits: String = rest
        .trim_start()
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().with_context(|| format!("parsing {key}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip_properties() {
        assert_eq!(tokenize_char('\n'), 0);
        assert_eq!(tokenize_char(' '), 1);
        assert_eq!(tokenize_char('~'), 95);
        assert_eq!(tokenize_char('\u{1F600}'), 1); // non-ASCII -> space
        let toks = tokenize("Hi\n");
        assert_eq!(toks, vec![('H' as usize) - 31, ('i' as usize) - 31, 0]);
        assert!(toks.iter().all(|&t| t < VOCAB));
    }

    #[test]
    fn json_parser_extracts_fields() {
        let text = r#"{"vocab": 96, "hidden": 192, "depth": 2}"#;
        assert_eq!(parse_json_usize(text, "vocab").unwrap(), 96);
        assert_eq!(parse_json_usize(text, "hidden").unwrap(), 192);
        assert_eq!(parse_json_usize(text, "depth").unwrap(), 2);
        assert!(parse_json_usize(text, "missing").is_err());
    }

    #[test]
    fn nll_bits_uniform() {
        let logits = vec![0f32; VOCAB];
        let bits = nll_bits(&logits, 5);
        assert!((bits - (VOCAB as f64).log2()).abs() < 1e-9);
    }

    #[test]
    fn one_hot_shape() {
        let oh = one_hot_seq(&[0, 5, 95]);
        assert_eq!(oh.len(), 3);
        assert_eq!(oh[1][5], 1.0);
        assert_eq!(oh[1].iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn admit_and_retire_lane_preserve_survivors() {
        // Swap-remove retirement: retiring a middle lane moves the last
        // lane into its slot and reports the move; survivors stay
        // bit-identical.
        let mut rng = crate::util::Pcg32::seeded(17);
        let spec = LstmSpec::plain(VOCAB, 12);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 12);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        let lm = CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 12, depth: 1 };
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());

        // Three sessions advanced different distances sequentially.
        let mut states: Vec<LmState> = (0..3).map(|_| engine.new_state()).collect();
        for (i, s) in states.iter_mut().enumerate() {
            for t in 0..=i {
                engine.step_token(t, s);
            }
        }
        let mut bs = engine.new_batch_state(0);
        for s in &states {
            engine.admit_lane(s, &mut bs);
        }
        assert_eq!(bs.batch(), 3);

        // Retire the middle lane: lane 2 must move into slot 1.
        assert_eq!(engine.retire_lane(&mut bs, 1), Some(2));
        assert_eq!(bs.batch(), 2);
        for (lane, idx) in [(0usize, 0usize), (1, 2)] {
            let mut got = engine.new_state();
            engine.scatter_session(&bs, &mut got, lane);
            // h/logits rows were gathered from admit-time zeros, so only
            // compare the recurrent layers (the invariant retire_lane
            // actually owns).
            for (a, b) in got.layers.iter().zip(&states[idx].layers) {
                match (a, b) {
                    (LayerState::Float(x), LayerState::Float(y)) => {
                        assert_eq!(x.c, y.c);
                        assert_eq!(x.h, y.h);
                    }
                    _ => panic!("engine mismatch"),
                }
            }
        }
        // Retiring the last lane moves nothing.
        assert_eq!(engine.retire_lane(&mut bs, 1), None);
        assert_eq!(bs.batch(), 1);
    }
}
