//! Model artifacts: the binary tensor format shared with the python
//! build path, and the character-level LM assembled from those tensors.

pub mod lm;
pub mod weights;

pub use lm::{CharLm, CharLmEngine, LmState};
pub use weights::{Dtype, TensorFile, TensorView};
