//! Reader/writer for the little-endian named-tensor format produced by
//! `python/compile/model.py:write_tensors`.
//!
//! Layout: magic `0x49515257` ("IQRW"), version u32, tensor count u32,
//! then per tensor: name (u32 len + utf8), dtype u8, ndim u32, dims
//! u32×ndim, raw little-endian data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

pub const MAGIC: u32 = 0x4951_5257;

/// Element type tags (must match `_DTYPES` in model.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32 = 0,
    I8 = 1,
    I16 = 2,
    I32 = 3,
}

impl Dtype {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Dtype::F32,
            1 => Dtype::I8,
            2 => Dtype::I16,
            3 => Dtype::I32,
            other => bail!("unknown dtype tag {other}"),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I16 => 2,
            Dtype::I8 => 1,
        }
    }
}

/// One named tensor.
#[derive(Debug, Clone)]
pub struct TensorView {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl TensorView {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == Dtype::F32, "expected f32");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn as_i8(&self) -> Result<Vec<i8>> {
        ensure!(self.dtype == Dtype::I8, "expected i8");
        Ok(self.data.iter().map(|&b| b as i8).collect())
    }

    pub fn as_i16(&self) -> Result<Vec<i16>> {
        ensure!(self.dtype == Dtype::I16, "expected i16");
        Ok(self
            .data
            .chunks_exact(2)
            .map(|b| i16::from_le_bytes([b[0], b[1]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        ensure!(self.dtype == Dtype::I32, "expected i32");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// A parsed tensor file.
#[derive(Debug, Default)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, TensorView>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

impl TensorFile {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::read(&mut f)
    }

    pub fn read(r: &mut impl Read) -> Result<Self> {
        ensure!(read_u32(r)? == MAGIC, "bad magic (not an IQRW tensor file)");
        let version = read_u32(r)?;
        ensure!(version == 1, "unsupported version {version}");
        let count = read_u32(r)? as usize;
        ensure!(count < 1 << 20, "implausible tensor count {count}");
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            ensure!(name_len < 4096, "implausible name length");
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name utf8")?;
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)?;
            let dtype = Dtype::from_u8(tag[0])?;
            let ndim = read_u32(r)? as usize;
            ensure!(ndim <= 8, "implausible rank {ndim}");
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(r)? as usize);
            }
            let elems: usize = shape.iter().product();
            ensure!(elems < 1 << 30, "implausible tensor size");
            let mut data = vec![0u8; elems * dtype.size()];
            r.read_exact(&mut data)?;
            tensors.insert(name, TensorView { dtype, shape, data });
        }
        Ok(TensorFile { tensors })
    }

    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&1u32.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[t.dtype as u8])?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            w.write_all(&t.data)?;
        }
        Ok(())
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        self.write(&mut f)
    }

    pub fn get(&self, name: &str) -> Result<&TensorView> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor `{name}`"))
    }

    /// Insert an f32 tensor (tests / round-trips).
    pub fn put_f32(&mut self, name: &str, shape: Vec<usize>, data: &[f32]) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let bytes = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.tensors.insert(
            name.to_string(),
            TensorView { dtype: Dtype::F32, shape, data: bytes },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut tf = TensorFile::default();
        tf.put_f32("a.w", vec![2, 3], &[1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        tf.put_f32("b", vec![1], &[42.0]);
        let mut buf = Vec::new();
        tf.write(&mut buf).unwrap();
        let back = TensorFile::read(&mut buf.as_slice()).unwrap();
        assert_eq!(back.tensors.len(), 2);
        let a = back.get("a.w").unwrap();
        assert_eq!(a.shape, vec![2, 3]);
        assert_eq!(a.as_f32().unwrap(), vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        assert!(back.get("missing").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = vec![0u8; 16];
        assert!(TensorFile::read(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(Dtype::F32.size(), 4);
        assert_eq!(Dtype::I8.size(), 1);
        assert_eq!(Dtype::I16.size(), 2);
        assert_eq!(Dtype::I32.size(), 4);
    }
}
