//! Deterministic PCG32 random number generator.
//!
//! The whole library (workload generators, weight initialization,
//! property tests) must be reproducible without external crates, so we
//! carry a small, well-known PRNG: PCG-XSH-RR 64/32 (O'Neill 2014).

/// PCG-XSH-RR 64/32. Deterministic, seedable, tiny.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULTIPLIER: u64 = 6364136223846793005;

    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULTIPLIER).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        f64::from(self.next_u32()) / f64::from(u32::MAX) * (1.0 - f64::EPSILON)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough
    /// for workloads; exact rejection for tests is overkill).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        ((u64::from(self.next_u32()) * u64::from(n)) >> 32) as u32
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = (i64::from(hi) - i64::from(lo) + 1) as u32;
        lo.wrapping_add(self.below(span) as i32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std, as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (f64::from(mean) + f64::from(std) * self.normal()) as f32
    }

    /// Fill a slice with uniform floats in `[lo, hi)`.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out {
            *v = self.uniform(f64::from(lo), f64::from(hi)) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            data.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let v = rng.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
            let i = rng.range_i32(-5, 5);
            assert!((-5..=5).contains(&i));
            let b = rng.below(10);
            assert!(b < 10);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let v = rng.normal();
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / f64::from(n);
        let var = sumsq / f64::from(n) - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(3);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
