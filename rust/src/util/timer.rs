//! Minimal timing utilities for the benchmark harnesses.
//!
//! Criterion is not available in the offline environment, so the
//! `benches/` binaries use this stopwatch: warmup, repeated timed runs,
//! and simple robust statistics (median + median absolute deviation).

use std::time::{Duration, Instant};

/// A stopwatch that collects per-iteration wall-clock samples.
#[derive(Debug, Default)]
pub struct Stopwatch {
    samples: Vec<Duration>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time one invocation of `f` and record the sample.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.samples.push(start.elapsed());
        out
    }

    pub fn samples(&self) -> &[Duration] {
        &self.samples
    }

    /// Median of the recorded samples in seconds.
    pub fn median_secs(&self) -> f64 {
        let mut s: Vec<f64> = self.samples.iter().map(Duration::as_secs_f64).collect();
        if s.is_empty() {
            return f64::NAN;
        }
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mid = s.len() / 2;
        if s.len() % 2 == 0 { (s[mid - 1] + s[mid]) / 2.0 } else { s[mid] }
    }

    /// Median absolute deviation in seconds.
    pub fn mad_secs(&self) -> f64 {
        let med = self.median_secs();
        let mut dev: Vec<f64> = self
            .samples
            .iter()
            .map(|d| (d.as_secs_f64() - med).abs())
            .collect();
        if dev.is_empty() {
            return f64::NAN;
        }
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mid = dev.len() / 2;
        if dev.len() % 2 == 0 { (dev[mid - 1] + dev[mid]) / 2.0 } else { dev[mid] }
    }

    /// Total time across samples in seconds.
    pub fn total_secs(&self) -> f64 {
        self.samples.iter().map(Duration::as_secs_f64).sum()
    }
}

/// Run `f` for `warmup` unrecorded iterations then `iters` timed ones;
/// returns the stopwatch. `black_box` the result inside `f` yourself if
/// needed (use [`std::hint::black_box`]).
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stopwatch {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut sw = Stopwatch::new();
    for _ in 0..iters {
        sw.time(|| std::hint::black_box(f()));
    }
    sw
}

/// Format a duration-in-seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_statistics() {
        let sw = bench(1, 9, || {
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert_eq!(sw.samples().len(), 9);
        assert!(sw.median_secs() > 0.0);
        assert!(sw.mad_secs() >= 0.0);
        assert!(sw.total_secs() >= sw.median_secs());
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" us"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}
