//! Small dependency-free utilities: deterministic RNG, timing, and a
//! minimal property-testing helper used across the test suite.

pub mod proptest;
pub mod rng;
pub mod timer;

pub use rng::Pcg32;
pub use timer::Stopwatch;
