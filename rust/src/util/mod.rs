//! Small dependency-free utilities: deterministic RNG, timing, a
//! minimal property-testing helper used across the test suite, and the
//! SIMD-dispatch switch shared by every runtime-dispatched kernel.

pub mod proptest;
pub mod rng;
pub mod timer;

pub use rng::Pcg32;
pub use timer::Stopwatch;

/// Read a boolean environment flag: set means any non-empty value
/// other than `"0"`. One parse rule for every `PALLAS_*` switch
/// (kernel dispatch, bench quick mode) so they can never drift apart.
pub fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// True when the `PALLAS_FORCE_SCALAR` environment override is set.
/// The CI kernel matrix sets this to run the whole test suite against
/// the scalar reference kernels, proving the scalar and AVX2 paths
/// bit-exact on every PR. Read once and cached: dispatch sits on the
/// per-step hot path.
pub fn force_scalar() -> bool {
    static FORCE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FORCE.get_or_init(|| env_flag("PALLAS_FORCE_SCALAR"))
}

/// True when the runtime-dispatched AVX2 kernels should run: the CPU
/// reports AVX2 and [`force_scalar`] is not in effect. Every
/// `is_x86_feature_detected!` dispatch site in the crate routes through
/// this, so one environment variable flips the entire execution path.
#[inline]
pub fn avx2_enabled() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if !force_scalar() && std::arch::is_x86_feature_detected!("avx2") {
            return true;
        }
    }
    false
}
