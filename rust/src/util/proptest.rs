//! A minimal property-testing harness (the `proptest` crate is not
//! available offline).
//!
//! [`run_cases`] drives a closure with a deterministic RNG for N cases;
//! on failure it reports the case index and seed so the exact failing
//! input can be reproduced by rerunning with that seed.

use super::rng::Pcg32;

/// Number of cases property tests run by default.
pub const DEFAULT_CASES: usize = 256;

/// Run `property` for `cases` deterministic cases. The property
/// receives a per-case RNG; panic (assert) to signal failure.
pub fn run_cases(name: &str, cases: usize, mut property: impl FnMut(&mut Pcg32)) {
    for case in 0..cases {
        let seed = 0x9e3779b97f4a7c15u64
            .wrapping_mul(case as u64 + 1)
            ^ (name.len() as u64).rotate_left(17);
        let mut rng = Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(payload) = result {
            eprintln!(
                "property `{name}` failed at case {case}/{cases} (seed {seed:#x})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Shorthand with the default case count.
pub fn check(name: &str, property: impl FnMut(&mut Pcg32)) {
    run_cases(name, DEFAULT_CASES, property);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u32-below", |rng| {
            let n = 1 + rng.below(100);
            assert!(rng.below(n) < n);
        });
    }

    #[test]
    fn reports_failure_case() {
        let result = std::panic::catch_unwind(|| {
            run_cases("always-fails", 3, |_| panic!("boom"));
        });
        assert!(result.is_err());
    }
}
