//! Magnitude pruning: zero the smallest-magnitude fraction of weights.
//!
//! The paper's sparse models come from production pruning pipelines; we
//! reproduce the standard magnitude criterion, optionally in blocks of
//! 4 along the row (the shape ARM/TFLite sparse kernels exploit).

use crate::tensor::qmatmul::{K_BLOCK, MR};
use crate::tensor::Matrix;

/// Zero the smallest-|w| `sparsity` fraction of entries (per-matrix
/// global threshold). `sparsity` in `[0, 1]`.
pub fn prune_magnitude(w: &mut Matrix<f32>, sparsity: f64) {
    assert!((0.0..=1.0).contains(&sparsity));
    if sparsity == 0.0 || w.is_empty() {
        return;
    }
    let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((w.len() as f64) * sparsity).round() as usize;
    if k == 0 {
        return;
    }
    let threshold = mags[(k - 1).min(mags.len() - 1)];
    let mut zeroed = 0usize;
    for v in &mut w.data {
        if v.abs() <= threshold && zeroed < k {
            *v = 0.0;
            zeroed += 1;
        }
    }
}

/// Block-of-4 magnitude pruning along rows: whole 4-wide blocks are
/// kept or zeroed by their L1 norm, matching sparse-kernel-friendly
/// structure.
pub fn prune_magnitude_block4(w: &mut Matrix<f32>, sparsity: f64) {
    assert!((0.0..=1.0).contains(&sparsity));
    assert_eq!(w.cols % 4, 0, "block pruning needs cols % 4 == 0");
    if sparsity == 0.0 || w.is_empty() {
        return;
    }
    let blocks = w.len() / 4;
    let mut norms: Vec<(f32, usize)> = (0..blocks)
        .map(|b| {
            let s: f32 = w.data[b * 4..b * 4 + 4].iter().map(|v| v.abs()).sum();
            (s, b)
        })
        .collect();
    norms.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let k = ((blocks as f64) * sparsity).round() as usize;
    for &(_, b) in norms.iter().take(k) {
        for v in &mut w.data[b * 4..b * 4 + 4] {
            *v = 0.0;
        }
    }
}

/// Structured (block-granular) magnitude pruning in the execution
/// kernel's own tile shape: rank [`MR`]-row × [`K_BLOCK`]-column tiles
/// by L1 norm and zero the smallest `sparsity` fraction *of tiles*.
///
/// This is the pruning criterion that the block-sparse kernel
/// ([`crate::sparse::BlockSparseI8`]) actually converts into skipped
/// work: element-level magnitude pruning scatters zeros through blocks
/// that must still be stored and multiplied, whereas a zeroed tile here
/// is a dropped block there, so element sparsity ≈ block sparsity ≈
/// kernel speedup. Ragged edge tiles (fewer than `MR` rows or `K_BLOCK`
/// columns) participate with their live entries only.
pub fn prune_block_structured(w: &mut Matrix<f32>, sparsity: f64) {
    assert!((0.0..=1.0).contains(&sparsity));
    if sparsity == 0.0 || w.is_empty() {
        return;
    }
    let row_tiles = w.rows.div_ceil(MR);
    let col_tiles = w.cols.div_ceil(K_BLOCK);
    let n_tiles = row_tiles * col_tiles;
    let mut norms: Vec<(f32, usize)> = Vec::with_capacity(n_tiles);
    for p in 0..row_tiles {
        for kb in 0..col_tiles {
            let mut s = 0.0f32;
            let k0 = kb * K_BLOCK;
            let kn = (w.cols - k0).min(K_BLOCK);
            for q in 0..MR.min(w.rows - p * MR) {
                s += w.row(p * MR + q)[k0..k0 + kn]
                    .iter()
                    .map(|v| v.abs())
                    .sum::<f32>();
            }
            norms.push((s, p * col_tiles + kb));
        }
    }
    norms.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let k = ((n_tiles as f64) * sparsity).round() as usize;
    for &(_, t) in norms.iter().take(k) {
        let (p, kb) = (t / col_tiles, t % col_tiles);
        let k0 = kb * K_BLOCK;
        let kn = (w.cols - k0).min(K_BLOCK);
        for q in 0..MR.min(w.rows - p * MR) {
            w.row_mut(p * MR + q)[k0..k0 + kn].fill(0.0);
        }
    }
}

/// Fraction of exactly-zero entries.
pub fn sparsity_of(w: &Matrix<f32>) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    let zeros = w.data.iter().filter(|v| **v == 0.0).count();
    zeros as f64 / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_matrix(seed: u64, rows: usize, cols: usize) -> Matrix<f32> {
        let mut rng = Pcg32::seeded(seed);
        let mut w = Matrix::<f32>::zeros(rows, cols);
        for v in &mut w.data {
            *v = rng.normal_f32(0.0, 1.0);
            if *v == 0.0 {
                *v = 0.5;
            }
        }
        w
    }

    #[test]
    fn prunes_to_requested_sparsity() {
        let mut w = random_matrix(1, 64, 64);
        prune_magnitude(&mut w, 0.5);
        let s = sparsity_of(&w);
        assert!((s - 0.5).abs() < 0.01, "sparsity {s}");
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let mut w = Matrix::from_vec(1, 4, vec![0.1f32, -5.0, 0.2, 3.0]);
        prune_magnitude(&mut w, 0.5);
        assert_eq!(w.data, vec![0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn zero_sparsity_is_noop() {
        let mut w = random_matrix(2, 8, 8);
        let before = w.clone();
        prune_magnitude(&mut w, 0.0);
        assert_eq!(w, before);
    }

    #[test]
    fn block4_prunes_whole_blocks() {
        let mut w = random_matrix(3, 16, 64);
        prune_magnitude_block4(&mut w, 0.5);
        let s = sparsity_of(&w);
        assert!((s - 0.5).abs() < 0.02, "sparsity {s}");
        // Every 4-block is all-zero or all-nonzero-ish (block either
        // survived intact or was zeroed).
        for b in 0..w.len() / 4 {
            let blk = &w.data[b * 4..b * 4 + 4];
            let zeros = blk.iter().filter(|v| **v == 0.0).count();
            assert!(zeros == 0 || zeros == 4, "partial block {blk:?}");
        }
    }

    #[test]
    fn structured_prune_zeroes_whole_tiles() {
        // 64x96 divides evenly into 16x3 MR×K_BLOCK tiles; at 0.75 the
        // element sparsity must match the tile sparsity exactly and
        // every tile must be uniformly dead or alive.
        let mut w = random_matrix(5, 64, 96);
        prune_block_structured(&mut w, 0.75);
        let s = sparsity_of(&w);
        assert!((s - 0.75).abs() < 0.01, "sparsity {s}");
        for p in 0..64 / MR {
            for kb in 0..96 / K_BLOCK {
                let mut zeros = 0;
                for q in 0..MR {
                    let k0 = kb * K_BLOCK;
                    zeros += w.row(p * MR + q)[k0..k0 + K_BLOCK]
                        .iter()
                        .filter(|v| **v == 0.0)
                        .count();
                }
                assert!(
                    zeros == 0 || zeros == MR * K_BLOCK,
                    "partial tile ({p},{kb}): {zeros} zeros"
                );
            }
        }
    }

    #[test]
    fn structured_prune_handles_ragged_edges() {
        // 33x47: ragged in both dimensions. Must not panic, and must
        // prune roughly the requested fraction of tiles.
        let mut w = random_matrix(6, 33, 47);
        prune_block_structured(&mut w, 0.5);
        let s = sparsity_of(&w);
        assert!(s > 0.3 && s < 0.7, "sparsity {s}");
    }

    #[test]
    fn full_sparsity_zeroes_everything() {
        let mut w = random_matrix(4, 8, 8);
        prune_magnitude(&mut w, 1.0);
        assert_eq!(sparsity_of(&w), 1.0);
    }
}
