//! Magnitude pruning: zero the smallest-magnitude fraction of weights.
//!
//! The paper's sparse models come from production pruning pipelines; we
//! reproduce the standard magnitude criterion, optionally in blocks of
//! 4 along the row (the shape ARM/TFLite sparse kernels exploit).

use crate::tensor::Matrix;

/// Zero the smallest-|w| `sparsity` fraction of entries (per-matrix
/// global threshold). `sparsity` in `[0, 1]`.
pub fn prune_magnitude(w: &mut Matrix<f32>, sparsity: f64) {
    assert!((0.0..=1.0).contains(&sparsity));
    if sparsity == 0.0 || w.is_empty() {
        return;
    }
    let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((w.len() as f64) * sparsity).round() as usize;
    if k == 0 {
        return;
    }
    let threshold = mags[(k - 1).min(mags.len() - 1)];
    let mut zeroed = 0usize;
    for v in &mut w.data {
        if v.abs() <= threshold && zeroed < k {
            *v = 0.0;
            zeroed += 1;
        }
    }
}

/// Block-of-4 magnitude pruning along rows: whole 4-wide blocks are
/// kept or zeroed by their L1 norm, matching sparse-kernel-friendly
/// structure.
pub fn prune_magnitude_block4(w: &mut Matrix<f32>, sparsity: f64) {
    assert!((0.0..=1.0).contains(&sparsity));
    assert_eq!(w.cols % 4, 0, "block pruning needs cols % 4 == 0");
    if sparsity == 0.0 || w.is_empty() {
        return;
    }
    let blocks = w.len() / 4;
    let mut norms: Vec<(f32, usize)> = (0..blocks)
        .map(|b| {
            let s: f32 = w.data[b * 4..b * 4 + 4].iter().map(|v| v.abs()).sum();
            (s, b)
        })
        .collect();
    norms.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let k = ((blocks as f64) * sparsity).round() as usize;
    for &(_, b) in norms.iter().take(k) {
        for v in &mut w.data[b * 4..b * 4 + 4] {
            *v = 0.0;
        }
    }
}

/// Fraction of exactly-zero entries.
pub fn sparsity_of(w: &Matrix<f32>) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    let zeros = w.data.iter().filter(|v| **v == 0.0).count();
    zeros as f64 / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_matrix(seed: u64, rows: usize, cols: usize) -> Matrix<f32> {
        let mut rng = Pcg32::seeded(seed);
        let mut w = Matrix::<f32>::zeros(rows, cols);
        for v in &mut w.data {
            *v = rng.normal_f32(0.0, 1.0);
            if *v == 0.0 {
                *v = 0.5;
            }
        }
        w
    }

    #[test]
    fn prunes_to_requested_sparsity() {
        let mut w = random_matrix(1, 64, 64);
        prune_magnitude(&mut w, 0.5);
        let s = sparsity_of(&w);
        assert!((s - 0.5).abs() < 0.01, "sparsity {s}");
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let mut w = Matrix::from_vec(1, 4, vec![0.1f32, -5.0, 0.2, 3.0]);
        prune_magnitude(&mut w, 0.5);
        assert_eq!(w.data, vec![0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn zero_sparsity_is_noop() {
        let mut w = random_matrix(2, 8, 8);
        let before = w.clone();
        prune_magnitude(&mut w, 0.0);
        assert_eq!(w, before);
    }

    #[test]
    fn block4_prunes_whole_blocks() {
        let mut w = random_matrix(3, 16, 64);
        prune_magnitude_block4(&mut w, 0.5);
        let s = sparsity_of(&w);
        assert!((s - 0.5).abs() < 0.02, "sparsity {s}");
        // Every 4-block is all-zero or all-nonzero-ish (block either
        // survived intact or was zeroed).
        for b in 0..w.len() / 4 {
            let blk = &w.data[b * 4..b * 4 + 4];
            let zeros = blk.iter().filter(|v| **v == 0.0).count();
            assert!(zeros == 0 || zeros == 4, "partial block {blk:?}");
        }
    }

    #[test]
    fn full_sparsity_zeroes_everything() {
        let mut w = random_matrix(4, 8, 8);
        prune_magnitude(&mut w, 1.0);
        assert_eq!(sparsity_of(&w), 1.0);
    }
}
