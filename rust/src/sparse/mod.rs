//! Sparsity support for the Table 1 "Sparse LSTM" / "Sparse CIFG" rows.
//!
//! The paper evaluates 50%-sparse production models. We reproduce the
//! mechanism end to end: magnitude and structured pruning to a target
//! sparsity ([`prune`]), compressed row storage with a reference sparse
//! int8 matvec ([`csr`]), and a block-sparse execution format in the
//! packed kernel's tile geometry ([`bsr`]) so pruned models ride the
//! same register-tiled batched serving path as dense ones — the size
//! *and* speed implications of sparsity are both measurable.

pub mod bsr;
pub mod csr;
pub mod prune;

pub use bsr::BlockSparseI8;
pub use csr::SparseMatrixI8;
pub use prune::{prune_block_structured, prune_magnitude, sparsity_of};
