//! Sparsity support for the Table 1 "Sparse LSTM" / "Sparse CIFG" rows.
//!
//! The paper evaluates 50%-sparse production models. We reproduce the
//! mechanism: magnitude pruning to a target sparsity ([`prune`]) and a
//! compressed block-row storage with a sparse int8 kernel ([`csr`]) so
//! the size *and* speed implications of sparsity are measurable.

pub mod csr;
pub mod prune;

pub use csr::SparseMatrixI8;
pub use prune::{prune_magnitude, sparsity_of};
