//! Compressed sparse row storage for int8 weights, with a sparse
//! integer matvec kernel and byte-size accounting (the Table 1 size
//! column for the sparse rows).

use crate::tensor::qmatmul::bias_at;
use crate::tensor::Matrix;

/// CSR int8 matrix: per-row column indices + values.
#[derive(Debug, Clone)]
pub struct SparseMatrixI8 {
    pub rows: usize,
    pub cols: usize,
    /// Row start offsets into `col_idx`/`values`, length `rows + 1`.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u16>,
    pub values: Vec<i8>,
}

impl SparseMatrixI8 {
    /// Compress a dense int8 matrix (zeros dropped).
    pub fn from_dense(w: &Matrix<i8>) -> Self {
        assert!(w.cols <= u16::MAX as usize + 1, "cols exceed u16 index");
        let mut row_ptr = Vec::with_capacity(w.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..w.rows {
            for (c, &v) in w.row(r).iter().enumerate() {
                if v != 0 {
                    col_idx.push(c as u16);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        SparseMatrixI8 { rows: w.rows, cols: w.cols, row_ptr, col_idx, values }
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Storage bytes: values (1B) + indices (2B) + row pointers (4B).
    pub fn storage_bytes(&self) -> usize {
        self.values.len() + 2 * self.col_idx.len() + 4 * self.row_ptr.len()
    }

    /// Sparse `out[r] = folded_bias[r] + Σ w[r,c] x[c]` over non-zeros.
    ///
    /// `folded_bias` is either empty (no bias) or covers every row — a
    /// short non-empty slice is a caller bug and panics instead of
    /// silently reading zeros, same contract as the dense kernels.
    pub fn matvec_i32(&self, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
        assert_eq!(self.cols, x.len());
        assert_eq!(self.rows, out.len());
        debug_assert!(folded_bias.is_empty() || folded_bias.len() == self.rows);
        for r in 0..self.rows {
            let start = self.row_ptr[r] as usize;
            let end = self.row_ptr[r + 1] as usize;
            let mut acc = 0i32;
            for i in start..end {
                acc += i32::from(self.values[i])
                    * i32::from(x[self.col_idx[i] as usize]);
            }
            out[r] = acc + bias_at(folded_bias, r);
        }
    }

    /// Decompress back to dense (tests).
    pub fn to_dense(&self) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                w.set(r, self.col_idx[i] as usize, self.values[i]);
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::qmatmul::matvec_i8_i32;
    use crate::util::{proptest, Pcg32};

    fn random_sparse_dense(rng: &mut Pcg32, rows: usize, cols: usize) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(rows, cols);
        for v in &mut w.data {
            if rng.next_f64() < 0.5 {
                *v = rng.range_i32(-127, 127) as i8;
            }
        }
        w
    }

    #[test]
    fn roundtrip_dense_sparse_dense() {
        proptest::check("csr-roundtrip", |rng| {
            let rows = 1 + rng.below(16) as usize;
            let cols = 1 + rng.below(48) as usize;
            let w = random_sparse_dense(rng, rows, cols);
            let s = SparseMatrixI8::from_dense(&w);
            assert_eq!(s.to_dense(), w);
        });
    }

    #[test]
    fn sparse_matvec_matches_dense() {
        proptest::check("csr-matvec", |rng| {
            let rows = 1 + rng.below(16) as usize;
            let cols = 1 + rng.below(48) as usize;
            let w = random_sparse_dense(rng, rows, cols);
            let x: Vec<i8> =
                (0..cols).map(|_| rng.range_i32(-128, 127) as i8).collect();
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-1000, 1000)).collect();
            let s = SparseMatrixI8::from_dense(&w);
            let mut dense_out = vec![0i32; rows];
            let mut sparse_out = vec![0i32; rows];
            matvec_i8_i32(&w, &x, &bias, &mut dense_out);
            s.matvec_i32(&x, &bias, &mut sparse_out);
            assert_eq!(dense_out, sparse_out);
        });
    }

    #[test]
    fn storage_shrinks_at_50_percent() {
        let mut rng = Pcg32::seeded(12);
        let w = random_sparse_dense(&mut rng, 128, 128);
        let s = SparseMatrixI8::from_dense(&w);
        let dense_bytes = 128 * 128;
        // ~50% nnz at 3 bytes/nnz: CSR only wins for int8 below ~33%
        // density; at 50% it is larger — which is exactly why the paper
        // reports sparse-model sizes with *packed* formats. We assert
        // the accounting is sane rather than a win:
        assert!(s.nnz() < dense_bytes);
        assert_eq!(
            s.storage_bytes(),
            s.nnz() * 3 + 4 * (128 + 1)
        );
    }

    #[test]
    #[should_panic]
    fn short_bias_slice_panics() {
        // A non-empty bias shorter than `rows` used to be silently
        // zero-extended by `.get(r).unwrap_or(0)`; it must panic.
        let mut w = Matrix::<i8>::zeros(3, 4);
        w.set(1, 0, 5);
        let s = SparseMatrixI8::from_dense(&w);
        let x = vec![1i8; 4];
        let mut out = vec![0i32; 3];
        s.matvec_i32(&x, &[7, 8], &mut out);
    }

    #[test]
    fn empty_and_full_rows() {
        let mut w = Matrix::<i8>::zeros(3, 4);
        w.set(1, 0, 5);
        w.set(1, 3, -5);
        let s = SparseMatrixI8::from_dense(&w);
        assert_eq!(s.nnz(), 2);
        let x = vec![1i8, 2, 3, 4];
        let mut out = vec![0i32; 3];
        s.matvec_i32(&x, &[], &mut out);
        assert_eq!(out, vec![0, 5 - 20, 0]);
    }
}
