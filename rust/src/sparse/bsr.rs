//! Block-sparse int8 storage in the packed kernel's own geometry, so
//! pruned weights ride the register-tiled batched serving path instead
//! of falling back to per-lane scalar matvecs.
//!
//! [`BlockSparseI8`] re-blocks a dense int8 matrix at quantization time
//! into the exact tiles [`PackedWeightsI8`] executes: panels of
//! [`MR`]-output-row × [`K_BLOCK`]-byte blocks, zero-padded at the row
//! and K edges, keeping only blocks with at least one non-zero. Each
//! stored block is one 32-byte AVX2 load per row — the batched kernel
//! does a sign-extend + `pmaddwd` 4-row × [`LANE_TILE`]-lane FMA per
//! block, identical to the dense panel kernel except that the `kb` loop
//! walks the panel's stored-block list instead of `0..k_blocks`.
//!
//! Why BSR and not CSR here: at int8, CSR costs 3 bytes per non-zero
//! (1B value + 2B column index) plus pointer overhead, so it only
//! shrinks the model below ~33% density — and its gather-indexed inner
//! loop defeats SIMD entirely. BSR keeps the dense kernel's streaming
//! loads (indices amortize to 2 bytes per *128-byte block*) and skips
//! work at block granularity, which is what structured pruning
//! ([`prune_block_structured`]) produces.
//!
//! Bit-exactness: integer accumulation is associative and commutative,
//! and every skipped block is all-zero, so any block order and any
//! tiling produce the same int32 sums as the per-lane CSR matvec and
//! the dense kernels — the property `rust/tests/sparse_serving.rs`
//! pins across shapes, sparsities, and live-lane counts. Remainders
//! follow the packed kernel's padding contract exactly (K tails staged,
//! missing lanes re-pointed at the last live row, pad rows skipped at
//! writeback), so the batched path records **zero** scalar-tail MACs in
//! the debug [`tail_audit`] counter.
//!
//! [`PackedWeightsI8`]: crate::tensor::PackedWeightsI8
//! [`tail_audit`]: crate::tensor::qmatmul::tail_audit
//! [`prune_block_structured`]: super::prune::prune_block_structured

use crate::tensor::qmatmul::{bias_at, K_BLOCK, MR};
#[cfg(target_arch = "x86_64")]
use crate::tensor::qmatmul::{hsum_epi32, widen_i8, LANE_TILE};
use crate::tensor::Matrix;
#[cfg(target_arch = "x86_64")]
use crate::util::avx2_enabled;

/// Bytes in one stored block: [`MR`] rows × [`K_BLOCK`] columns.
pub const BLOCK_BYTES: usize = MR * K_BLOCK;

/// Block-sparse int8 matrix in the packed panel geometry.
///
/// Panel `p` covers output rows `p*MR .. p*MR+MR`; its stored blocks
/// are listed in ascending `kb` (K-block index) order. Within a block,
/// row `q`'s [`K_BLOCK`] bytes sit at `q * K_BLOCK` — the same
/// sub-layout as a [`PackedWeightsI8`] panel chunk, zero-padded past
/// the logical row/column extents.
///
/// [`PackedWeightsI8`]: crate::tensor::PackedWeightsI8
#[derive(Debug, Clone)]
pub struct BlockSparseI8 {
    /// Logical row count (output features).
    pub rows: usize,
    /// Logical column count (the K / reduction dimension).
    pub cols: usize,
    /// Stored-block start offsets per panel, length `ceil(rows/MR)+1`.
    pub panel_ptr: Vec<u32>,
    /// K-block index (`kb`) of each stored block, ascending per panel.
    pub block_kb: Vec<u16>,
    /// Stored blocks, [`BLOCK_BYTES`] each, zero-padded.
    pub blocks: Vec<i8>,
}

impl BlockSparseI8 {
    /// Re-block a dense int8 matrix, dropping all-zero MR×K_BLOCK
    /// tiles. Pad rows/columns (past `rows`/`cols`) are stored as
    /// zero inside kept blocks, exactly like the dense panel packing.
    pub fn from_dense(w: &Matrix<i8>) -> Self {
        let k_blocks = w.cols.div_ceil(K_BLOCK);
        assert!(k_blocks <= u16::MAX as usize + 1, "K blocks exceed u16 index");
        let n_panels = w.rows.div_ceil(MR);
        let mut panel_ptr = Vec::with_capacity(n_panels + 1);
        let mut block_kb = Vec::new();
        let mut blocks = Vec::new();
        panel_ptr.push(0u32);
        let mut staged = [0i8; BLOCK_BYTES];
        for p in 0..n_panels {
            for kb in 0..k_blocks {
                staged.fill(0);
                let mut any = false;
                let k0 = kb * K_BLOCK;
                let kn = (w.cols - k0).min(K_BLOCK);
                for q in 0..MR {
                    let r = p * MR + q;
                    if r >= w.rows {
                        break;
                    }
                    let src = &w.row(r)[k0..k0 + kn];
                    if src.iter().any(|&v| v != 0) {
                        any = true;
                    }
                    staged[q * K_BLOCK..q * K_BLOCK + kn].copy_from_slice(src);
                }
                if any {
                    block_kb.push(kb as u16);
                    blocks.extend_from_slice(&staged);
                }
            }
            panel_ptr.push(block_kb.len() as u32);
        }
        BlockSparseI8 { rows: w.rows, cols: w.cols, panel_ptr, block_kb, blocks }
    }

    /// Stored blocks.
    pub fn block_count(&self) -> usize {
        self.block_kb.len()
    }

    /// Stored non-zero values (explicit zeros inside kept blocks are
    /// not counted — this is the effective-FLOP numerator's complement).
    pub fn nnz(&self) -> usize {
        self.blocks.iter().filter(|&&v| v != 0).count()
    }

    /// Fraction of the dense block grid that is stored (1.0 = every
    /// block kept). The batched kernel's work scales with this, not
    /// with element-level sparsity.
    pub fn block_density(&self) -> f64 {
        let total = self.rows.div_ceil(MR) * self.cols.div_ceil(K_BLOCK);
        if total == 0 {
            return 0.0;
        }
        self.block_count() as f64 / total as f64
    }

    /// Storage bytes: block payload (1B/entry) + per-block kb index
    /// (2B) + panel pointers (4B). This is the resident size the
    /// registry and `ServingReport` account for pruned models.
    pub fn storage_bytes(&self) -> usize {
        self.blocks.len() + 2 * self.block_kb.len() + 4 * self.panel_ptr.len()
    }

    /// Sparse `out[r] = folded_bias[r] + Σ w[r,c] x[c]` over stored
    /// blocks — the sequential path and the scalar reference the
    /// batched kernel is bit-exact with. `folded_bias` is either empty
    /// or covers every row (a short slice panics, never reads zeros).
    pub fn matvec_i32(&self, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
        assert_eq!(self.cols, x.len());
        assert_eq!(self.rows, out.len());
        debug_assert!(folded_bias.is_empty() || folded_bias.len() == self.rows);
        let n_panels = self.rows.div_ceil(MR);
        for p in 0..n_panels {
            let start = self.panel_ptr[p] as usize;
            let end = self.panel_ptr[p + 1] as usize;
            let prow = p * MR;
            let rows_here = (self.rows - prow).min(MR);
            for q in 0..rows_here {
                let mut acc = 0i32;
                for bi in start..end {
                    let k0 = self.block_kb[bi] as usize * K_BLOCK;
                    let kn = (self.cols - k0).min(K_BLOCK);
                    let blk = &self.blocks[bi * BLOCK_BYTES + q * K_BLOCK..][..kn];
                    for (w, &xv) in blk.iter().zip(&x[k0..k0 + kn]) {
                        acc += i32::from(*w) * i32::from(xv);
                    }
                }
                out[prow + q] = acc + bias_at(folded_bias, prow + q);
            }
        }
    }

    /// Batched block-sparse GEMM: `x` is `[batch, cols]` row-major
    /// activations, `out` is `[batch, rows]` with
    /// `out[b,r] = folded_bias[r] + Σ_c w[r,c] * x[b,c]`.
    ///
    /// On AVX2 this runs the block-list panel kernel — full 32-wide
    /// `pmaddwd` multiply-adds per stored block, zero scalar-tail
    /// iterations for any `batch` and any shape. Without AVX2, or
    /// under `PALLAS_FORCE_SCALAR`, it runs [`Self::matvec_i32`] per
    /// lane. Either way the result is bit-exact with the per-lane CSR
    /// matvec over the same weights.
    pub fn gemm(&self, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
        assert_eq!(x.cols, self.cols);
        assert_eq!(out.rows, x.rows);
        assert_eq!(out.cols, self.rows);
        debug_assert!(folded_bias.is_empty() || folded_bias.len() == self.rows);
        if x.rows == 0 || self.rows == 0 {
            return;
        }
        // Executed MACs: stored blocks only — the measured counterpart
        // of the bench's computed effective-FLOP number.
        crate::tensor::qmatmul::kernel_counters::record_bsr(
            (x.rows * self.block_count() * MR * K_BLOCK) as u64,
        );
        #[cfg(target_arch = "x86_64")]
        {
            if avx2_enabled() {
                // SAFETY: feature checked at runtime.
                unsafe { self.gemm_avx2(x, folded_bias, out) };
                return;
            }
        }
        for b in 0..x.rows {
            let or = &mut out.data[b * self.rows..(b + 1) * self.rows];
            self.matvec_i32(x.row(b), folded_bias, or);
        }
    }

    /// The block-list panel kernel: per lane tile (4 activation rows),
    /// per panel (4 weight rows), each row's accumulators walk the
    /// panel's *stored* blocks — each 32-byte weight block is
    /// sign-extended once and `pmaddwd`-accumulated four times. The
    /// padding contract is the dense kernel's: a ragged last K block is
    /// read from the staged tail buffer (block zero-padding annihilates
    /// the slack), missing tile lanes re-point at the last live row,
    /// and pad rows are skipped at writeback.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn gemm_avx2(&self, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
        use std::arch::x86_64::*;
        let rows = self.rows;
        let cols = self.cols;
        let k_tail = cols % K_BLOCK;
        let full_blocks = cols / K_BLOCK;
        let n_panels = rows.div_ceil(MR);

        // Staging for the ragged K tail, shared with the dense kernel's
        // scheme: the last 32-byte block of each lane is copied here so
        // SIMD loads never run off the row.
        let mut tails = [[0i8; K_BLOCK]; LANE_TILE];

        let mut b = 0usize;
        while b < x.rows {
            let live = (x.rows - b).min(LANE_TILE);
            // A partial tile re-points its missing lanes at the tile's
            // last live row: computed redundantly, never written back.
            let lanes: [&[i8]; LANE_TILE] =
                std::array::from_fn(|l| x.row(b + l.min(live - 1)));
            if k_tail != 0 {
                for (t, lane) in tails.iter_mut().zip(lanes.iter()) {
                    t[..k_tail].copy_from_slice(&lane[full_blocks * K_BLOCK..]);
                }
            }
            for p in 0..n_panels {
                let start = self.panel_ptr[p] as usize;
                let end = self.panel_ptr[p + 1] as usize;
                let prow = p * MR;
                let rows_here = (rows - prow).min(MR);
                for q in 0..rows_here {
                    let mut acc = [_mm256_setzero_si256(); LANE_TILE];
                    for bi in start..end {
                        let kb = *self.block_kb.get_unchecked(bi) as usize;
                        let wv = _mm256_loadu_si256(
                            self.blocks.as_ptr().add(bi * BLOCK_BYTES + q * K_BLOCK)
                                as *const __m256i,
                        );
                        let (w_lo, w_hi) = widen_i8(wv);
                        let staged = k_tail != 0 && kb == full_blocks;
                        for (l, a) in acc.iter_mut().enumerate() {
                            let xp = if staged {
                                tails[l].as_ptr()
                            } else {
                                lanes[l].as_ptr().add(kb * K_BLOCK)
                            };
                            let xv = _mm256_loadu_si256(xp as *const __m256i);
                            let (x_lo, x_hi) = widen_i8(xv);
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_lo, x_lo));
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_hi, x_hi));
                        }
                    }
                    let bias = bias_at(folded_bias, prow + q);
                    for (l, a) in acc.iter().enumerate().take(live) {
                        out.data[(b + l) * rows + prow + q] = hsum_epi32(*a) + bias;
                    }
                }
            }
            b += live;
        }
    }

    /// Decompress back to dense (tests).
    pub fn to_dense(&self) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(self.rows, self.cols);
        let n_panels = self.rows.div_ceil(MR);
        for p in 0..n_panels {
            for bi in self.panel_ptr[p] as usize..self.panel_ptr[p + 1] as usize {
                let k0 = self.block_kb[bi] as usize * K_BLOCK;
                let kn = (self.cols - k0).min(K_BLOCK);
                for q in 0..MR {
                    let r = p * MR + q;
                    if r >= self.rows {
                        break;
                    }
                    w.row_mut(r)[k0..k0 + kn]
                        .copy_from_slice(&self.blocks[bi * BLOCK_BYTES + q * K_BLOCK..][..kn]);
                }
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::SparseMatrixI8;
    use crate::tensor::qmatmul::{matvec_i8_i32, tail_audit};
    use crate::util::{proptest, Pcg32};

    fn random_sparse_dense(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(rows, cols);
        for v in &mut w.data {
            if rng.next_f64() < density {
                *v = rng.range_i32(-127, 127) as i8;
            }
        }
        w
    }

    #[test]
    fn roundtrip_dense_bsr_dense() {
        proptest::check("bsr-roundtrip", |rng| {
            let rows = 1 + rng.below(40) as usize;
            let cols = 1 + rng.below(80) as usize;
            let density = [0.0, 0.1, 0.5, 1.0][rng.below(4) as usize];
            let w = random_sparse_dense(rng, rows, cols, density);
            let s = BlockSparseI8::from_dense(&w);
            assert_eq!(s.to_dense(), w);
        });
    }

    #[test]
    fn matvec_matches_dense_and_csr() {
        proptest::check("bsr-matvec", |rng| {
            let rows = 1 + rng.below(40) as usize;
            let cols = 1 + rng.below(80) as usize;
            let density = [0.05, 0.25, 0.5][rng.below(3) as usize];
            let w = random_sparse_dense(rng, rows, cols, density);
            let x: Vec<i8> =
                (0..cols).map(|_| rng.range_i32(-128, 127) as i8).collect();
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-1000, 1000)).collect();
            let s = BlockSparseI8::from_dense(&w);
            let csr = SparseMatrixI8::from_dense(&w);
            let mut dense_out = vec![0i32; rows];
            let mut bsr_out = vec![0i32; rows];
            let mut csr_out = vec![0i32; rows];
            matvec_i8_i32(&w, &x, &bias, &mut dense_out);
            s.matvec_i32(&x, &bias, &mut bsr_out);
            csr.matvec_i32(&x, &bias, &mut csr_out);
            assert_eq!(bsr_out, dense_out);
            assert_eq!(bsr_out, csr_out);
        });
    }

    #[test]
    fn gemm_matches_matvec_per_lane() {
        proptest::check("bsr-gemm-eq-matvec", |rng| {
            let rows = 1 + rng.below(70) as usize;
            let cols = 1 + rng.below(100) as usize;
            let batch = 1 + rng.below(9) as usize;
            let density = [0.05, 0.25, 0.5][rng.below(3) as usize];
            let w = random_sparse_dense(rng, rows, cols, density);
            let s = BlockSparseI8::from_dense(&w);
            let mut x = Matrix::<i8>::zeros(batch, cols);
            for v in &mut x.data {
                *v = rng.range_i32(-128, 127) as i8;
            }
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let mut out = Matrix::<i32>::zeros(batch, rows);
            s.gemm(&x, &bias, &mut out);
            for b in 0..batch {
                let mut single = vec![0i32; rows];
                s.matvec_i32(x.row(b), &bias, &mut single);
                assert_eq!(out.row(b), &single[..], "lane {b}");
            }
        });
    }

    #[test]
    fn gemm_runs_tail_free() {
        // Ragged everything: rows 33, cols 47, odd batches. The block
        // kernel must never record scalar-tail work. (Release builds
        // compile the counter out; the CI debug jobs carry the check.)
        let mut rng = Pcg32::seeded(311);
        let w = random_sparse_dense(&mut rng, 33, 47, 0.3);
        let s = BlockSparseI8::from_dense(&w);
        tail_audit::reset();
        for &batch in &[1usize, 3, 5, 7, 8] {
            let mut x = Matrix::<i8>::zeros(batch, 47);
            for v in &mut x.data {
                *v = rng.range_i32(-128, 127) as i8;
            }
            let mut out = Matrix::<i32>::zeros(batch, 33);
            s.gemm(&x, &[], &mut out);
        }
        assert_eq!(tail_audit::count(), 0, "block-sparse kernel recorded tails");
    }

    #[test]
    #[should_panic]
    fn short_bias_slice_panics() {
        let mut w = Matrix::<i8>::zeros(3, 4);
        w.set(2, 1, 7);
        let s = BlockSparseI8::from_dense(&w);
        let x = vec![1i8; 4];
        let mut out = vec![0i32; 3];
        s.matvec_i32(&x, &[5, 6], &mut out);
    }

    #[test]
    fn empty_blocks_are_dropped() {
        // One non-zero in an otherwise zero 8x64 matrix: exactly one
        // block survives, and rows in empty panels still get their bias.
        let mut w = Matrix::<i8>::zeros(8, 64);
        w.set(5, 40, 3);
        let s = BlockSparseI8::from_dense(&w);
        assert_eq!(s.block_count(), 1);
        assert_eq!(s.nnz(), 1);
        let x = vec![2i8; 64];
        let bias: Vec<i32> = (0..8).map(|r| r as i32 * 10).collect();
        let mut out = vec![0i32; 8];
        s.matvec_i32(&x, &bias, &mut out);
        for (r, &o) in out.iter().enumerate() {
            let want = if r == 5 { 6 + 50 } else { r as i32 * 10 };
            assert_eq!(o, want, "row {r}");
        }
    }

    #[test]
    fn storage_shrinks_at_structured_sparsity() {
        // 128x128 with 3/4 of the blocks zeroed: BSR must come in well
        // under the dense byte count (CSR would not at this density).
        let mut rng = Pcg32::seeded(313);
        let mut w = random_sparse_dense(&mut rng, 128, 128, 1.0);
        let k_blocks = 128usize.div_ceil(K_BLOCK);
        for p in 0..128 / MR {
            for kb in 0..k_blocks {
                if (p + kb) % 4 != 0 {
                    for q in 0..MR {
                        let r = p * MR + q;
                        let k0 = kb * K_BLOCK;
                        w.row_mut(r)[k0..(k0 + K_BLOCK).min(128)].fill(0);
                    }
                }
            }
        }
        let s = BlockSparseI8::from_dense(&w);
        assert!(s.block_density() < 0.3, "density {}", s.block_density());
        assert!(
            s.storage_bytes() < 128 * 128 / 2,
            "bsr bytes {} vs dense {}",
            s.storage_bytes(),
            128 * 128
        );
        assert_eq!(
            s.storage_bytes(),
            s.block_count() * (BLOCK_BYTES + 2) + 4 * (128 / MR + 1)
        );
    }
}
