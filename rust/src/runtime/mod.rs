//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the CPU PJRT client, and
//! execute them from the serving path.
//!
//! The wrapper depends on the external `xla` crate (PJRT C API
//! bindings), which cannot be built in the offline environment, so the
//! whole module is gated behind the `xla-runtime` cargo feature. The
//! rest of the crate — including the entire integer inference stack and
//! the serving coordinator — builds and runs without it.

#[cfg(feature = "xla-runtime")]
pub mod pjrt;

#[cfg(feature = "xla-runtime")]
pub use pjrt::{CharLmRuntime, HloExecutable};
