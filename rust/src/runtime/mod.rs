//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the CPU PJRT client, and
//! execute them from the serving path.

pub mod pjrt;

pub use pjrt::{CharLmRuntime, HloExecutable};
