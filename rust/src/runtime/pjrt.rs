//! Thin wrapper over the `xla` crate (PJRT C API): HLO text →
//! `HloModuleProto` → compile → execute. The interchange is HLO *text*
//! because jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects (see DESIGN.md / aot.py).

use std::path::Path;

use anyhow::{ensure, Context, Result};

/// A compiled HLO module plus its client.
pub struct HloExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub path: String,
}

impl HloExecutable {
    /// Load + compile an HLO text artifact on a shared CPU client.
    pub fn load(client: &xla::PjRtClient, path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        Ok(HloExecutable { exe, path: path.display().to_string() })
    }

    /// Execute with literal inputs; returns the flattened result tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling: {e}"))
    }
}

/// 2-D f32 literal from a row-major slice.
pub fn literal_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    ensure!(data.len() == rows * cols);
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

/// Extract an f32 vec from a literal.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
}

/// The char-LM float serving runtime: the `model_b{B}.hlo.txt` artifact
/// executing one step for a fixed batch size.
///
/// Signature (from aot.py): `(x_onehot [B,V], c0, h0, c1, h1, ...) ->
/// (logits [B,V], c0', h0', c1', h1', ...)`.
pub struct CharLmRuntime {
    exe: HloExecutable,
    pub batch: usize,
    pub vocab: usize,
    pub hidden: usize,
    pub depth: usize,
}

/// Device-side state for one batch slot group.
pub struct RuntimeState {
    /// `[depth][2]` state tensors, each `[batch, hidden]` row-major.
    pub flat: Vec<Vec<f32>>,
}

impl CharLmRuntime {
    pub fn load(
        client: &xla::PjRtClient,
        artifacts_dir: impl AsRef<Path>,
        batch: usize,
        vocab: usize,
        hidden: usize,
        depth: usize,
    ) -> Result<Self> {
        let path = artifacts_dir
            .as_ref()
            .join(format!("model_b{batch}.hlo.txt"));
        let exe = HloExecutable::load(client, path)?;
        Ok(CharLmRuntime { exe, batch, vocab, hidden, depth })
    }

    pub fn zero_state(&self) -> RuntimeState {
        RuntimeState {
            flat: (0..2 * self.depth)
                .map(|_| vec![0f32; self.batch * self.hidden])
                .collect(),
        }
    }

    /// One step: `x` is `[batch * vocab]` one-hot rows; returns logits
    /// `[batch * vocab]` and updates `state` in place.
    pub fn step(&self, x: &[f32], state: &mut RuntimeState) -> Result<Vec<f32>> {
        let mut inputs = Vec::with_capacity(1 + 2 * self.depth);
        inputs.push(literal_f32_2d(x, self.batch, self.vocab)?);
        for s in &state.flat {
            inputs.push(literal_f32_2d(s, self.batch, self.hidden)?);
        }
        let outputs = self.exe.run(&inputs)?;
        ensure!(
            outputs.len() == 1 + 2 * self.depth,
            "expected {} outputs, got {}",
            1 + 2 * self.depth,
            outputs.len()
        );
        let logits = literal_to_f32(&outputs[0])?;
        for (i, out) in outputs.iter().skip(1).enumerate() {
            state.flat[i] = literal_to_f32(out)?;
        }
        Ok(logits)
    }
}
