//! Float matrix kernels — the float baseline of Table 1 and the
//! substrate for calibration.

use super::dense::Matrix;

/// 4-way unrolled dot product — the one accumulation order shared by
/// [`matvec_f32`] and [`gemm_f32`], so the batch-major path is bit-exact
/// with the sequential path (float accumulation order matters).
#[inline]
fn dot_f32(row: &[f32], x: &[f32]) -> f32 {
    let mut acc0 = 0f32;
    let mut acc1 = 0f32;
    let mut acc2 = 0f32;
    let mut acc3 = 0f32;
    let chunks = x.len() / 4 * 4;
    let mut c = 0;
    while c < chunks {
        acc0 += row[c] * x[c];
        acc1 += row[c + 1] * x[c + 1];
        acc2 += row[c + 2] * x[c + 2];
        acc3 += row[c + 3] * x[c + 3];
        c += 4;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks..x.len() {
        acc += row[i] * x[i];
    }
    acc
}

/// `out[r] = Σ_c w[r,c] * x[c]` — float matrix-vector product.
/// 4-way unrolled accumulation: keeps the float baseline honest so
/// the Table-1 speed ratios are not inflated by a strawman.
pub fn matvec_f32(w: &Matrix<f32>, x: &[f32], out: &mut [f32]) {
    assert_eq!(w.cols, x.len());
    assert_eq!(w.rows, out.len());
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot_f32(w.row(r), x);
    }
}

/// Batch-major float GEMM: `x` is `[batch, cols]` activations, `out` is
/// `[batch, rows]` with `out[b,r] = Σ_c w[r,c] * x[b,c]`. Batch lanes
/// are blocked in groups of [`crate::tensor::LANE_TILE`] so each weight
/// row stays cache-hot across lanes; every output element runs the
/// exact `dot_f32` accumulation, so results are bit-identical to
/// per-lane [`matvec_f32`].
///
/// The serving path shares the int8 kernels' lane-padding contract: the
/// batch state rounds its physical width up to the tile, so this kernel
/// always sees full 4-lane blocks there (pad lanes are zero rows whose
/// outputs are never read). Ragged widths from direct callers still
/// work — the remainder block just amortizes the weight pass over
/// fewer lanes.
pub fn gemm_f32(w: &Matrix<f32>, x: &Matrix<f32>, out: &mut Matrix<f32>) {
    assert_eq!(x.cols, w.cols);
    assert_eq!(out.rows, x.rows);
    assert_eq!(out.cols, w.rows);
    let mut b = 0usize;
    while b < x.rows {
        let bn = (x.rows - b).min(4);
        for r in 0..w.rows {
            let row = w.row(r);
            for i in 0..bn {
                out.data[(b + i) * w.rows + r] = dot_f32(row, x.row(b + i));
            }
        }
        b += bn;
    }
}

/// `out = a @ b` for row-major matrices.
pub fn matmul_f32(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols, b.rows);
    let mut out = Matrix::zeros(a.rows, b.cols);
    for r in 0..a.rows {
        for k in 0..a.cols {
            let av = a.at(r, k);
            if av == 0.0 {
                continue;
            }
            let brow = b.row(k);
            let orow = out.row_mut(r);
            for c in 0..b.cols {
                orow[c] += av * brow[c];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn matvec_small_known() {
        let w = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = [1.0, 0.5, -1.0];
        let mut out = [0.0; 2];
        matvec_f32(&w, &x, &mut out);
        assert_eq!(out, [-1.0, 0.5]);
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::seeded(5);
        let mut a = Matrix::<f32>::zeros(7, 5);
        let mut b = Matrix::<f32>::zeros(5, 9);
        rng.fill_uniform_f32(&mut a.data, -1.0, 1.0);
        rng.fill_uniform_f32(&mut b.data, -1.0, 1.0);
        let got = matmul_f32(&a, &b);
        for r in 0..7 {
            for c in 0..9 {
                let mut want = 0f32;
                for k in 0..5 {
                    want += a.at(r, k) * b.at(k, c);
                }
                assert!((got.at(r, c) - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gemm_bit_exact_with_matvec() {
        let mut rng = Pcg32::seeded(7);
        for &(rows, cols, batch) in &[(11usize, 13usize, 1usize), (8, 32, 4), (5, 7, 9)] {
            let mut w = Matrix::<f32>::zeros(rows, cols);
            rng.fill_uniform_f32(&mut w.data, -1.0, 1.0);
            let mut x = Matrix::<f32>::zeros(batch, cols);
            rng.fill_uniform_f32(&mut x.data, -2.0, 2.0);
            let mut out = Matrix::<f32>::zeros(batch, rows);
            gemm_f32(&w, &x, &mut out);
            for b in 0..batch {
                let mut single = vec![0f32; rows];
                matvec_f32(&w, x.row(b), &mut single);
                // Bit-exact, not approximately equal: the batch path
                // reuses the sequential accumulation order.
                assert_eq!(out.row(b), &single[..]);
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(6);
        let mut w = Matrix::<f32>::zeros(11, 13);
        rng.fill_uniform_f32(&mut w.data, -2.0, 2.0);
        let mut x = vec![0f32; 13];
        rng.fill_uniform_f32(&mut x, -2.0, 2.0);
        let xm = Matrix::from_vec(13, 1, x.clone());
        let want = matmul_f32(&w, &xm);
        let mut got = vec![0f32; 11];
        matvec_f32(&w, &x, &mut got);
        for r in 0..11 {
            assert!((got[r] - want.at(r, 0)).abs() < 1e-4);
        }
    }
}
