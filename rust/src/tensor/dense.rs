//! A minimal row-major matrix container, generic over the element type.

/// Row-major 2-D matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Copy + Default> Matrix<T> {
    /// All-default (zero) matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![T::default(); rows * cols] }
    }

    /// Build from a row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Map every element to a new matrix.
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Matrix<U> {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Drop rows `k..`, keeping the prefix in place (batch-major engines
    /// use this to shed finished lanes without repacking). `Vec::truncate`
    /// retains capacity, so shrinking never deallocates.
    pub fn truncate_rows(&mut self, k: usize) {
        assert!(k <= self.rows, "truncate {k} > rows {}", self.rows);
        self.rows = k;
        self.data.truncate(k * self.cols);
    }

    /// Copy row `src` over row `dst` in place (no-op when equal). The
    /// lane-compaction primitive of continuous batching: retiring a
    /// middle lane moves a survivor's row down so live lanes stay a
    /// dense prefix.
    pub fn copy_row_within(&mut self, src: usize, dst: usize) {
        debug_assert!(src < self.rows && dst < self.rows);
        if src == dst {
            return;
        }
        let c = self.cols;
        self.data.copy_within(src * c..(src + 1) * c, dst * c);
    }

    /// Resize to `rows × cols`, reusing the existing allocation when
    /// capacity suffices (the batch-scratch resize path: per-wave batch
    /// changes must not reallocate every buffer).
    pub fn resize(&mut self, rows: usize, cols: usize) {
        if self.cols != cols {
            self.cols = cols;
            self.data.clear();
        }
        self.rows = rows;
        self.data.resize(rows * cols, T::default());
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

impl Matrix<f32> {
    /// Maximum absolute value (used for symmetric quantization scales).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |m, &v| m.max(v.abs()))
    }

    /// (min, max) of all elements.
    pub fn min_max(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let mut m = Matrix::<f32>::zeros(2, 3);
        assert_eq!(m.len(), 6);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn from_vec_and_map() {
        let m = Matrix::from_vec(2, 2, vec![1.0f32, -2.0, 3.0, -4.5]);
        assert_eq!(m.max_abs(), 4.5);
        assert_eq!(m.min_max(), (-4.5, 3.0));
        let n = m.map(|v| (v * 2.0) as i32);
        assert_eq!(n.data, vec![2, -4, 6, -9]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn from_vec_checks_shape() {
        let _ = Matrix::from_vec(2, 2, vec![1.0f32]);
    }
}
