//! Dense tensors and the matrix-multiply kernels of the inference path.
//!
//! Matrix multiplication is the paper's "basic operation" (§3.1.1): the
//! quantized path is int8 × int8 → int32 with the zero-point
//! contribution folded into the bias offline (§6), so the inner loop is
//! a pure symmetric integer dot product.

pub mod dense;
pub mod matmul;
pub mod qmatmul;

pub use dense::Matrix;
pub use matmul::{gemm_f32, matmul_f32, matvec_f32};
pub use qmatmul::{
    fold_zero_point, gemm_i8_i32, kernel_counters, kernel_counters::KernelCounters,
    matvec_i8_i32, pad_lanes, PackedWeightsI4, PackedWeightsI8, K_BLOCK, LANE_TILE,
};
