//! Quantized integer matrix kernels: int8 × int8 → int32.
//!
//! The deployment optimization of §6 is implemented here: for an
//! asymmetric activation `x` with zero point `zp`, the gate computation
//! `Σ_j W[i,j] * (x[j] + zp)` is split into `Σ_j W[i,j] * x[j]` (the
//! hot loop, fully symmetric) plus the static `zp * Σ_j W[i,j]`, which
//! [`fold_zero_point`] precomputes into the bias offline. The paper
//! reports this makes integer LSTM ~5% faster than hybrid and ~2×
//! faster than float; `benches/deployment_speed.rs` measures both forms
//! (experiment E4).
//!
//! # The packed, register-tiled batched kernel
//!
//! The serving hot loop is [`PackedWeightsI8::gemm`]: the weight matrix
//! is packed **once, at quantization time**, into K-major panels of
//! [`MR`] output rows whose K extent is zero-padded to the 32-byte
//! `pmaddwd` block ([`K_BLOCK`]), and the batch dimension is
//! register-tiled in [`LANE_TILE`] lanes. Remainders never fall back to
//! scalar multiply-accumulate:
//!
//! * **K remainder** — the panel is zero-padded, so the last 32-byte
//!   block runs the same SIMD multiply-add (zero weights annihilate the
//!   padding); the activation's ragged tail is staged into a 32-byte
//!   buffer once per lane tile so loads never run off the row.
//! * **lane remainder** — a partial lane tile re-points its missing
//!   lanes at the tile's last live row; the redundant results are
//!   computed in registers and simply never written back.
//! * **row remainder** — the last panel's padding rows are skipped at
//!   the panel level (whole rows, never per-element tails).
//!
//! Integer accumulation is associative, so every tiling is bit-exact
//! with [`matvec_i8_i32`] per lane; `gemm_i8_i32_scalar` stays the
//! reference oracle and the non-AVX2 / `PALLAS_FORCE_SCALAR` fallback.
//! Debug builds count every scalar-tail multiply-accumulate the *old*
//! blocked kernel still executes in [`tail_audit`], which is how the
//! test suite proves the batched serving path runs tail-free for any
//! live-lane count and any `n_cell`.

use super::dense::Matrix;
#[cfg(target_arch = "x86_64")]
use crate::util::avx2_enabled;

/// Output rows per packed weight panel (the register tile height).
pub const MR: usize = 4;

/// Batch lanes per register tile. Batch states round their lane
/// capacity up to this width (dead lanes zeroed, never read back) so
/// the serving-path GEMMs always see full tiles.
pub const LANE_TILE: usize = 4;

/// K-dimension block in bytes: one 32-byte AVX2 load, sign-extended and
/// `pmaddwd`-accumulated.
pub const K_BLOCK: usize = 32;

/// Round a live lane count up to the register-tile width ([`LANE_TILE`]).
/// `pad_lanes(0) == 0`: an empty batch stays empty.
#[inline]
pub fn pad_lanes(lanes: usize) -> usize {
    lanes.div_ceil(LANE_TILE) * LANE_TILE
}

/// Debug-build audit of scalar-tail multiply-accumulate work in the
/// batched int8 kernels.
///
/// The packed kernel ([`PackedWeightsI8::gemm`](super::PackedWeightsI8::gemm))
/// records nothing — it has no scalar tails by construction. The
/// pre-packing blocked kernel ([`gemm_i8_i32`](super::gemm_i8_i32) on a
/// raw matrix) records its per-lane K tails and its remainder-lane
/// matvec fallback. Tests reset the counter, drive the batched serving
/// path over ragged shapes, and assert it stayed at zero. The counter
/// is **thread-local** (kernels never cross threads), so the assertion
/// is exact even under the parallel test harness. Release builds
/// compile the counter out ([`count`] always returns 0).
pub mod tail_audit {
    #[cfg(debug_assertions)]
    thread_local! {
        static TAIL_ITERS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
    }

    /// Record `n` scalar-tail multiply-accumulate iterations on the
    /// calling thread.
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    #[inline]
    pub(crate) fn record(n: usize) {
        #[cfg(debug_assertions)]
        if n > 0 {
            TAIL_ITERS.with(|c| c.set(c.get() + n));
        }
        #[cfg(not(debug_assertions))]
        let _ = n;
    }

    /// Reset the calling thread's tail counter to zero.
    pub fn reset() {
        #[cfg(debug_assertions)]
        TAIL_ITERS.with(|c| c.set(0));
    }

    /// Scalar-tail iterations the calling thread recorded since its
    /// last [`reset`] (always 0 in release builds).
    pub fn count() -> usize {
        #[cfg(debug_assertions)]
        let n = TAIL_ITERS.with(|c| c.get());
        #[cfg(not(debug_assertions))]
        let n = 0;
        n
    }
}

/// Bias lookup shared by every kernel (dense *and* sparse): an empty
/// slice means "no bias"; a *short* non-empty slice is a caller bug —
/// debug-asserted here, and the direct index still panics (never
/// silently zeroes) in release.
#[inline]
pub(crate) fn bias_at(folded_bias: &[i32], r: usize) -> i32 {
    if folded_bias.is_empty() {
        0
    } else {
        debug_assert!(
            r < folded_bias.len(),
            "folded bias has {} entries but row {r} was requested",
            folded_bias.len()
        );
        folded_bias[r]
    }
}

/// Inner dot product of two int8 slices with int32 accumulation,
/// dispatching to AVX2 (`pmaddwd`: sign-extend to i16, pairwise
/// multiply-add into i32 lanes) when available. Exactly equal to the
/// scalar sum for all inputs: every product fits i16×i16→i32 and
/// §3.1.1 bounds the accumulator.
#[inline]
fn dot_i8(row: &[i8], x: &[i8]) -> i32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: feature checked at runtime.
            return unsafe { dot_i8_avx2(row, x) };
        }
    }
    dot_i8_scalar(row, x)
}

#[inline]
fn dot_i8_scalar(row: &[i8], x: &[i8]) -> i32 {
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    let chunks = x.len() / 4 * 4;
    let mut c = 0;
    while c < chunks {
        acc0 += i32::from(row[c]) * i32::from(x[c]);
        acc1 += i32::from(row[c + 1]) * i32::from(x[c + 1]);
        acc2 += i32::from(row[c + 2]) * i32::from(x[c + 2]);
        acc3 += i32::from(row[c + 3]) * i32::from(x[c + 3]);
        c += 4;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks..x.len() {
        acc += i32::from(row[i]) * i32::from(x[i]);
    }
    acc
}

/// Horizontal sum of the 8 i32 lanes of an AVX2 accumulator.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
pub(crate) unsafe fn hsum_epi32(acc: std::arch::x86_64::__m256i) -> i32 {
    use std::arch::x86_64::*;
    let hi128 = _mm256_extracti128_si256(acc, 1);
    let lo128 = _mm256_castsi256_si128(acc);
    let sum128 = _mm_add_epi32(hi128, lo128);
    let shuf = _mm_add_epi32(sum128, _mm_shuffle_epi32(sum128, 0b00_00_11_10));
    let shuf2 = _mm_add_epi32(shuf, _mm_shuffle_epi32(shuf, 0b00_00_00_01));
    _mm_cvtsi128_si32(shuf2)
}

/// Sign-extend 32 packed int8 values to two 16×i16 registers.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
pub(crate) unsafe fn widen_i8(
    v: std::arch::x86_64::__m256i,
) -> (std::arch::x86_64::__m256i, std::arch::x86_64::__m256i) {
    use std::arch::x86_64::*;
    (
        _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v)),
        _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v, 1)),
    )
}

/// AVX2 int8 dot product: 32 bytes/iteration via two
/// sign-extend + `pmaddwd` + i32 adds.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(row: &[i8], x: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(row.len(), x.len());
    let n = row.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 32 <= n {
        let a8 = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
        let b8 = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        let (a_lo, a_hi) = widen_i8(a8);
        let (b_lo, b_hi) = widen_i8(b8);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_lo, b_lo));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_hi, b_hi));
        i += 32;
    }
    let mut total = hsum_epi32(acc);
    while i < n {
        total += i32::from(*row.get_unchecked(i)) * i32::from(*x.get_unchecked(i));
        i += 1;
    }
    total
}

/// Precompute the §6 zero-point fold: `bias'[i] = bias[i] + zp * Σ_j W[i,j]`.
///
/// `zp` is the zero point *added* to the stored int8 activation to
/// recover the affine value (i.e. the kernel computes `W (x + zp)`).
pub fn fold_zero_point(w: &Matrix<i8>, bias: &[i32], zp: i32) -> Vec<i32> {
    assert!(bias.is_empty() || bias.len() == w.rows);
    let mut folded = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let row_sum: i32 = w.row(r).iter().map(|&v| i32::from(v)).sum();
        let b = bias_at(bias, r);
        folded.push(b.wrapping_add(zp.wrapping_mul(row_sum)));
    }
    folded
}

/// Symmetric int8 matrix-vector product with int32 accumulation:
/// `out[r] = folded_bias[r] + Σ_c w[r,c] * x[c]`.
///
/// This is the §6-optimized inner loop: no zero-point arithmetic, no
/// branching, straight multiply-accumulate. §3.1.1 guarantees the int32
/// accumulator cannot overflow for depths below 2^15. `folded_bias` is
/// either empty or covers every row — a short slice panics instead of
/// silently reading zeros.
pub fn matvec_i8_i32(w: &Matrix<i8>, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
    assert_eq!(w.cols, x.len());
    assert_eq!(w.rows, out.len());
    debug_assert!(folded_bias.is_empty() || folded_bias.len() == w.rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot_i8(w.row(r), x) + bias_at(folded_bias, r);
    }
}

/// int8 weight matrix pre-packed for the register-tiled batched GEMM.
///
/// Packing happens **once** — at quantization time, owned by the cell
/// that owns the weights — not per step. The panel layout is K-major:
/// panel `p` covers output rows `p*MR .. p*MR+MR`; within a panel, each
/// [`K_BLOCK`]-byte block of the K dimension stores the [`MR`] rows'
/// 32-byte chunks back to back (`panels[p][kb][q][32]`). Rows past
/// `rows` and K past `cols` are zero — the padding that lets the AVX2
/// kernel run full 32-wide multiply-adds with no scalar remainder for
/// *any* shape.
///
/// The unpacked matrix is retained: the sequential path keeps its
/// row-major [`matvec_i8_i32`] access, and the scalar reference oracle
/// ([`gemm_i8_i32`]'s fallback) runs against it, so forced-scalar runs
/// execute a genuinely independent code path.
#[derive(Debug, Clone)]
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
pub struct PackedWeightsI8 {
    dense: Matrix<i8>,
    /// `ceil(rows/MR)` panels × `ceil(cols/K_BLOCK)` K blocks × MR rows
    /// × K_BLOCK bytes, zero-padded.
    panels: Vec<i8>,
    k_blocks: usize,
}

impl PackedWeightsI8 {
    /// Pack a dense int8 matrix into padded K-major panels.
    ///
    /// The panel copy only serves the AVX2 kernel: when that kernel can
    /// never run in this process (non-x86, CPU without AVX2, or
    /// `PALLAS_FORCE_SCALAR`), it is skipped entirely so scalar
    /// configurations do not pay double weight memory. Both this check
    /// and [`Self::gemm`]'s dispatch read the same cached switch, so
    /// they cannot disagree within one process.
    pub fn pack(dense: Matrix<i8>) -> Self {
        let k_blocks = dense.cols.div_ceil(K_BLOCK);
        let mut panels = Vec::new();
        if crate::util::avx2_enabled() {
            let n_panels = dense.rows.div_ceil(MR);
            panels = vec![0i8; n_panels * k_blocks * MR * K_BLOCK];
            for p in 0..n_panels {
                for kb in 0..k_blocks {
                    for q in 0..MR {
                        let r = p * MR + q;
                        if r >= dense.rows {
                            continue; // padding rows stay zero
                        }
                        let k0 = kb * K_BLOCK;
                        let kn = (dense.cols - k0).min(K_BLOCK);
                        let base = ((p * k_blocks + kb) * MR + q) * K_BLOCK;
                        panels[base..base + kn]
                            .copy_from_slice(&dense.row(r)[k0..k0 + kn]);
                    }
                }
            }
        }
        PackedWeightsI8 { dense, panels, k_blocks }
    }

    /// Logical row count (output features).
    pub fn rows(&self) -> usize {
        self.dense.rows
    }

    /// Logical column count (the K / reduction dimension).
    pub fn cols(&self) -> usize {
        self.dense.cols
    }

    /// The unpacked row-major matrix (sequential matvec path, scalar
    /// oracle, zero-point folding).
    pub fn dense(&self) -> &Matrix<i8> {
        &self.dense
    }

    /// Logical weight bytes (Table-1 size accounting counts the model,
    /// not the padded execution copy).
    pub fn storage_bytes(&self) -> usize {
        self.dense.len()
    }

    /// Sequential matrix-vector product over the unpacked rows —
    /// bit-exact with [`Self::gemm`] per lane.
    #[inline]
    pub fn matvec(&self, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
        matvec_i8_i32(&self.dense, x, folded_bias, out);
    }

    /// Register-tiled batched GEMM: `x` is `[batch, cols]` row-major
    /// activations, `out` is `[batch, rows]` with
    /// `out[b,r] = folded_bias[r] + Σ_c w[r,c] * x[b,c]`.
    ///
    /// On AVX2 this runs the padded panel kernel — zero scalar-tail
    /// iterations for any `batch` and any shape (see the module docs
    /// for how each remainder is absorbed). Without AVX2, or under
    /// `PALLAS_FORCE_SCALAR`, it runs the scalar reference oracle.
    /// Either way the result is bit-exact with per-lane
    /// [`matvec_i8_i32`].
    pub fn gemm(&self, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
        assert_eq!(x.cols, self.dense.cols);
        assert_eq!(out.rows, x.rows);
        assert_eq!(out.cols, self.dense.rows);
        debug_assert!(folded_bias.is_empty() || folded_bias.len() == self.dense.rows);
        if x.rows == 0 || self.dense.rows == 0 {
            return;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if avx2_enabled() {
                // SAFETY: feature checked at runtime.
                unsafe { self.gemm_avx2(x, folded_bias, out) };
                return;
            }
        }
        gemm_i8_i32_scalar(&self.dense, x, folded_bias, out);
    }

    /// The padded panel kernel: per lane tile (4 activation rows), per
    /// panel (4 weight rows), one row's accumulators run the full
    /// zero-padded K extent against all 4 lanes — each 32-byte weight
    /// chunk is sign-extended once and `pmaddwd`-accumulated four
    /// times. No scalar multiply-accumulate anywhere.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn gemm_avx2(
        &self,
        x: &Matrix<i8>,
        folded_bias: &[i32],
        out: &mut Matrix<i32>,
    ) {
        use std::arch::x86_64::*;
        let rows = self.dense.rows;
        let cols = self.dense.cols;
        let k_blocks = self.k_blocks;
        let k_tail = cols % K_BLOCK;
        let full_blocks = cols / K_BLOCK;
        let panel_stride = k_blocks * MR * K_BLOCK;
        let n_panels = rows.div_ceil(MR);

        // Staging for the ragged K tail: the last 32-byte block of each
        // lane is copied here so SIMD loads never run off the row. Bytes
        // past the tail are annihilated by the panel's zero padding, so
        // stale contents from a previous tile are harmless.
        let mut tails = [[0i8; K_BLOCK]; LANE_TILE];

        let mut b = 0usize;
        while b < x.rows {
            let live = (x.rows - b).min(LANE_TILE);
            // A partial tile re-points its missing lanes at the tile's
            // last live row: computed redundantly, never written back.
            let lanes: [&[i8]; LANE_TILE] =
                std::array::from_fn(|l| x.row(b + l.min(live - 1)));
            if k_tail != 0 {
                for (t, lane) in tails.iter_mut().zip(lanes.iter()) {
                    t[..k_tail].copy_from_slice(&lane[full_blocks * K_BLOCK..]);
                }
            }
            for p in 0..n_panels {
                let panel = self.panels.as_ptr().add(p * panel_stride);
                let prow = p * MR;
                let rows_here = (rows - prow).min(MR);
                for q in 0..rows_here {
                    let mut acc = [_mm256_setzero_si256(); LANE_TILE];
                    for kb in 0..k_blocks {
                        let wv = _mm256_loadu_si256(
                            panel.add((kb * MR + q) * K_BLOCK) as *const __m256i,
                        );
                        let (w_lo, w_hi) = widen_i8(wv);
                        let staged = k_tail != 0 && kb == full_blocks;
                        for (l, a) in acc.iter_mut().enumerate() {
                            let xp = if staged {
                                tails[l].as_ptr()
                            } else {
                                lanes[l].as_ptr().add(kb * K_BLOCK)
                            };
                            let xv = _mm256_loadu_si256(xp as *const __m256i);
                            let (x_lo, x_hi) = widen_i8(xv);
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_lo, x_lo));
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_hi, x_hi));
                        }
                    }
                    let bias = bias_at(folded_bias, prow + q);
                    for (l, a) in acc.iter().enumerate().take(live) {
                        out.data[(b + l) * rows + prow + q] = hsum_epi32(*a) + bias;
                    }
                }
            }
            b += live;
        }
    }
}

/// Blocked int8 × int8 → int32 GEMM over an *unpacked* weight matrix.
///
/// `x` is `[batch, cols]` row-major activations, `out` is `[batch,
/// rows]`: `out[b,r] = folded_bias[r] + Σ_c w[r,c] * x[b,c]`. The batch
/// dimension is register-tiled in blocks of 4 lanes; lane and K
/// remainders fall back to scalar tails (recorded in [`tail_audit`] in
/// debug builds). The serving path does not use this — it packs its
/// weights once into [`PackedWeightsI8`], whose kernel has no tails —
/// but it remains the batched entry point for ad-hoc matrices.
pub fn gemm_i8_i32(w: &Matrix<i8>, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
    assert_eq!(x.cols, w.cols);
    assert_eq!(out.rows, x.rows);
    assert_eq!(out.cols, w.rows);
    debug_assert!(folded_bias.is_empty() || folded_bias.len() == w.rows);
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: feature checked at runtime.
            unsafe { gemm_i8_i32_avx2(w, x, folded_bias, out) };
            return;
        }
    }
    gemm_i8_i32_scalar(w, x, folded_bias, out);
}

/// Scalar reference oracle: 4 batch lanes share each weight-row pass so
/// the row stays hot in cache. Bit-exact with every tiled kernel
/// (integer accumulation is associative); this is the execution path of
/// the `PALLAS_FORCE_SCALAR` CI job.
fn gemm_i8_i32_scalar(
    w: &Matrix<i8>,
    x: &Matrix<i8>,
    folded_bias: &[i32],
    out: &mut Matrix<i32>,
) {
    let mut b = 0usize;
    while b < x.rows {
        let bn = (x.rows - b).min(4);
        for r in 0..w.rows {
            let row = w.row(r);
            let bias = bias_at(folded_bias, r);
            for i in 0..bn {
                out.data[(b + i) * w.rows + r] = dot_i8_scalar(row, x.row(b + i)) + bias;
            }
        }
        b += bn;
    }
}

/// AVX2 inner kernel for unpacked weights: a 1×4 register tile — each
/// 32-byte weight-row chunk is sign-extended once and
/// `pmaddwd`-accumulated against four batch lanes. K remainders run
/// scalar per lane and remainder lanes (< 4) fall back to the matvec
/// kernel; both tails are recorded in [`tail_audit`] (debug builds).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_i8_i32_avx2(
    w: &Matrix<i8>,
    x: &Matrix<i8>,
    folded_bias: &[i32],
    out: &mut Matrix<i32>,
) {
    use std::arch::x86_64::*;

    let n = w.cols;
    let mut b = 0usize;
    while b + 4 <= x.rows {
        let lanes = [x.row(b), x.row(b + 1), x.row(b + 2), x.row(b + 3)];
        for r in 0..w.rows {
            let row = w.row(r);
            let mut acc = [_mm256_setzero_si256(); 4];
            let mut i = 0usize;
            while i + 32 <= n {
                let wv = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
                let (w_lo, w_hi) = widen_i8(wv);
                for (l, a) in lanes.iter().zip(acc.iter_mut()) {
                    let xv = _mm256_loadu_si256(l.as_ptr().add(i) as *const __m256i);
                    let (x_lo, x_hi) = widen_i8(xv);
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_lo, x_lo));
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_hi, x_hi));
                }
                i += 32;
            }
            tail_audit::record((n - i) * 4);
            let bias = bias_at(folded_bias, r);
            for (li, (l, a)) in lanes.iter().zip(acc.iter()).enumerate() {
                let mut total = hsum_epi32(*a);
                for j in i..n {
                    total += i32::from(*row.get_unchecked(j)) * i32::from(*l.get_unchecked(j));
                }
                out.data[(b + li) * w.rows + r] = total + bias;
            }
        }
        b += 4;
    }
    while b < x.rows {
        // Remainder lane: the whole lane runs the untiled matvec path.
        tail_audit::record(w.rows * w.cols);
        let or = &mut out.data[b * w.rows..(b + 1) * w.rows];
        matvec_i8_i32(w, x.row(b), folded_bias, or);
        b += 1;
    }
}

/// Unfolded (naive) variant that applies the zero point inside the inner
/// loop — kept for the E4 ablation of the §6 optimization and as a
/// correctness oracle for the folded kernel.
pub fn matvec_i8_i32_unfolded(
    w: &Matrix<i8>,
    x: &[i8],
    bias: &[i32],
    zp: i32,
    out: &mut [i32],
) {
    assert_eq!(w.cols, x.len());
    assert_eq!(w.rows, out.len());
    for (r, o) in out.iter_mut().enumerate() {
        let row = w.row(r);
        let mut acc = 0i64;
        for (wv, xv) in row.iter().zip(x) {
            acc += i64::from(*wv) * (i64::from(*xv) + i64::from(zp));
        }
        *o = (acc + i64::from(bias_at(bias, r))) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg32};

    fn random_w(rng: &mut Pcg32, rows: usize, cols: usize) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(rows, cols);
        for v in &mut w.data {
            *v = rng.range_i32(-127, 127) as i8;
        }
        w
    }

    fn random_x(rng: &mut Pcg32, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect()
    }

    fn random_batch(rng: &mut Pcg32, batch: usize, cols: usize) -> Matrix<i8> {
        let mut x = Matrix::<i8>::zeros(batch, cols);
        for v in &mut x.data {
            *v = rng.range_i32(-128, 127) as i8;
        }
        x
    }

    #[test]
    fn folded_equals_unfolded() {
        proptest::check("folded-eq-unfolded", |rng| {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(64) as usize;
            let w = random_w(rng, rows, cols);
            let x = random_x(rng, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let zp = rng.range_i32(-128, 127);
            let folded = fold_zero_point(&w, &bias, zp);
            let mut out_folded = vec![0i32; rows];
            let mut out_naive = vec![0i32; rows];
            matvec_i8_i32(&w, &x, &folded, &mut out_folded);
            matvec_i8_i32_unfolded(&w, &x, &bias, zp, &mut out_naive);
            assert_eq!(out_folded, out_naive);
        });
    }

    #[test]
    fn matches_float_reference() {
        let mut rng = Pcg32::seeded(17);
        let rows = 16;
        let cols = 128;
        let w = random_w(&mut rng, rows, cols);
        let x = random_x(&mut rng, cols);
        let mut out = vec![0i32; rows];
        matvec_i8_i32(&w, &x, &[], &mut out);
        for r in 0..rows {
            let want: i64 = w
                .row(r)
                .iter()
                .zip(&x)
                .map(|(&a, &b)| i64::from(a) * i64::from(b))
                .sum();
            assert_eq!(i64::from(out[r]), want);
        }
    }

    #[test]
    #[should_panic]
    fn short_bias_slice_panics() {
        let w = Matrix::from_vec(3, 2, vec![1i8; 6]);
        let x = vec![1i8; 2];
        let mut out = vec![0i32; 3];
        // Two bias entries for three rows: must panic, never silently
        // read a zero for row 2.
        matvec_i8_i32(&w, &x, &[5, 6], &mut out);
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Pcg32::seeded(23);
        let w = random_w(&mut rng, 8, 32);
        let x = random_batch(&mut rng, 4, 32);
        let bias: Vec<i32> = (0..8).map(|_| rng.range_i32(-100, 100)).collect();
        let mut out = Matrix::<i32>::zeros(4, 8);
        gemm_i8_i32(&w, &x, &bias, &mut out);
        for b in 0..4 {
            let mut single = vec![0i32; 8];
            matvec_i8_i32(&w, x.row(b), &bias, &mut single);
            assert_eq!(out.row(b), &single[..]);
        }
    }

    #[test]
    fn gemm_matches_matvec_per_lane() {
        // The batch-major GEMM must be bit-exact with the per-lane
        // matvec for every shape, including non-multiple-of-32 depths
        // and non-multiple-of-4 batches (tile remainders).
        proptest::check("gemm-i8-eq-matvec", |rng| {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(80) as usize;
            let batch = 1 + rng.below(9) as usize;
            let w = random_w(rng, rows, cols);
            let x = random_batch(rng, batch, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let mut out = Matrix::<i32>::zeros(batch, rows);
            gemm_i8_i32(&w, &x, &bias, &mut out);
            for b in 0..batch {
                let mut single = vec![0i32; rows];
                matvec_i8_i32(&w, x.row(b), &bias, &mut single);
                assert_eq!(out.row(b), &single[..], "lane {b}");
            }
        });
    }

    #[test]
    fn gemm_scalar_matches_dispatch() {
        let mut rng = Pcg32::seeded(41);
        let w = random_w(&mut rng, 13, 70);
        let x = random_batch(&mut rng, 6, 70);
        let bias: Vec<i32> = (0..13).map(|_| rng.range_i32(-500, 500)).collect();
        let mut out_a = Matrix::<i32>::zeros(6, 13);
        let mut out_b = Matrix::<i32>::zeros(6, 13);
        gemm_i8_i32(&w, &x, &bias, &mut out_a);
        gemm_i8_i32_scalar(&w, &x, &bias, &mut out_b);
        assert_eq!(out_a.data, out_b.data);
    }

    #[test]
    fn packed_matches_scalar_on_pinned_ragged_shapes() {
        // The acceptance grid: every n_cell × batch combination the
        // continuous batcher actually produces after compaction —
        // single rows, 32±1 depths, and odd live-lane counts.
        let mut rng = Pcg32::seeded(61);
        for &rows in &[1usize, 31, 33, 100] {
            for &cols in &[1usize, 31, 32, 33, 100] {
                for &batch in &[1usize, 3, 5, 7] {
                    let w = random_w(&mut rng, rows, cols);
                    let packed = PackedWeightsI8::pack(w.clone());
                    let x = random_batch(&mut rng, batch, cols);
                    let bias: Vec<i32> =
                        (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
                    let mut got = Matrix::<i32>::zeros(batch, rows);
                    let mut want = Matrix::<i32>::zeros(batch, rows);
                    packed.gemm(&x, &bias, &mut got);
                    gemm_i8_i32_scalar(&w, &x, &bias, &mut want);
                    assert_eq!(
                        got.data, want.data,
                        "rows={rows} cols={cols} batch={batch}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_matches_matvec_property() {
        proptest::check("packed-gemm-eq-matvec", |rng| {
            let rows = 1 + rng.below(70) as usize;
            let cols = 1 + rng.below(100) as usize;
            let batch = 1 + rng.below(9) as usize;
            let w = random_w(rng, rows, cols);
            let packed = PackedWeightsI8::pack(w);
            let x = random_batch(rng, batch, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let mut out = Matrix::<i32>::zeros(batch, rows);
            packed.gemm(&x, &bias, &mut out);
            for b in 0..batch {
                let mut single = vec![0i32; rows];
                packed.matvec(x.row(b), &bias, &mut single);
                assert_eq!(out.row(b), &single[..], "lane {b}");
            }
        });
    }

    #[test]
    fn packed_extreme_magnitudes() {
        // Worst-case accumulation across ragged shapes: all-(-127)
        // weights against all-(-128) activations.
        for &(rows, cols) in &[(5usize, 33usize), (4, 32), (7, 95), (1, 1)] {
            let w = Matrix::from_vec(rows, cols, vec![-127i8; rows * cols]);
            let packed = PackedWeightsI8::pack(w);
            let x = Matrix::from_vec(3, cols, vec![-128i8; 3 * cols]);
            let mut out = Matrix::<i32>::zeros(3, rows);
            packed.gemm(&x, &[], &mut out);
            for &v in &out.data {
                assert_eq!(v, 127 * 128 * cols as i32);
            }
        }
    }

    #[test]
    fn packed_roundtrip_preserves_dense() {
        let mut rng = Pcg32::seeded(71);
        let w = random_w(&mut rng, 9, 37);
        let packed = PackedWeightsI8::pack(w.clone());
        assert_eq!(packed.dense().data, w.data);
        assert_eq!(packed.rows(), 9);
        assert_eq!(packed.cols(), 37);
        assert_eq!(packed.storage_bytes(), 9 * 37);
    }

    #[test]
    fn packed_kernel_runs_tail_free() {
        // The packed path must never record scalar-tail work, no matter
        // how ragged the shape; the counter is thread-local, so this is
        // exact even under the parallel test harness. (Release builds
        // compile the counter out and the assertion degenerates to
        // 0 == 0 — the CI debug jobs carry the real check.)
        let mut rng = Pcg32::seeded(83);
        let w = random_w(&mut rng, 33, 47);
        let packed = PackedWeightsI8::pack(w.clone());
        let x = random_batch(&mut rng, 5, 47);
        let mut out = Matrix::<i32>::zeros(5, 33);
        // Positive control first: the unpacked AVX2 kernel on the same
        // ragged shape does record tails.
        if crate::util::avx2_enabled() && cfg!(debug_assertions) {
            tail_audit::reset();
            gemm_i8_i32(&w, &x, &[], &mut out);
            assert!(
                tail_audit::count() > 0,
                "unpacked kernel should record K/lane tails on 5x47"
            );
        }
        tail_audit::reset();
        for &batch in &[1usize, 3, 5, 7, 8] {
            let xb = random_batch(&mut rng, batch, 47);
            let mut ob = Matrix::<i32>::zeros(batch, 33);
            packed.gemm(&xb, &[], &mut ob);
        }
        assert_eq!(
            tail_audit::count(),
            0,
            "packed kernel recorded scalar tails"
        );
    }

    #[test]
    fn no_overflow_at_max_magnitude_depth() {
        // §3.1.1: int8×int8 into int32 is safe for depths < 2^15. At the
        // extreme all-(-127)·all-(-128) case with depth 4096 the
        // accumulator reaches 127*128*4096 = 2^26-ish — well inside i32.
        let cols = 4096;
        let w = Matrix::from_vec(1, cols, vec![-127i8; cols]);
        let x = vec![-128i8; cols];
        let mut out = vec![0i32; 1];
        matvec_i8_i32(&w, &x, &[], &mut out);
        assert_eq!(out[0], 127 * 128 * cols as i32);
    }

    #[test]
    fn pad_lanes_rounds_to_tile() {
        assert_eq!(pad_lanes(0), 0);
        assert_eq!(pad_lanes(1), 4);
        assert_eq!(pad_lanes(4), 4);
        assert_eq!(pad_lanes(5), 8);
        assert_eq!(pad_lanes(7), 8);
        assert_eq!(pad_lanes(8), 8);
        assert_eq!(pad_lanes(9), 12);
    }
}

#[cfg(test)]
mod simd_tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn simd_dot_equals_scalar() {
        proptest::check("dot-i8-simd-vs-scalar", |rng| {
            let n = rng.below(300) as usize;
            let a: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
            assert_eq!(dot_i8(&a, &b), dot_i8_scalar(&a, &b));
        });
    }

    #[test]
    fn simd_dot_extreme_values() {
        // Worst-case magnitudes across non-multiple-of-32 lengths.
        for n in [0usize, 1, 31, 32, 33, 63, 64, 65, 255, 2048] {
            let a = vec![-128i8; n];
            let b = vec![-128i8; n];
            assert_eq!(dot_i8(&a, &b), (n as i32) * 128 * 128);
            let c = vec![127i8; n];
            assert_eq!(dot_i8(&a, &c), (n as i32) * -128 * 127);
        }
    }
}
