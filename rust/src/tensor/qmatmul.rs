//! Quantized integer matrix kernels: int8 × int8 → int32.
//!
//! The deployment optimization of §6 is implemented here: for an
//! asymmetric activation `x` with zero point `zp`, the gate computation
//! `Σ_j W[i,j] * (x[j] + zp)` is split into `Σ_j W[i,j] * x[j]` (the
//! hot loop, fully symmetric) plus the static `zp * Σ_j W[i,j]`, which
//! [`fold_zero_point`] precomputes into the bias offline. The paper
//! reports this makes integer LSTM ~5% faster than hybrid and ~2×
//! faster than float; `benches/deployment_speed.rs` measures both forms
//! (experiment E4).
//!
//! # The packed, register-tiled batched kernel
//!
//! The serving hot loop is [`PackedWeightsI8::gemm`]: the weight matrix
//! is packed **once, at quantization time**, into K-major panels of
//! [`MR`] output rows whose K extent is zero-padded to the 32-byte
//! `pmaddwd` block ([`K_BLOCK`]), and the batch dimension is
//! register-tiled in [`LANE_TILE`] lanes. Remainders never fall back to
//! scalar multiply-accumulate:
//!
//! * **K remainder** — the panel is zero-padded, so the last 32-byte
//!   block runs the same SIMD multiply-add (zero weights annihilate the
//!   padding); the activation's ragged tail is staged into a 32-byte
//!   buffer once per lane tile so loads never run off the row.
//! * **lane remainder** — a partial lane tile re-points its missing
//!   lanes at the tile's last live row; the redundant results are
//!   computed in registers and simply never written back.
//! * **row remainder** — the last panel's padding rows are skipped at
//!   the panel level (whole rows, never per-element tails).
//!
//! Integer accumulation is associative, so every tiling is bit-exact
//! with [`matvec_i8_i32`] per lane; `gemm_i8_i32_scalar` stays the
//! reference oracle and the non-AVX2 / `PALLAS_FORCE_SCALAR` fallback.
//! Debug builds count every scalar-tail multiply-accumulate the *old*
//! blocked kernel still executes in [`tail_audit`], which is how the
//! test suite proves the batched serving path runs tail-free for any
//! live-lane count and any `n_cell`.
//!
//! # Int4 nibble panels
//!
//! [`PackedWeightsI4`] is the same panel geometry at half the bytes:
//! weights are quantized to the symmetric range −7..7 (so the stored
//! nibble is plain 4-bit two's complement and unpack is shift/mask +
//! sign-extend, no offset fixup), nibble-packed two-per-byte at pack
//! time, and unpacked to i8 **in-register** inside the GEMM — the
//! `pmaddwd` FMA and the whole padding contract above are unchanged.
//! See `docs/QUANTIZATION.md` for the byte-level layout of both panel
//! formats.

use super::dense::Matrix;
#[cfg(target_arch = "x86_64")]
use crate::util::avx2_enabled;

/// Output rows per packed weight panel (the register tile height).
pub const MR: usize = 4;

/// Batch lanes per register tile. Batch states round their lane
/// capacity up to this width (dead lanes zeroed, never read back) so
/// the serving-path GEMMs always see full tiles.
pub const LANE_TILE: usize = 4;

/// K-dimension block in bytes: one 32-byte AVX2 load, sign-extended and
/// `pmaddwd`-accumulated.
pub const K_BLOCK: usize = 32;

/// Round a live lane count up to the register-tile width ([`LANE_TILE`]).
/// `pad_lanes(0) == 0`: an empty batch stays empty.
#[inline]
pub fn pad_lanes(lanes: usize) -> usize {
    lanes.div_ceil(LANE_TILE) * LANE_TILE
}

/// Debug-build audit of scalar-tail multiply-accumulate work in the
/// batched int8 kernels.
///
/// The packed kernel ([`PackedWeightsI8::gemm`](super::PackedWeightsI8::gemm))
/// records nothing — it has no scalar tails by construction. The
/// pre-packing blocked kernel ([`gemm_i8_i32`](super::gemm_i8_i32) on a
/// raw matrix) records its per-lane K tails and its remainder-lane
/// matvec fallback. Tests reset the counter, drive the batched serving
/// path over ragged shapes, and assert it stayed at zero. The counter
/// is **thread-local** (kernels never cross threads), so the assertion
/// is exact even under the parallel test harness. Release builds
/// compile the counter out ([`count`] always returns 0).
pub mod tail_audit {
    #[cfg(debug_assertions)]
    thread_local! {
        static TAIL_ITERS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
    }

    /// Record `n` scalar-tail multiply-accumulate iterations on the
    /// calling thread.
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    #[inline]
    pub(crate) fn record(n: usize) {
        #[cfg(debug_assertions)]
        if n > 0 {
            TAIL_ITERS.with(|c| c.set(c.get() + n));
        }
        #[cfg(not(debug_assertions))]
        let _ = n;
    }

    /// Reset the calling thread's tail counter to zero.
    pub fn reset() {
        #[cfg(debug_assertions)]
        TAIL_ITERS.with(|c| c.set(0));
    }

    /// Scalar-tail iterations the calling thread recorded since its
    /// last [`reset`] (always 0 in release builds).
    pub fn count() -> usize {
        #[cfg(debug_assertions)]
        let n = TAIL_ITERS.with(|c| c.get());
        #[cfg(not(debug_assertions))]
        let n = 0;
        n
    }
}

/// Per-format GEMM invocation and MAC counters, live in release builds.
///
/// Same shape as [`tail_audit`] — a thread-local `Cell`, so the hot
/// path pays two register-width loads and one store per *GEMM call*
/// (not per MAC; counts are computed from the shapes) and no
/// synchronization ever. Unlike `tail_audit` this is **not** compiled
/// out in release: the serving report's effective-FLOP attribution and
/// the `ablations.rs` measured-MAC columns come from here, and those
/// claims are only worth making on release-mode kernels.
///
/// Dense int8/int4 counts are *logical* MACs (`batch × rows × cols` —
/// zero-padding work is part of the format's cost and is included).
/// The BSR count is *executed* MACs (`batch × stored_blocks × MR ×
/// K_BLOCK`), which is exactly what makes the dense-vs-sparse
/// comparison in the bench a measurement instead of arithmetic.
///
/// Consumers must bracket a measurement with [`reset`] / [`take`]:
/// counters accumulate per thread, so unpaired reads attribute earlier
/// unrelated GEMMs (e.g. another scheduler on the same test thread) to
/// the wrong measurement.
pub mod kernel_counters {
    use std::cell::Cell;

    /// GEMM invocations and multiply-accumulate counts by weight
    /// format.
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct KernelCounters {
        /// Dense int8 packed-panel GEMM calls.
        pub gemm_i8: u64,
        /// Logical MACs through the dense int8 GEMM.
        pub macs_i8: u64,
        /// Int4 nibble-panel GEMM calls.
        pub gemm_i4: u64,
        /// Logical MACs through the int4 GEMM.
        pub macs_i4: u64,
        /// Block-sparse (BSR) GEMM calls.
        pub gemm_bsr: u64,
        /// Executed MACs through the BSR GEMM (stored blocks only).
        pub macs_bsr: u64,
    }

    impl KernelCounters {
        /// Accumulate another snapshot into this one.
        pub fn add(&mut self, other: &KernelCounters) {
            self.gemm_i8 += other.gemm_i8;
            self.macs_i8 += other.macs_i8;
            self.gemm_i4 += other.gemm_i4;
            self.macs_i4 += other.macs_i4;
            self.gemm_bsr += other.gemm_bsr;
            self.macs_bsr += other.macs_bsr;
        }

        /// Total GEMM invocations across formats.
        pub fn total_gemms(&self) -> u64 {
            self.gemm_i8 + self.gemm_i4 + self.gemm_bsr
        }

        /// Total MACs across formats.
        pub fn total_macs(&self) -> u64 {
            self.macs_i8 + self.macs_i4 + self.macs_bsr
        }

        /// True when nothing was recorded.
        pub fn is_empty(&self) -> bool {
            self.total_gemms() == 0
        }
    }

    thread_local! {
        static COUNTERS: Cell<KernelCounters> =
            const { Cell::new(KernelCounters {
                gemm_i8: 0,
                macs_i8: 0,
                gemm_i4: 0,
                macs_i4: 0,
                gemm_bsr: 0,
                macs_bsr: 0,
            }) };
    }

    /// Record one dense int8 GEMM of `macs` logical MACs.
    #[inline]
    pub(crate) fn record_i8(macs: u64) {
        COUNTERS.with(|c| {
            let mut k = c.get();
            k.gemm_i8 += 1;
            k.macs_i8 += macs;
            c.set(k);
        });
    }

    /// Record one int4 GEMM of `macs` logical MACs.
    #[inline]
    pub(crate) fn record_i4(macs: u64) {
        COUNTERS.with(|c| {
            let mut k = c.get();
            k.gemm_i4 += 1;
            k.macs_i4 += macs;
            c.set(k);
        });
    }

    /// Record one BSR GEMM of `macs` executed MACs.
    #[inline]
    pub(crate) fn record_bsr(macs: u64) {
        COUNTERS.with(|c| {
            let mut k = c.get();
            k.gemm_bsr += 1;
            k.macs_bsr += macs;
            c.set(k);
        });
    }

    /// Zero the calling thread's counters (start of a measurement).
    pub fn reset() {
        COUNTERS.with(|c| c.set(KernelCounters::default()));
    }

    /// Read and zero the calling thread's counters (end of a
    /// measurement).
    pub fn take() -> KernelCounters {
        COUNTERS.with(|c| c.replace(KernelCounters::default()))
    }

    /// Read the calling thread's counters without resetting.
    pub fn snapshot() -> KernelCounters {
        COUNTERS.with(|c| c.get())
    }
}

/// Bias lookup shared by every kernel (dense *and* sparse): an empty
/// slice means "no bias"; a *short* non-empty slice is a caller bug —
/// debug-asserted here, and the direct index still panics (never
/// silently zeroes) in release.
#[inline]
pub(crate) fn bias_at(folded_bias: &[i32], r: usize) -> i32 {
    if folded_bias.is_empty() {
        0
    } else {
        debug_assert!(
            r < folded_bias.len(),
            "folded bias has {} entries but row {r} was requested",
            folded_bias.len()
        );
        folded_bias[r]
    }
}

/// Inner dot product of two int8 slices with int32 accumulation,
/// dispatching to AVX2 (`pmaddwd`: sign-extend to i16, pairwise
/// multiply-add into i32 lanes) when available. Exactly equal to the
/// scalar sum for all inputs: every product fits i16×i16→i32 and
/// §3.1.1 bounds the accumulator.
#[inline]
fn dot_i8(row: &[i8], x: &[i8]) -> i32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: feature checked at runtime.
            return unsafe { dot_i8_avx2(row, x) };
        }
    }
    dot_i8_scalar(row, x)
}

#[inline]
fn dot_i8_scalar(row: &[i8], x: &[i8]) -> i32 {
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    let chunks = x.len() / 4 * 4;
    let mut c = 0;
    while c < chunks {
        acc0 += i32::from(row[c]) * i32::from(x[c]);
        acc1 += i32::from(row[c + 1]) * i32::from(x[c + 1]);
        acc2 += i32::from(row[c + 2]) * i32::from(x[c + 2]);
        acc3 += i32::from(row[c + 3]) * i32::from(x[c + 3]);
        c += 4;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks..x.len() {
        acc += i32::from(row[i]) * i32::from(x[i]);
    }
    acc
}

/// Horizontal sum of the 8 i32 lanes of an AVX2 accumulator.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
pub(crate) unsafe fn hsum_epi32(acc: std::arch::x86_64::__m256i) -> i32 {
    use std::arch::x86_64::*;
    let hi128 = _mm256_extracti128_si256(acc, 1);
    let lo128 = _mm256_castsi256_si128(acc);
    let sum128 = _mm_add_epi32(hi128, lo128);
    let shuf = _mm_add_epi32(sum128, _mm_shuffle_epi32(sum128, 0b00_00_11_10));
    let shuf2 = _mm_add_epi32(shuf, _mm_shuffle_epi32(shuf, 0b00_00_00_01));
    _mm_cvtsi128_si32(shuf2)
}

/// Sign-extend 32 packed int8 values to two 16×i16 registers.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
pub(crate) unsafe fn widen_i8(
    v: std::arch::x86_64::__m256i,
) -> (std::arch::x86_64::__m256i, std::arch::x86_64::__m256i) {
    use std::arch::x86_64::*;
    (
        _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v)),
        _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v, 1)),
    )
}

/// AVX2 int8 dot product: 32 bytes/iteration via two
/// sign-extend + `pmaddwd` + i32 adds.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(row: &[i8], x: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(row.len(), x.len());
    let n = row.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 32 <= n {
        let a8 = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
        let b8 = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        let (a_lo, a_hi) = widen_i8(a8);
        let (b_lo, b_hi) = widen_i8(b8);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_lo, b_lo));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_hi, b_hi));
        i += 32;
    }
    let mut total = hsum_epi32(acc);
    while i < n {
        total += i32::from(*row.get_unchecked(i)) * i32::from(*x.get_unchecked(i));
        i += 1;
    }
    total
}

/// Precompute the §6 zero-point fold: `bias'[i] = bias[i] + zp * Σ_j W[i,j]`.
///
/// `zp` is the zero point *added* to the stored int8 activation to
/// recover the affine value (i.e. the kernel computes `W (x + zp)`).
pub fn fold_zero_point(w: &Matrix<i8>, bias: &[i32], zp: i32) -> Vec<i32> {
    assert!(bias.is_empty() || bias.len() == w.rows);
    let mut folded = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let row_sum: i32 = w.row(r).iter().map(|&v| i32::from(v)).sum();
        let b = bias_at(bias, r);
        folded.push(b.wrapping_add(zp.wrapping_mul(row_sum)));
    }
    folded
}

/// Symmetric int8 matrix-vector product with int32 accumulation:
/// `out[r] = folded_bias[r] + Σ_c w[r,c] * x[c]`.
///
/// This is the §6-optimized inner loop: no zero-point arithmetic, no
/// branching, straight multiply-accumulate. §3.1.1 guarantees the int32
/// accumulator cannot overflow for depths below 2^15. `folded_bias` is
/// either empty or covers every row — a short slice panics instead of
/// silently reading zeros.
pub fn matvec_i8_i32(w: &Matrix<i8>, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
    assert_eq!(w.cols, x.len());
    assert_eq!(w.rows, out.len());
    debug_assert!(folded_bias.is_empty() || folded_bias.len() == w.rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot_i8(w.row(r), x) + bias_at(folded_bias, r);
    }
}

/// int8 weight matrix pre-packed for the register-tiled batched GEMM.
///
/// Packing happens **once** — at quantization time, owned by the cell
/// that owns the weights — not per step. The panel layout is K-major:
/// panel `p` covers output rows `p*MR .. p*MR+MR`; within a panel, each
/// [`K_BLOCK`]-byte block of the K dimension stores the [`MR`] rows'
/// 32-byte chunks back to back (`panels[p][kb][q][32]`). Rows past
/// `rows` and K past `cols` are zero — the padding that lets the AVX2
/// kernel run full 32-wide multiply-adds with no scalar remainder for
/// *any* shape.
///
/// The unpacked matrix is retained: the sequential path keeps its
/// row-major [`matvec_i8_i32`] access, and the scalar reference oracle
/// ([`gemm_i8_i32`]'s fallback) runs against it, so forced-scalar runs
/// execute a genuinely independent code path.
#[derive(Debug, Clone)]
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
pub struct PackedWeightsI8 {
    dense: Matrix<i8>,
    /// `ceil(rows/MR)` panels × `ceil(cols/K_BLOCK)` K blocks × MR rows
    /// × K_BLOCK bytes, zero-padded.
    panels: Vec<i8>,
    k_blocks: usize,
}

impl PackedWeightsI8 {
    /// Pack a dense int8 matrix into padded K-major panels.
    ///
    /// The panel copy only serves the AVX2 kernel: when that kernel can
    /// never run in this process (non-x86, CPU without AVX2, or
    /// `PALLAS_FORCE_SCALAR`), it is skipped entirely so scalar
    /// configurations do not pay double weight memory. Both this check
    /// and [`Self::gemm`]'s dispatch read the same cached switch, so
    /// they cannot disagree within one process.
    pub fn pack(dense: Matrix<i8>) -> Self {
        let k_blocks = dense.cols.div_ceil(K_BLOCK);
        let mut panels = Vec::new();
        if crate::util::avx2_enabled() {
            let n_panels = dense.rows.div_ceil(MR);
            panels = vec![0i8; n_panels * k_blocks * MR * K_BLOCK];
            for p in 0..n_panels {
                for kb in 0..k_blocks {
                    for q in 0..MR {
                        let r = p * MR + q;
                        if r >= dense.rows {
                            continue; // padding rows stay zero
                        }
                        let k0 = kb * K_BLOCK;
                        let kn = (dense.cols - k0).min(K_BLOCK);
                        let base = ((p * k_blocks + kb) * MR + q) * K_BLOCK;
                        panels[base..base + kn]
                            .copy_from_slice(&dense.row(r)[k0..k0 + kn]);
                    }
                }
            }
        }
        PackedWeightsI8 { dense, panels, k_blocks }
    }

    /// Logical row count (output features).
    pub fn rows(&self) -> usize {
        self.dense.rows
    }

    /// Logical column count (the K / reduction dimension).
    pub fn cols(&self) -> usize {
        self.dense.cols
    }

    /// The unpacked row-major matrix (sequential matvec path, scalar
    /// oracle, zero-point folding).
    pub fn dense(&self) -> &Matrix<i8> {
        &self.dense
    }

    /// Logical weight bytes (Table-1 size accounting counts the model,
    /// not the padded execution copy).
    pub fn storage_bytes(&self) -> usize {
        self.dense.len()
    }

    /// Sequential matrix-vector product over the unpacked rows —
    /// bit-exact with [`Self::gemm`] per lane.
    #[inline]
    pub fn matvec(&self, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
        matvec_i8_i32(&self.dense, x, folded_bias, out);
    }

    /// Register-tiled batched GEMM: `x` is `[batch, cols]` row-major
    /// activations, `out` is `[batch, rows]` with
    /// `out[b,r] = folded_bias[r] + Σ_c w[r,c] * x[b,c]`.
    ///
    /// On AVX2 this runs the padded panel kernel — zero scalar-tail
    /// iterations for any `batch` and any shape (see the module docs
    /// for how each remainder is absorbed). Without AVX2, or under
    /// `PALLAS_FORCE_SCALAR`, it runs the scalar reference oracle.
    /// Either way the result is bit-exact with per-lane
    /// [`matvec_i8_i32`].
    pub fn gemm(&self, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
        assert_eq!(x.cols, self.dense.cols);
        assert_eq!(out.rows, x.rows);
        assert_eq!(out.cols, self.dense.rows);
        debug_assert!(folded_bias.is_empty() || folded_bias.len() == self.dense.rows);
        if x.rows == 0 || self.dense.rows == 0 {
            return;
        }
        kernel_counters::record_i8((x.rows * self.dense.rows * self.dense.cols) as u64);
        #[cfg(target_arch = "x86_64")]
        {
            if avx2_enabled() {
                // SAFETY: feature checked at runtime.
                unsafe { self.gemm_avx2(x, folded_bias, out) };
                return;
            }
        }
        gemm_i8_i32_scalar(&self.dense, x, folded_bias, out);
    }

    /// The padded panel kernel: per lane tile (4 activation rows), per
    /// panel (4 weight rows), one row's accumulators run the full
    /// zero-padded K extent against all 4 lanes — each 32-byte weight
    /// chunk is sign-extended once and `pmaddwd`-accumulated four
    /// times. No scalar multiply-accumulate anywhere.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn gemm_avx2(
        &self,
        x: &Matrix<i8>,
        folded_bias: &[i32],
        out: &mut Matrix<i32>,
    ) {
        use std::arch::x86_64::*;
        let rows = self.dense.rows;
        let cols = self.dense.cols;
        let k_blocks = self.k_blocks;
        let k_tail = cols % K_BLOCK;
        let full_blocks = cols / K_BLOCK;
        let panel_stride = k_blocks * MR * K_BLOCK;
        let n_panels = rows.div_ceil(MR);

        // Staging for the ragged K tail: the last 32-byte block of each
        // lane is copied here so SIMD loads never run off the row. Bytes
        // past the tail are annihilated by the panel's zero padding, so
        // stale contents from a previous tile are harmless.
        let mut tails = [[0i8; K_BLOCK]; LANE_TILE];

        let mut b = 0usize;
        while b < x.rows {
            let live = (x.rows - b).min(LANE_TILE);
            // A partial tile re-points its missing lanes at the tile's
            // last live row: computed redundantly, never written back.
            let lanes: [&[i8]; LANE_TILE] =
                std::array::from_fn(|l| x.row(b + l.min(live - 1)));
            if k_tail != 0 {
                for (t, lane) in tails.iter_mut().zip(lanes.iter()) {
                    t[..k_tail].copy_from_slice(&lane[full_blocks * K_BLOCK..]);
                }
            }
            for p in 0..n_panels {
                let panel = self.panels.as_ptr().add(p * panel_stride);
                let prow = p * MR;
                let rows_here = (rows - prow).min(MR);
                for q in 0..rows_here {
                    let mut acc = [_mm256_setzero_si256(); LANE_TILE];
                    for kb in 0..k_blocks {
                        let wv = _mm256_loadu_si256(
                            panel.add((kb * MR + q) * K_BLOCK) as *const __m256i,
                        );
                        let (w_lo, w_hi) = widen_i8(wv);
                        let staged = k_tail != 0 && kb == full_blocks;
                        for (l, a) in acc.iter_mut().enumerate() {
                            let xp = if staged {
                                tails[l].as_ptr()
                            } else {
                                lanes[l].as_ptr().add(kb * K_BLOCK)
                            };
                            let xv = _mm256_loadu_si256(xp as *const __m256i);
                            let (x_lo, x_hi) = widen_i8(xv);
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_lo, x_lo));
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_hi, x_hi));
                        }
                    }
                    let bias = bias_at(folded_bias, prow + q);
                    for (l, a) in acc.iter().enumerate().take(live) {
                        out.data[(b + l) * rows + prow + q] = hsum_epi32(*a) + bias;
                    }
                }
            }
            b += live;
        }
    }
}

/// Two's-complement encode of an int4 weight into its storage nibble.
/// The value must already be in the representable range `-8..=7`
/// (quantization clamps to the symmetric −7..7); anything wider is a
/// caller bug and panics — nibble wraparound would silently corrupt
/// the model.
#[inline]
fn nibble_of_i4(v: i8) -> u8 {
    assert!(
        (-8..=7).contains(&v),
        "int4 pack: weight {v} outside the representable range -8..=7"
    );
    (v as u8) & 0x0F
}

/// Sign-extend a storage nibble (already masked to 4 bits) back to the
/// signed int4 value: `(n ^ 8) - 8` maps `0..=7 -> 0..=7` and
/// `8..=15 -> -8..=-1`. The SIMD kernel runs the identical xor/sub on
/// 32 bytes at once.
#[inline]
fn i4_from_nibble(n: u8) -> i32 {
    debug_assert!(n < 16);
    i32::from(n ^ 8) - 8
}

/// int4 weight matrix, nibble-packed for the register-tiled batched
/// GEMM — [`PackedWeightsI8`]'s panel geometry at half the bytes.
///
/// Two storage forms, both built **once** at pack time:
///
/// * **Row-major nibbles** (`packed_rows`) — `ceil(cols/2)` bytes per
///   row; byte `k` of row `r` packs `w[r, 2k]` in its low nibble and
///   `w[r, 2k+1]` in its high nibble (an odd `cols` leaves the last
///   high nibble zero). This is the *only* copy counted by
///   [`Self::storage_bytes`] and the copy the sequential matvec and
///   scalar oracle read — there is no retained byte-per-weight matrix,
///   so resident weight memory genuinely halves.
/// * **K-major panels** (`panels`, AVX2 processes only) — the dense
///   kernel's `panels[p][kb][q]` layout with each [`K_BLOCK`]-column
///   chunk packed into `K_BLOCK/2 = 16` bytes: byte `j` holds
///   `w[k0 + j]` (low nibble) and `w[k0 + 16 + j]` (high nibble).
///   That split is chosen so one `vpand`/`vpsrlw`+`vpand` pair on the
///   16-byte load yields the 32 weights *in K order* across the two
///   128-bit halves of a `ymm` register — after the xor/sub
///   sign-extend, the unchanged [`widen_i8`] + `pmaddwd` flow of the
///   int8 kernel runs on it verbatim.
///
/// Padding follows the int8 panel contract exactly (rows past `rows`
/// and K past `cols` are zero nibbles, which decode to zero weights),
/// so the batched kernel absorbs every K/lane/row remainder with zero
/// scalar-tail multiply-accumulates — the same [`tail_audit`] proof
/// covers it.
#[derive(Debug, Clone)]
pub struct PackedWeightsI4 {
    rows: usize,
    cols: usize,
    /// Row-major nibble storage: `rows * ceil(cols/2)` bytes.
    packed_rows: Vec<u8>,
    /// `ceil(rows/MR)` panels × `ceil(cols/K_BLOCK)` K blocks × MR rows
    /// × `K_BLOCK/2` bytes, zero-padded; empty when the AVX2 kernel can
    /// never run in this process.
    panels: Vec<u8>,
    k_blocks: usize,
}

impl PackedWeightsI4 {
    /// Nibble-pack a dense int4-range matrix (every value in `-8..=7`,
    /// which symmetric −7..7 quantization guarantees; a wider value
    /// panics). Like [`PackedWeightsI8::pack`], the K-major panel copy
    /// is built only when the AVX2 kernel can actually run, so
    /// forced-scalar configurations do not pay double weight memory.
    pub fn pack(dense: &Matrix<i8>) -> Self {
        let rows = dense.rows;
        let cols = dense.cols;
        let row_bytes = cols.div_ceil(2);
        let k_blocks = cols.div_ceil(K_BLOCK);
        let mut packed_rows = vec![0u8; rows * row_bytes];
        for r in 0..rows {
            let src = dense.row(r);
            let dst = &mut packed_rows[r * row_bytes..(r + 1) * row_bytes];
            for (k, byte) in dst.iter_mut().enumerate() {
                let lo = nibble_of_i4(src[2 * k]);
                let hi = if 2 * k + 1 < cols { nibble_of_i4(src[2 * k + 1]) } else { 0 };
                *byte = lo | (hi << 4);
            }
        }
        let mut panels = Vec::new();
        if crate::util::avx2_enabled() {
            const NIB: usize = K_BLOCK / 2;
            let n_panels = rows.div_ceil(MR);
            panels = vec![0u8; n_panels * k_blocks * MR * NIB];
            for p in 0..n_panels {
                for kb in 0..k_blocks {
                    for q in 0..MR {
                        let r = p * MR + q;
                        if r >= rows {
                            continue; // padding rows stay zero nibbles
                        }
                        let src = dense.row(r);
                        let k0 = kb * K_BLOCK;
                        let base = ((p * k_blocks + kb) * MR + q) * NIB;
                        for j in 0..NIB {
                            let lo_k = k0 + j;
                            let hi_k = k0 + NIB + j;
                            let lo = if lo_k < cols { nibble_of_i4(src[lo_k]) } else { 0 };
                            let hi = if hi_k < cols { nibble_of_i4(src[hi_k]) } else { 0 };
                            panels[base + j] = lo | (hi << 4);
                        }
                    }
                }
            }
        }
        PackedWeightsI4 { rows, cols, packed_rows, panels, k_blocks }
    }

    /// Logical row count (output features).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical column count (the K / reduction dimension).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Logical weight bytes: `rows * ceil(cols/2)` — half the int8
    /// packing (plus at most one pad nibble per row). This is the
    /// number the registry's residency accounting and Table-1 size
    /// columns report; the AVX2 panel copy is an uncounted execution
    /// copy, exactly like the int8 panels.
    pub fn storage_bytes(&self) -> usize {
        self.packed_rows.len()
    }

    /// Decode one row's nibbles into `out` (`cols` values).
    fn unpack_row(&self, r: usize, out: &mut [i8]) {
        debug_assert_eq!(out.len(), self.cols);
        let row_bytes = self.cols.div_ceil(2);
        let src = &self.packed_rows[r * row_bytes..(r + 1) * row_bytes];
        for (c, o) in out.iter_mut().enumerate() {
            let byte = src[c / 2];
            let nib = if c % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            *o = i4_from_nibble(nib) as i8;
        }
    }

    /// Decode back to a dense int8 matrix (tests, re-quantization).
    pub fn to_dense(&self) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row_bytes = self.cols.div_ceil(2);
            let src = &self.packed_rows[r * row_bytes..(r + 1) * row_bytes];
            for c in 0..self.cols {
                let byte = src[c / 2];
                let nib = if c % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                w.set(r, c, i4_from_nibble(nib) as i8);
            }
        }
        w
    }

    /// Sequential matrix-vector product over the row-major nibbles —
    /// bit-exact with [`Self::gemm`] per lane (integer accumulation is
    /// associative, and every decoded pad nibble is zero).
    pub fn matvec(&self, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
        assert_eq!(self.cols, x.len());
        assert_eq!(self.rows, out.len());
        debug_assert!(folded_bias.is_empty() || folded_bias.len() == self.rows);
        let row_bytes = self.cols.div_ceil(2);
        let pairs = self.cols / 2;
        for (r, o) in out.iter_mut().enumerate() {
            let row = &self.packed_rows[r * row_bytes..(r + 1) * row_bytes];
            let mut acc = 0i32;
            for k in 0..pairs {
                let byte = row[k];
                acc += i4_from_nibble(byte & 0x0F) * i32::from(x[2 * k]);
                acc += i4_from_nibble(byte >> 4) * i32::from(x[2 * k + 1]);
            }
            if self.cols % 2 == 1 {
                acc += i4_from_nibble(row[pairs] & 0x0F) * i32::from(x[self.cols - 1]);
            }
            *o = acc + bias_at(folded_bias, r);
        }
    }

    /// Register-tiled batched GEMM over nibble-packed weights: `x` is
    /// `[batch, cols]` row-major activations, `out` is `[batch, rows]`
    /// with `out[b,r] = folded_bias[r] + Σ_c w[r,c] * x[b,c]`.
    ///
    /// On AVX2 this runs the padded panel kernel with the in-register
    /// nibble unpack — zero scalar-tail iterations for any `batch` and
    /// any shape, same contract as [`PackedWeightsI8::gemm`]. Without
    /// AVX2, or under `PALLAS_FORCE_SCALAR`, a scalar oracle decodes
    /// each row once and reuses the int8 scalar dot product. Either way
    /// the result is bit-exact with per-lane [`Self::matvec`].
    pub fn gemm(&self, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
        assert_eq!(x.cols, self.cols);
        assert_eq!(out.rows, x.rows);
        assert_eq!(out.cols, self.rows);
        debug_assert!(folded_bias.is_empty() || folded_bias.len() == self.rows);
        if x.rows == 0 || self.rows == 0 {
            return;
        }
        kernel_counters::record_i4((x.rows * self.rows * self.cols) as u64);
        #[cfg(target_arch = "x86_64")]
        {
            if avx2_enabled() {
                // SAFETY: feature checked at runtime.
                unsafe { self.gemm_avx2(x, folded_bias, out) };
                return;
            }
        }
        self.gemm_scalar(x, folded_bias, out);
    }

    /// Scalar reference oracle, mirroring `gemm_i8_i32_scalar`'s
    /// 4-lane-per-row-pass structure: each weight row is nibble-decoded
    /// once per lane block and dotted against up to 4 activation lanes.
    fn gemm_scalar(&self, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
        let mut wrow = vec![0i8; self.cols];
        let mut b = 0usize;
        while b < x.rows {
            let bn = (x.rows - b).min(4);
            for r in 0..self.rows {
                self.unpack_row(r, &mut wrow);
                let bias = bias_at(folded_bias, r);
                for i in 0..bn {
                    out.data[(b + i) * self.rows + r] =
                        dot_i8_scalar(&wrow, x.row(b + i)) + bias;
                }
            }
            b += bn;
        }
    }

    /// The nibble panel kernel. Identical loop structure and padding
    /// contract to the int8 [`PackedWeightsI8`] kernel — staged ragged
    /// K tails, missing lanes re-pointed at the last live row, pad rows
    /// skipped at writeback — except the weight load is 16 bytes, not
    /// 32, and is expanded in-register:
    ///
    /// 1. `vpand` extracts the low nibbles (K positions `k0..k0+16`),
    /// 2. `vpsrlw` + `vpand` extracts the high nibbles (`k0+16..k0+32`;
    ///    the mask strips the bits `vpsrlw` drags across byte lanes),
    /// 3. the two `xmm` halves concatenate into one K-ordered `ymm`,
    /// 4. `xor 0x08` / `sub 0x08` per byte sign-extends 4→8 bits,
    ///
    /// after which the sign-extended weights feed the *unchanged*
    /// [`widen_i8`] + `pmaddwd` + `paddd` FMA of the int8 kernel.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn gemm_avx2(
        &self,
        x: &Matrix<i8>,
        folded_bias: &[i32],
        out: &mut Matrix<i32>,
    ) {
        use std::arch::x86_64::*;
        const NIB: usize = K_BLOCK / 2;
        let rows = self.rows;
        let cols = self.cols;
        let k_blocks = self.k_blocks;
        let k_tail = cols % K_BLOCK;
        let full_blocks = cols / K_BLOCK;
        let panel_stride = k_blocks * MR * NIB;
        let n_panels = rows.div_ceil(MR);
        let nib_mask = _mm_set1_epi8(0x0F);
        let sign_bias = _mm256_set1_epi8(8);

        // Staged ragged K tails, exactly the int8 kernel's scheme.
        let mut tails = [[0i8; K_BLOCK]; LANE_TILE];

        let mut b = 0usize;
        while b < x.rows {
            let live = (x.rows - b).min(LANE_TILE);
            // A partial tile re-points its missing lanes at the tile's
            // last live row: computed redundantly, never written back.
            let lanes: [&[i8]; LANE_TILE] =
                std::array::from_fn(|l| x.row(b + l.min(live - 1)));
            if k_tail != 0 {
                for (t, lane) in tails.iter_mut().zip(lanes.iter()) {
                    t[..k_tail].copy_from_slice(&lane[full_blocks * K_BLOCK..]);
                }
            }
            for p in 0..n_panels {
                let panel = self.panels.as_ptr().add(p * panel_stride);
                let prow = p * MR;
                let rows_here = (rows - prow).min(MR);
                for q in 0..rows_here {
                    let mut acc = [_mm256_setzero_si256(); LANE_TILE];
                    for kb in 0..k_blocks {
                        let pv = _mm_loadu_si128(
                            panel.add((kb * MR + q) * NIB) as *const __m128i,
                        );
                        let lo = _mm_and_si128(pv, nib_mask);
                        let hi = _mm_and_si128(_mm_srli_epi16::<4>(pv), nib_mask);
                        let unsigned = _mm256_set_m128i(hi, lo);
                        let wv = _mm256_sub_epi8(
                            _mm256_xor_si256(unsigned, sign_bias),
                            sign_bias,
                        );
                        let (w_lo, w_hi) = widen_i8(wv);
                        let staged = k_tail != 0 && kb == full_blocks;
                        for (l, a) in acc.iter_mut().enumerate() {
                            let xp = if staged {
                                tails[l].as_ptr()
                            } else {
                                lanes[l].as_ptr().add(kb * K_BLOCK)
                            };
                            let xv = _mm256_loadu_si256(xp as *const __m256i);
                            let (x_lo, x_hi) = widen_i8(xv);
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_lo, x_lo));
                            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_hi, x_hi));
                        }
                    }
                    let bias = bias_at(folded_bias, prow + q);
                    for (l, a) in acc.iter().enumerate().take(live) {
                        out.data[(b + l) * rows + prow + q] = hsum_epi32(*a) + bias;
                    }
                }
            }
            b += live;
        }
    }
}

/// Blocked int8 × int8 → int32 GEMM over an *unpacked* weight matrix.
///
/// `x` is `[batch, cols]` row-major activations, `out` is `[batch,
/// rows]`: `out[b,r] = folded_bias[r] + Σ_c w[r,c] * x[b,c]`. The batch
/// dimension is register-tiled in blocks of 4 lanes; lane and K
/// remainders fall back to scalar tails (recorded in [`tail_audit`] in
/// debug builds). The serving path does not use this — it packs its
/// weights once into [`PackedWeightsI8`], whose kernel has no tails —
/// but it remains the batched entry point for ad-hoc matrices.
pub fn gemm_i8_i32(w: &Matrix<i8>, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
    assert_eq!(x.cols, w.cols);
    assert_eq!(out.rows, x.rows);
    assert_eq!(out.cols, w.rows);
    debug_assert!(folded_bias.is_empty() || folded_bias.len() == w.rows);
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: feature checked at runtime.
            unsafe { gemm_i8_i32_avx2(w, x, folded_bias, out) };
            return;
        }
    }
    gemm_i8_i32_scalar(w, x, folded_bias, out);
}

/// Scalar reference oracle: 4 batch lanes share each weight-row pass so
/// the row stays hot in cache. Bit-exact with every tiled kernel
/// (integer accumulation is associative); this is the execution path of
/// the `PALLAS_FORCE_SCALAR` CI job.
fn gemm_i8_i32_scalar(
    w: &Matrix<i8>,
    x: &Matrix<i8>,
    folded_bias: &[i32],
    out: &mut Matrix<i32>,
) {
    let mut b = 0usize;
    while b < x.rows {
        let bn = (x.rows - b).min(4);
        for r in 0..w.rows {
            let row = w.row(r);
            let bias = bias_at(folded_bias, r);
            for i in 0..bn {
                out.data[(b + i) * w.rows + r] = dot_i8_scalar(row, x.row(b + i)) + bias;
            }
        }
        b += bn;
    }
}

/// AVX2 inner kernel for unpacked weights: a 1×4 register tile — each
/// 32-byte weight-row chunk is sign-extended once and
/// `pmaddwd`-accumulated against four batch lanes. K remainders run
/// scalar per lane and remainder lanes (< 4) fall back to the matvec
/// kernel; both tails are recorded in [`tail_audit`] (debug builds).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_i8_i32_avx2(
    w: &Matrix<i8>,
    x: &Matrix<i8>,
    folded_bias: &[i32],
    out: &mut Matrix<i32>,
) {
    use std::arch::x86_64::*;

    let n = w.cols;
    let mut b = 0usize;
    while b + 4 <= x.rows {
        let lanes = [x.row(b), x.row(b + 1), x.row(b + 2), x.row(b + 3)];
        for r in 0..w.rows {
            let row = w.row(r);
            let mut acc = [_mm256_setzero_si256(); 4];
            let mut i = 0usize;
            while i + 32 <= n {
                let wv = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
                let (w_lo, w_hi) = widen_i8(wv);
                for (l, a) in lanes.iter().zip(acc.iter_mut()) {
                    let xv = _mm256_loadu_si256(l.as_ptr().add(i) as *const __m256i);
                    let (x_lo, x_hi) = widen_i8(xv);
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_lo, x_lo));
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_hi, x_hi));
                }
                i += 32;
            }
            tail_audit::record((n - i) * 4);
            let bias = bias_at(folded_bias, r);
            for (li, (l, a)) in lanes.iter().zip(acc.iter()).enumerate() {
                let mut total = hsum_epi32(*a);
                for j in i..n {
                    total += i32::from(*row.get_unchecked(j)) * i32::from(*l.get_unchecked(j));
                }
                out.data[(b + li) * w.rows + r] = total + bias;
            }
        }
        b += 4;
    }
    while b < x.rows {
        // Remainder lane: the whole lane runs the untiled matvec path.
        tail_audit::record(w.rows * w.cols);
        let or = &mut out.data[b * w.rows..(b + 1) * w.rows];
        matvec_i8_i32(w, x.row(b), folded_bias, or);
        b += 1;
    }
}

/// Unfolded (naive) variant that applies the zero point inside the inner
/// loop — kept for the E4 ablation of the §6 optimization and as a
/// correctness oracle for the folded kernel.
pub fn matvec_i8_i32_unfolded(
    w: &Matrix<i8>,
    x: &[i8],
    bias: &[i32],
    zp: i32,
    out: &mut [i32],
) {
    assert_eq!(w.cols, x.len());
    assert_eq!(w.rows, out.len());
    for (r, o) in out.iter_mut().enumerate() {
        let row = w.row(r);
        let mut acc = 0i64;
        for (wv, xv) in row.iter().zip(x) {
            acc += i64::from(*wv) * (i64::from(*xv) + i64::from(zp));
        }
        *o = (acc + i64::from(bias_at(bias, r))) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg32};

    fn random_w(rng: &mut Pcg32, rows: usize, cols: usize) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(rows, cols);
        for v in &mut w.data {
            *v = rng.range_i32(-127, 127) as i8;
        }
        w
    }

    fn random_x(rng: &mut Pcg32, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect()
    }

    fn random_batch(rng: &mut Pcg32, batch: usize, cols: usize) -> Matrix<i8> {
        let mut x = Matrix::<i8>::zeros(batch, cols);
        for v in &mut x.data {
            *v = rng.range_i32(-128, 127) as i8;
        }
        x
    }

    #[test]
    fn folded_equals_unfolded() {
        proptest::check("folded-eq-unfolded", |rng| {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(64) as usize;
            let w = random_w(rng, rows, cols);
            let x = random_x(rng, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let zp = rng.range_i32(-128, 127);
            let folded = fold_zero_point(&w, &bias, zp);
            let mut out_folded = vec![0i32; rows];
            let mut out_naive = vec![0i32; rows];
            matvec_i8_i32(&w, &x, &folded, &mut out_folded);
            matvec_i8_i32_unfolded(&w, &x, &bias, zp, &mut out_naive);
            assert_eq!(out_folded, out_naive);
        });
    }

    #[test]
    fn matches_float_reference() {
        let mut rng = Pcg32::seeded(17);
        let rows = 16;
        let cols = 128;
        let w = random_w(&mut rng, rows, cols);
        let x = random_x(&mut rng, cols);
        let mut out = vec![0i32; rows];
        matvec_i8_i32(&w, &x, &[], &mut out);
        for r in 0..rows {
            let want: i64 = w
                .row(r)
                .iter()
                .zip(&x)
                .map(|(&a, &b)| i64::from(a) * i64::from(b))
                .sum();
            assert_eq!(i64::from(out[r]), want);
        }
    }

    #[test]
    #[should_panic]
    fn short_bias_slice_panics() {
        let w = Matrix::from_vec(3, 2, vec![1i8; 6]);
        let x = vec![1i8; 2];
        let mut out = vec![0i32; 3];
        // Two bias entries for three rows: must panic, never silently
        // read a zero for row 2.
        matvec_i8_i32(&w, &x, &[5, 6], &mut out);
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Pcg32::seeded(23);
        let w = random_w(&mut rng, 8, 32);
        let x = random_batch(&mut rng, 4, 32);
        let bias: Vec<i32> = (0..8).map(|_| rng.range_i32(-100, 100)).collect();
        let mut out = Matrix::<i32>::zeros(4, 8);
        gemm_i8_i32(&w, &x, &bias, &mut out);
        for b in 0..4 {
            let mut single = vec![0i32; 8];
            matvec_i8_i32(&w, x.row(b), &bias, &mut single);
            assert_eq!(out.row(b), &single[..]);
        }
    }

    #[test]
    fn gemm_matches_matvec_per_lane() {
        // The batch-major GEMM must be bit-exact with the per-lane
        // matvec for every shape, including non-multiple-of-32 depths
        // and non-multiple-of-4 batches (tile remainders).
        proptest::check("gemm-i8-eq-matvec", |rng| {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(80) as usize;
            let batch = 1 + rng.below(9) as usize;
            let w = random_w(rng, rows, cols);
            let x = random_batch(rng, batch, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let mut out = Matrix::<i32>::zeros(batch, rows);
            gemm_i8_i32(&w, &x, &bias, &mut out);
            for b in 0..batch {
                let mut single = vec![0i32; rows];
                matvec_i8_i32(&w, x.row(b), &bias, &mut single);
                assert_eq!(out.row(b), &single[..], "lane {b}");
            }
        });
    }

    #[test]
    fn gemm_scalar_matches_dispatch() {
        let mut rng = Pcg32::seeded(41);
        let w = random_w(&mut rng, 13, 70);
        let x = random_batch(&mut rng, 6, 70);
        let bias: Vec<i32> = (0..13).map(|_| rng.range_i32(-500, 500)).collect();
        let mut out_a = Matrix::<i32>::zeros(6, 13);
        let mut out_b = Matrix::<i32>::zeros(6, 13);
        gemm_i8_i32(&w, &x, &bias, &mut out_a);
        gemm_i8_i32_scalar(&w, &x, &bias, &mut out_b);
        assert_eq!(out_a.data, out_b.data);
    }

    #[test]
    fn packed_matches_scalar_on_pinned_ragged_shapes() {
        // The acceptance grid: every n_cell × batch combination the
        // continuous batcher actually produces after compaction —
        // single rows, 32±1 depths, and odd live-lane counts.
        let mut rng = Pcg32::seeded(61);
        for &rows in &[1usize, 31, 33, 100] {
            for &cols in &[1usize, 31, 32, 33, 100] {
                for &batch in &[1usize, 3, 5, 7] {
                    let w = random_w(&mut rng, rows, cols);
                    let packed = PackedWeightsI8::pack(w.clone());
                    let x = random_batch(&mut rng, batch, cols);
                    let bias: Vec<i32> =
                        (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
                    let mut got = Matrix::<i32>::zeros(batch, rows);
                    let mut want = Matrix::<i32>::zeros(batch, rows);
                    packed.gemm(&x, &bias, &mut got);
                    gemm_i8_i32_scalar(&w, &x, &bias, &mut want);
                    assert_eq!(
                        got.data, want.data,
                        "rows={rows} cols={cols} batch={batch}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_matches_matvec_property() {
        proptest::check("packed-gemm-eq-matvec", |rng| {
            let rows = 1 + rng.below(70) as usize;
            let cols = 1 + rng.below(100) as usize;
            let batch = 1 + rng.below(9) as usize;
            let w = random_w(rng, rows, cols);
            let packed = PackedWeightsI8::pack(w);
            let x = random_batch(rng, batch, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let mut out = Matrix::<i32>::zeros(batch, rows);
            packed.gemm(&x, &bias, &mut out);
            for b in 0..batch {
                let mut single = vec![0i32; rows];
                packed.matvec(x.row(b), &bias, &mut single);
                assert_eq!(out.row(b), &single[..], "lane {b}");
            }
        });
    }

    #[test]
    fn packed_extreme_magnitudes() {
        // Worst-case accumulation across ragged shapes: all-(-127)
        // weights against all-(-128) activations.
        for &(rows, cols) in &[(5usize, 33usize), (4, 32), (7, 95), (1, 1)] {
            let w = Matrix::from_vec(rows, cols, vec![-127i8; rows * cols]);
            let packed = PackedWeightsI8::pack(w);
            let x = Matrix::from_vec(3, cols, vec![-128i8; 3 * cols]);
            let mut out = Matrix::<i32>::zeros(3, rows);
            packed.gemm(&x, &[], &mut out);
            for &v in &out.data {
                assert_eq!(v, 127 * 128 * cols as i32);
            }
        }
    }

    #[test]
    fn packed_roundtrip_preserves_dense() {
        let mut rng = Pcg32::seeded(71);
        let w = random_w(&mut rng, 9, 37);
        let packed = PackedWeightsI8::pack(w.clone());
        assert_eq!(packed.dense().data, w.data);
        assert_eq!(packed.rows(), 9);
        assert_eq!(packed.cols(), 37);
        assert_eq!(packed.storage_bytes(), 9 * 37);
    }

    #[test]
    fn packed_kernel_runs_tail_free() {
        // The packed path must never record scalar-tail work, no matter
        // how ragged the shape; the counter is thread-local, so this is
        // exact even under the parallel test harness. (Release builds
        // compile the counter out and the assertion degenerates to
        // 0 == 0 — the CI debug jobs carry the real check.)
        let mut rng = Pcg32::seeded(83);
        let w = random_w(&mut rng, 33, 47);
        let packed = PackedWeightsI8::pack(w.clone());
        let x = random_batch(&mut rng, 5, 47);
        let mut out = Matrix::<i32>::zeros(5, 33);
        // Positive control first: the unpacked AVX2 kernel on the same
        // ragged shape does record tails.
        if crate::util::avx2_enabled() && cfg!(debug_assertions) {
            tail_audit::reset();
            gemm_i8_i32(&w, &x, &[], &mut out);
            assert!(
                tail_audit::count() > 0,
                "unpacked kernel should record K/lane tails on 5x47"
            );
        }
        tail_audit::reset();
        for &batch in &[1usize, 3, 5, 7, 8] {
            let xb = random_batch(&mut rng, batch, 47);
            let mut ob = Matrix::<i32>::zeros(batch, 33);
            packed.gemm(&xb, &[], &mut ob);
        }
        assert_eq!(
            tail_audit::count(),
            0,
            "packed kernel recorded scalar tails"
        );
    }

    #[test]
    fn no_overflow_at_max_magnitude_depth() {
        // §3.1.1: int8×int8 into int32 is safe for depths < 2^15. At the
        // extreme all-(-127)·all-(-128) case with depth 4096 the
        // accumulator reaches 127*128*4096 = 2^26-ish — well inside i32.
        let cols = 4096;
        let w = Matrix::from_vec(1, cols, vec![-127i8; cols]);
        let x = vec![-128i8; cols];
        let mut out = vec![0i32; 1];
        matvec_i8_i32(&w, &x, &[], &mut out);
        assert_eq!(out[0], 127 * 128 * cols as i32);
    }

    fn random_w4(rng: &mut Pcg32, rows: usize, cols: usize) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(rows, cols);
        for v in &mut w.data {
            *v = rng.range_i32(-8, 7) as i8;
        }
        w
    }

    #[test]
    fn int4_roundtrip_every_nibble_pattern() {
        // One row holding every signed nibble value, at both even and
        // odd positions, across odd and even column counts: the packed
        // bytes must decode back bit-exactly (including -8, the one
        // value quantization never emits but the format represents).
        for &cols in &[16usize, 17, 31, 32, 33] {
            let mut w = Matrix::<i8>::zeros(3, cols);
            for c in 0..cols {
                w.set(0, c, ((c % 16) as i8) - 8);
                w.set(1, c, 7 - ((c % 16) as i8));
                w.set(2, c, if c % 2 == 0 { -8 } else { 7 });
            }
            let packed = PackedWeightsI4::pack(&w);
            assert_eq!(packed.to_dense(), w, "cols={cols}");
            assert_eq!(packed.storage_bytes(), 3 * cols.div_ceil(2));
        }
    }

    #[test]
    fn int4_roundtrip_property() {
        proptest::check("int4-pack-roundtrip", |rng| {
            let rows = 1 + rng.below(40) as usize;
            let cols = 1 + rng.below(80) as usize;
            let w = random_w4(rng, rows, cols);
            let packed = PackedWeightsI4::pack(&w);
            assert_eq!(packed.to_dense(), w);
            assert_eq!(packed.rows(), rows);
            assert_eq!(packed.cols(), cols);
        });
    }

    #[test]
    #[should_panic]
    fn int4_pack_out_of_range_panics() {
        // A weight outside -8..=7 must panic at pack time, never wrap
        // into a different nibble.
        let w = Matrix::from_vec(1, 2, vec![3i8, 9]);
        let _ = PackedWeightsI4::pack(&w);
    }

    #[test]
    fn int4_packed_matches_scalar_on_pinned_ragged_shapes() {
        // The int4 acceptance grid, mirroring the int8 one: the
        // dispatched kernel (AVX2 nibble panels when available, the
        // nibble-decoding scalar oracle under PALLAS_FORCE_SCALAR) must
        // be bit-exact with the independent int8 scalar reference over
        // the decoded weights — single rows, 32±1 depths, odd batches.
        let mut rng = Pcg32::seeded(67);
        for &rows in &[1usize, 31, 33, 100] {
            for &cols in &[1usize, 31, 32, 33, 100] {
                for &batch in &[1usize, 3, 5, 7] {
                    let w = random_w4(&mut rng, rows, cols);
                    let packed = PackedWeightsI4::pack(&w);
                    let x = random_batch(&mut rng, batch, cols);
                    let bias: Vec<i32> =
                        (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
                    let mut got = Matrix::<i32>::zeros(batch, rows);
                    let mut want = Matrix::<i32>::zeros(batch, rows);
                    packed.gemm(&x, &bias, &mut got);
                    gemm_i8_i32_scalar(&w, &x, &bias, &mut want);
                    assert_eq!(
                        got.data, want.data,
                        "rows={rows} cols={cols} batch={batch}"
                    );
                }
            }
        }
    }

    #[test]
    fn int4_gemm_matches_matvec_property() {
        proptest::check("int4-gemm-eq-matvec", |rng| {
            let rows = 1 + rng.below(70) as usize;
            let cols = 1 + rng.below(100) as usize;
            let batch = 1 + rng.below(9) as usize;
            let w = random_w4(rng, rows, cols);
            let packed = PackedWeightsI4::pack(&w);
            let x = random_batch(rng, batch, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let mut out = Matrix::<i32>::zeros(batch, rows);
            packed.gemm(&x, &bias, &mut out);
            for b in 0..batch {
                let mut single = vec![0i32; rows];
                packed.matvec(x.row(b), &bias, &mut single);
                assert_eq!(out.row(b), &single[..], "lane {b}");
            }
        });
    }

    #[test]
    fn int4_extreme_magnitudes() {
        // Worst-case int4 accumulation across ragged shapes: all-(-8)
        // weights against all-(-128) activations.
        for &(rows, cols) in &[(5usize, 33usize), (4, 32), (7, 95), (1, 1)] {
            let w = Matrix::from_vec(rows, cols, vec![-8i8; rows * cols]);
            let packed = PackedWeightsI4::pack(&w);
            let x = Matrix::from_vec(3, cols, vec![-128i8; 3 * cols]);
            let mut out = Matrix::<i32>::zeros(3, rows);
            packed.gemm(&x, &[], &mut out);
            for &v in &out.data {
                assert_eq!(v, 8 * 128 * cols as i32);
            }
        }
    }

    #[test]
    fn int4_kernel_runs_tail_free() {
        // Same proof as the int8 packed kernel: the nibble panel kernel
        // must never record scalar-tail work, however ragged the shape.
        let mut rng = Pcg32::seeded(89);
        let w = random_w4(&mut rng, 33, 47);
        let packed = PackedWeightsI4::pack(&w);
        // Positive control first: the unpacked AVX2 kernel on the same
        // ragged shape does record tails.
        if crate::util::avx2_enabled() && cfg!(debug_assertions) {
            let x = random_batch(&mut rng, 5, 47);
            let mut out = Matrix::<i32>::zeros(5, 33);
            tail_audit::reset();
            gemm_i8_i32(&w, &x, &[], &mut out);
            assert!(
                tail_audit::count() > 0,
                "unpacked kernel should record K/lane tails on 5x47"
            );
        }
        tail_audit::reset();
        for &batch in &[1usize, 3, 5, 7, 8] {
            let xb = random_batch(&mut rng, batch, 47);
            let mut ob = Matrix::<i32>::zeros(batch, 33);
            packed.gemm(&xb, &[], &mut ob);
        }
        assert_eq!(tail_audit::count(), 0, "int4 kernel recorded scalar tails");
    }

    #[test]
    fn int4_storage_is_half_of_int8() {
        // The acceptance bound: nibble packing must come in at <= 55%
        // of the int8 byte count even at odd K (one pad nibble/row).
        for &(rows, cols) in &[(33usize, 47usize), (128, 512), (4, 32), (5, 11)] {
            let w = random_w4(&mut Pcg32::seeded(91), rows, cols);
            let p4 = PackedWeightsI4::pack(&w);
            let p8 = PackedWeightsI8::pack(w);
            assert!(
                (p4.storage_bytes() as f64) <= 0.55 * p8.storage_bytes() as f64,
                "{}x{}: int4 {}B vs int8 {}B",
                rows,
                cols,
                p4.storage_bytes(),
                p8.storage_bytes()
            );
        }
    }

    #[test]
    fn pad_lanes_rounds_to_tile() {
        assert_eq!(pad_lanes(0), 0);
        assert_eq!(pad_lanes(1), 4);
        assert_eq!(pad_lanes(4), 4);
        assert_eq!(pad_lanes(5), 8);
        assert_eq!(pad_lanes(7), 8);
        assert_eq!(pad_lanes(8), 8);
        assert_eq!(pad_lanes(9), 12);
    }
}

#[cfg(test)]
mod simd_tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn simd_dot_equals_scalar() {
        proptest::check("dot-i8-simd-vs-scalar", |rng| {
            let n = rng.below(300) as usize;
            let a: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
            assert_eq!(dot_i8(&a, &b), dot_i8_scalar(&a, &b));
        });
    }

    #[test]
    fn simd_dot_extreme_values() {
        // Worst-case magnitudes across non-multiple-of-32 lengths.
        for n in [0usize, 1, 31, 32, 33, 63, 64, 65, 255, 2048] {
            let a = vec![-128i8; n];
            let b = vec![-128i8; n];
            assert_eq!(dot_i8(&a, &b), (n as i32) * 128 * 128);
            let c = vec![127i8; n];
            assert_eq!(dot_i8(&a, &c), (n as i32) * -128 * 127);
        }
    }
}
