//! Quantized integer matrix kernels: int8 × int8 → int32.
//!
//! The deployment optimization of §6 is implemented here: for an
//! asymmetric activation `x` with zero point `zp`, the gate computation
//! `Σ_j W[i,j] * (x[j] + zp)` is split into `Σ_j W[i,j] * x[j]` (the
//! hot loop, fully symmetric) plus the static `zp * Σ_j W[i,j]`, which
//! [`fold_zero_point`] precomputes into the bias offline. The paper
//! reports this makes integer LSTM ~5% faster than hybrid and ~2×
//! faster than float; `benches/deployment_speed.rs` measures both forms
//! (experiment E4).

use super::dense::Matrix;

/// Inner dot product of two int8 slices with int32 accumulation,
/// dispatching to AVX2 (`pmaddwd`: sign-extend to i16, pairwise
/// multiply-add into i32 lanes) when available. Exactly equal to the
/// scalar sum for all inputs: every product fits i16×i16→i32 and
/// §3.1.1 bounds the accumulator.
#[inline]
fn dot_i8(row: &[i8], x: &[i8]) -> i32 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: feature checked at runtime.
            return unsafe { dot_i8_avx2(row, x) };
        }
    }
    dot_i8_scalar(row, x)
}

#[inline]
fn dot_i8_scalar(row: &[i8], x: &[i8]) -> i32 {
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    let chunks = x.len() / 4 * 4;
    let mut c = 0;
    while c < chunks {
        acc0 += i32::from(row[c]) * i32::from(x[c]);
        acc1 += i32::from(row[c + 1]) * i32::from(x[c + 1]);
        acc2 += i32::from(row[c + 2]) * i32::from(x[c + 2]);
        acc3 += i32::from(row[c + 3]) * i32::from(x[c + 3]);
        c += 4;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for i in chunks..x.len() {
        acc += i32::from(row[i]) * i32::from(x[i]);
    }
    acc
}

/// AVX2 int8 dot product: 32 bytes/iteration via two
/// sign-extend + `pmaddwd` + i32 adds.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(row: &[i8], x: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(row.len(), x.len());
    let n = row.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 32 <= n {
        let a8 = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
        let b8 = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        let a_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(a8));
        let a_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256(a8, 1));
        let b_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(b8));
        let b_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256(b8, 1));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_lo, b_lo));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_hi, b_hi));
        i += 32;
    }
    // Horizontal sum of the 8 i32 lanes.
    let hi128 = _mm256_extracti128_si256(acc, 1);
    let lo128 = _mm256_castsi256_si128(acc);
    let sum128 = _mm_add_epi32(hi128, lo128);
    let shuf = _mm_add_epi32(sum128, _mm_shuffle_epi32(sum128, 0b00_00_11_10));
    let shuf2 = _mm_add_epi32(shuf, _mm_shuffle_epi32(shuf, 0b00_00_00_01));
    let mut total = _mm_cvtsi128_si32(shuf2);
    while i < n {
        total += i32::from(*row.get_unchecked(i)) * i32::from(*x.get_unchecked(i));
        i += 1;
    }
    total
}

/// Precompute the §6 zero-point fold: `bias'[i] = bias[i] + zp * Σ_j W[i,j]`.
///
/// `zp` is the zero point *added* to the stored int8 activation to
/// recover the affine value (i.e. the kernel computes `W (x + zp)`).
pub fn fold_zero_point(w: &Matrix<i8>, bias: &[i32], zp: i32) -> Vec<i32> {
    assert!(bias.is_empty() || bias.len() == w.rows);
    let mut folded = Vec::with_capacity(w.rows);
    for r in 0..w.rows {
        let row_sum: i32 = w.row(r).iter().map(|&v| i32::from(v)).sum();
        let b = bias.get(r).copied().unwrap_or(0);
        folded.push(b.wrapping_add(zp.wrapping_mul(row_sum)));
    }
    folded
}

/// Symmetric int8 matrix-vector product with int32 accumulation:
/// `out[r] = folded_bias[r] + Σ_c w[r,c] * x[c]`.
///
/// This is the §6-optimized inner loop: no zero-point arithmetic, no
/// branching, straight multiply-accumulate. §3.1.1 guarantees the int32
/// accumulator cannot overflow for depths below 2^15.
pub fn matvec_i8_i32(w: &Matrix<i8>, x: &[i8], folded_bias: &[i32], out: &mut [i32]) {
    assert_eq!(w.cols, x.len());
    assert_eq!(w.rows, out.len());
    assert!(folded_bias.is_empty() || folded_bias.len() == w.rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot_i8(w.row(r), x) + folded_bias.get(r).copied().unwrap_or(0);
    }
}

/// Blocked int8 × int8 → int32 GEMM — the batch-major hot loop of the
/// serving path. `x` is `[batch, cols]` row-major activations, `out` is
/// `[batch, rows]`: `out[b,r] = folded_bias[r] + Σ_c w[r,c] * x[b,c]`.
///
/// The batch dimension is register-tiled in blocks of 4 lanes so each
/// 32-byte weight-row chunk is loaded once and multiplied against four
/// activation rows (the amortization that makes batch > 1 cheaper per
/// token than repeated [`matvec_i8_i32`] calls). Integer accumulation
/// is associative, so every tiling is bit-exact with the per-lane
/// matvec — batch-major engines are property-tested on exactly that.
pub fn gemm_i8_i32(w: &Matrix<i8>, x: &Matrix<i8>, folded_bias: &[i32], out: &mut Matrix<i32>) {
    assert_eq!(x.cols, w.cols);
    assert_eq!(out.rows, x.rows);
    assert_eq!(out.cols, w.rows);
    assert!(folded_bias.is_empty() || folded_bias.len() == w.rows);
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: feature checked at runtime.
            unsafe { gemm_i8_i32_avx2(w, x, folded_bias, out) };
            return;
        }
    }
    gemm_i8_i32_scalar(w, x, folded_bias, out);
}

/// Scalar fallback: 4 batch lanes share each weight-row pass so the row
/// stays hot in cache.
fn gemm_i8_i32_scalar(
    w: &Matrix<i8>,
    x: &Matrix<i8>,
    folded_bias: &[i32],
    out: &mut Matrix<i32>,
) {
    let mut b = 0usize;
    while b < x.rows {
        let bn = (x.rows - b).min(4);
        for r in 0..w.rows {
            let row = w.row(r);
            let bias = folded_bias.get(r).copied().unwrap_or(0);
            for i in 0..bn {
                out.data[(b + i) * w.rows + r] = dot_i8_scalar(row, x.row(b + i)) + bias;
            }
        }
        b += bn;
    }
}

/// AVX2 inner kernel: a 1×4 register tile — each 32-byte weight-row
/// chunk is sign-extended once and `pmaddwd`-accumulated against four
/// batch lanes. Remainder lanes (< 4) fall back to the matvec kernel.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_i8_i32_avx2(
    w: &Matrix<i8>,
    x: &Matrix<i8>,
    folded_bias: &[i32],
    out: &mut Matrix<i32>,
) {
    use std::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(acc: __m256i) -> i32 {
        let hi128 = _mm256_extracti128_si256(acc, 1);
        let lo128 = _mm256_castsi256_si128(acc);
        let sum128 = _mm_add_epi32(hi128, lo128);
        let shuf = _mm_add_epi32(sum128, _mm_shuffle_epi32(sum128, 0b00_00_11_10));
        let shuf2 = _mm_add_epi32(shuf, _mm_shuffle_epi32(shuf, 0b00_00_00_01));
        _mm_cvtsi128_si32(shuf2)
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen(v: __m256i) -> (__m256i, __m256i) {
        (
            _mm256_cvtepi8_epi16(_mm256_castsi256_si128(v)),
            _mm256_cvtepi8_epi16(_mm256_extracti128_si256(v, 1)),
        )
    }

    let n = w.cols;
    let mut b = 0usize;
    while b + 4 <= x.rows {
        let lanes = [x.row(b), x.row(b + 1), x.row(b + 2), x.row(b + 3)];
        for r in 0..w.rows {
            let row = w.row(r);
            let mut acc = [_mm256_setzero_si256(); 4];
            let mut i = 0usize;
            while i + 32 <= n {
                let wv = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
                let (w_lo, w_hi) = widen(wv);
                for (l, a) in lanes.iter().zip(acc.iter_mut()) {
                    let xv = _mm256_loadu_si256(l.as_ptr().add(i) as *const __m256i);
                    let (x_lo, x_hi) = widen(xv);
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_lo, x_lo));
                    *a = _mm256_add_epi32(*a, _mm256_madd_epi16(w_hi, x_hi));
                }
                i += 32;
            }
            let bias = folded_bias.get(r).copied().unwrap_or(0);
            for (li, (l, a)) in lanes.iter().zip(acc.iter()).enumerate() {
                let mut total = hsum_epi32(*a);
                for j in i..n {
                    total += i32::from(*row.get_unchecked(j)) * i32::from(*l.get_unchecked(j));
                }
                out.data[(b + li) * w.rows + r] = total + bias;
            }
        }
        b += 4;
    }
    while b < x.rows {
        let or = &mut out.data[b * w.rows..(b + 1) * w.rows];
        matvec_i8_i32(w, x.row(b), folded_bias, or);
        b += 1;
    }
}

/// Unfolded (naive) variant that applies the zero point inside the inner
/// loop — kept for the E4 ablation of the §6 optimization and as a
/// correctness oracle for the folded kernel.
pub fn matvec_i8_i32_unfolded(
    w: &Matrix<i8>,
    x: &[i8],
    bias: &[i32],
    zp: i32,
    out: &mut [i32],
) {
    assert_eq!(w.cols, x.len());
    assert_eq!(w.rows, out.len());
    for (r, o) in out.iter_mut().enumerate() {
        let row = w.row(r);
        let mut acc = 0i64;
        for (wv, xv) in row.iter().zip(x) {
            acc += i64::from(*wv) * (i64::from(*xv) + i64::from(zp));
        }
        *o = (acc + i64::from(bias.get(r).copied().unwrap_or(0))) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, Pcg32};

    fn random_w(rng: &mut Pcg32, rows: usize, cols: usize) -> Matrix<i8> {
        let mut w = Matrix::<i8>::zeros(rows, cols);
        for v in &mut w.data {
            *v = rng.range_i32(-127, 127) as i8;
        }
        w
    }

    fn random_x(rng: &mut Pcg32, n: usize) -> Vec<i8> {
        (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect()
    }

    #[test]
    fn folded_equals_unfolded() {
        proptest::check("folded-eq-unfolded", |rng| {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(64) as usize;
            let w = random_w(rng, rows, cols);
            let x = random_x(rng, cols);
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let zp = rng.range_i32(-128, 127);
            let folded = fold_zero_point(&w, &bias, zp);
            let mut out_folded = vec![0i32; rows];
            let mut out_naive = vec![0i32; rows];
            matvec_i8_i32(&w, &x, &folded, &mut out_folded);
            matvec_i8_i32_unfolded(&w, &x, &bias, zp, &mut out_naive);
            assert_eq!(out_folded, out_naive);
        });
    }

    #[test]
    fn matches_float_reference() {
        let mut rng = Pcg32::seeded(17);
        let rows = 16;
        let cols = 128;
        let w = random_w(&mut rng, rows, cols);
        let x = random_x(&mut rng, cols);
        let mut out = vec![0i32; rows];
        matvec_i8_i32(&w, &x, &[], &mut out);
        for r in 0..rows {
            let want: i64 = w
                .row(r)
                .iter()
                .zip(&x)
                .map(|(&a, &b)| i64::from(a) * i64::from(b))
                .sum();
            assert_eq!(i64::from(out[r]), want);
        }
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Pcg32::seeded(23);
        let w = random_w(&mut rng, 8, 32);
        let mut x = Matrix::<i8>::zeros(4, 32);
        for v in &mut x.data {
            *v = rng.range_i32(-128, 127) as i8;
        }
        let bias: Vec<i32> = (0..8).map(|_| rng.range_i32(-100, 100)).collect();
        let mut out = Matrix::<i32>::zeros(4, 8);
        gemm_i8_i32(&w, &x, &bias, &mut out);
        for b in 0..4 {
            let mut single = vec![0i32; 8];
            matvec_i8_i32(&w, x.row(b), &bias, &mut single);
            assert_eq!(out.row(b), &single[..]);
        }
    }

    #[test]
    fn gemm_matches_matvec_per_lane() {
        // The batch-major GEMM must be bit-exact with the per-lane
        // matvec for every shape, including non-multiple-of-32 depths
        // and non-multiple-of-4 batches (tile remainders).
        proptest::check("gemm-i8-eq-matvec", |rng| {
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(80) as usize;
            let batch = 1 + rng.below(9) as usize;
            let w = random_w(rng, rows, cols);
            let mut x = Matrix::<i8>::zeros(batch, cols);
            for v in &mut x.data {
                *v = rng.range_i32(-128, 127) as i8;
            }
            let bias: Vec<i32> =
                (0..rows).map(|_| rng.range_i32(-100_000, 100_000)).collect();
            let mut out = Matrix::<i32>::zeros(batch, rows);
            gemm_i8_i32(&w, &x, &bias, &mut out);
            for b in 0..batch {
                let mut single = vec![0i32; rows];
                matvec_i8_i32(&w, x.row(b), &bias, &mut single);
                assert_eq!(out.row(b), &single[..], "lane {b}");
            }
        });
    }

    #[test]
    fn gemm_scalar_matches_dispatch() {
        let mut rng = Pcg32::seeded(41);
        let w = random_w(&mut rng, 13, 70);
        let mut x = Matrix::<i8>::zeros(6, 70);
        for v in &mut x.data {
            *v = rng.range_i32(-128, 127) as i8;
        }
        let bias: Vec<i32> = (0..13).map(|_| rng.range_i32(-500, 500)).collect();
        let mut out_a = Matrix::<i32>::zeros(6, 13);
        let mut out_b = Matrix::<i32>::zeros(6, 13);
        gemm_i8_i32(&w, &x, &bias, &mut out_a);
        gemm_i8_i32_scalar(&w, &x, &bias, &mut out_b);
        assert_eq!(out_a.data, out_b.data);
    }

    #[test]
    fn no_overflow_at_max_magnitude_depth() {
        // §3.1.1: int8×int8 into int32 is safe for depths < 2^15. At the
        // extreme all-(-127)·all-(-128) case with depth 4096 the
        // accumulator reaches 127*128*4096 = 2^26-ish — well inside i32.
        let cols = 4096;
        let w = Matrix::from_vec(1, cols, vec![-127i8; cols]);
        let x = vec![-128i8; cols];
        let mut out = vec![0i32; 1];
        matvec_i8_i32(&w, &x, &[], &mut out);
        assert_eq!(out[0], 127 * 128 * cols as i32);
    }
}

#[cfg(test)]
mod simd_tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn simd_dot_equals_scalar() {
        proptest::check("dot-i8-simd-vs-scalar", |rng| {
            let n = rng.below(300) as usize;
            let a: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| rng.range_i32(-128, 127) as i8).collect();
            assert_eq!(dot_i8(&a, &b), dot_i8_scalar(&a, &b));
        });
    }

    #[test]
    fn simd_dot_extreme_values() {
        // Worst-case magnitudes across non-multiple-of-32 lengths.
        for n in [0usize, 1, 31, 32, 33, 63, 64, 65, 255, 2048] {
            let a = vec![-128i8; n];
            let b = vec![-128i8; n];
            assert_eq!(dot_i8(&a, &b), (n as i32) * 128 * 128);
            let c = vec![127i8; n];
            assert_eq!(dot_i8(&a, &c), (n as i32) * -128 * 127);
        }
    }
}
