use iqrnn::lstm::*;
use iqrnn::util::{Pcg32, timer::bench};
fn main() {
    let mut rng = Pcg32::seeded(4);
    for &(n_input, hidden) in &[(256usize, 512usize), (96, 192)] {
        let spec = LstmSpec::plain(n_input, hidden);
        let w = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(w.clone());
        let calib: Vec<Vec<Vec<f32>>> = (0..2).map(|_| (0..8).map(|_| (0..n_input).map(|_| rng.normal_f32(0.0,1.0)).collect()).collect()).collect();
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&w, &stats, Default::default());
        let hybrid = HybridLstm::from_weights(&w);
        let x: Vec<f32> = (0..n_input).map(|_| rng.normal_f32(0.0,1.0)).collect();
        let qx: Vec<i8> = x.iter().map(|&v| integer.input_q.quantize(v as f64)).collect();
        let mut hs = FloatState::zeros(&spec);
        let t_h = bench(5, 101, || { hybrid.step(&x, &mut hs); hs.h[0] }).median_secs();
        let mut is = IntegerState::zeros(&integer);
        let t_i = bench(5, 101, || { integer.step_q(&qx, &mut is); is.h[0] }).median_secs();
        let mut is2 = IntegerState::zeros(&integer);
        let t_if = bench(5, 101, || { integer.step(&x, &mut is2); is2.h[0] }).median_secs();
        println!("{n_input}x{hidden}: hybrid {:.1}us integer(q) {:.1}us integer(f32-in) {:.1}us", t_h*1e6, t_i*1e6, t_if*1e6);
    }
}
