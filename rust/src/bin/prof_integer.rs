use iqrnn::lstm::*;
use iqrnn::util::Pcg32;
fn main() {
    let mut rng = Pcg32::seeded(4);
    let n_input = 256; let hidden = 512;
    let spec = LstmSpec::plain(n_input, hidden);
    let weights = StackWeights::random(n_input, spec, 2, &mut rng);
    let calib: Vec<Vec<Vec<f32>>> = (0..4).map(|_| (0..16).map(|_| (0..n_input).map(|_| rng.normal_f32(0.0,1.0)).collect()).collect()).collect();
    let stats = weights.calibrate(&calib);
    let stack = LstmStack::build(&weights, StackEngine::Integer, Some(&stats), Default::default());
    let xs: Vec<Vec<f32>> = (0..32).map(|_| (0..n_input).map(|_| rng.normal_f32(0.0,1.0)).collect()).collect();
    let mut out = vec![0f32; stack.n_output()];
    let mut states = stack.zero_state();
    for _ in 0..40 { for x in &xs { stack.step(x, &mut states, &mut out); } }
    std::hint::black_box(out[0]);
}
