//! Workload generation: the three evaluation "datasets" of the Table-1
//! analog (DESIGN.md §3 substitutions) and synthetic streaming traffic
//! for the serving experiments.

pub mod corpus;
pub mod synth;

pub use corpus::{EvalSet, load_eval_sets};
pub use synth::{RequestTrace, TraceRequest};
