//! Synthetic streaming request traces for the serving experiments
//! (E4/E10): Poisson arrivals of variable-length utterances, shaped
//! like the paper's speech traffic (VoiceSearch-like short requests,
//! occasional YouTube-like long streams) — optionally spread over
//! several registered models (the multi-model serving experiments).

use crate::coordinator::registry::ModelId;
use crate::util::Pcg32;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub id: u64,
    /// The registry model this request's stream runs under (0 in
    /// single-model traces). All chunks of one session must carry the
    /// same model — a stream's state lives under exactly one model.
    pub model: ModelId,
    /// Arrival offset from trace start, in milliseconds.
    pub arrival_ms: f64,
    /// Token sequence to stream through the model.
    pub tokens: Vec<usize>,
}

/// A generated trace.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub requests: Vec<TraceRequest>,
}

impl RequestTrace {
    /// Poisson arrivals at `rate_per_s`, length distribution: 90% short
    /// (geometric around `mean_len`), 10% long (4x), token alphabet
    /// `vocab`.
    pub fn generate(
        count: usize,
        rate_per_s: f64,
        mean_len: usize,
        vocab: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let mut t_ms = 0f64;
        let mut requests = Vec::with_capacity(count);
        for id in 0..count {
            // Exponential inter-arrival.
            let u = rng.next_f64().max(1e-12);
            t_ms += -u.ln() / rate_per_s * 1000.0;
            let long = rng.next_f64() < 0.1;
            let base = if long { mean_len * 4 } else { mean_len };
            let len = (base as f64 * (0.5 + rng.next_f64())).round().max(2.0) as usize;
            let tokens = (0..len).map(|_| rng.below(vocab as u32) as usize).collect();
            requests.push(TraceRequest { id: id as u64, model: 0, arrival_ms: t_ms, tokens });
        }
        RequestTrace { requests }
    }

    /// Bursty arrivals (flash-crowd shape): `bursts` bursts of
    /// `burst_size` requests each, every request in a burst arriving at
    /// the same instant, bursts separated by `gap_ms`. Length
    /// distribution matches [`Self::generate`] — this is the adversarial
    /// arrival pattern for the continuous-batching scheduler (a burst
    /// overfills the lanes, then the queue drains between bursts).
    pub fn generate_bursty(
        bursts: usize,
        burst_size: usize,
        gap_ms: f64,
        mean_len: usize,
        vocab: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let mut requests = Vec::with_capacity(bursts * burst_size);
        let mut id = 0u64;
        for b in 0..bursts {
            let t_ms = b as f64 * gap_ms;
            for _ in 0..burst_size {
                let long = rng.next_f64() < 0.1;
                let base = if long { mean_len * 4 } else { mean_len };
                let len = (base as f64 * (0.5 + rng.next_f64())).round().max(2.0) as usize;
                let tokens = (0..len).map(|_| rng.below(vocab as u32) as usize).collect();
                requests.push(TraceRequest { id, model: 0, arrival_ms: t_ms, tokens });
                id += 1;
            }
        }
        RequestTrace { requests }
    }

    /// Evenly staggered arrivals of equal-length streams — the
    /// construction where continuous batching provably beats
    /// wave-at-a-time (each new stream arrives mid-wave).
    pub fn generate_staggered(
        count: usize,
        gap_ms: f64,
        len: usize,
        vocab: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let requests = (0..count)
            .map(|i| TraceRequest {
                id: i as u64,
                model: 0,
                arrival_ms: i as f64 * gap_ms,
                tokens: (0..len).map(|_| rng.below(vocab as u32) as usize).collect(),
            })
            .collect();
        RequestTrace { requests }
    }

    /// Remap every session id in place to the smallest fresh ids
    /// accepted by `keep`, preserving chunk grouping (requests that
    /// shared an id still share one) and arrival order. This is how the
    /// sharded-serving experiments build *routing-skewed* traces: with
    /// `keep = |id| shard_home(id, workers) == hot`, every session
    /// hash-homes to one worker, which is the adversarial arrival
    /// pattern for static sticky routing (and the showcase for work
    /// stealing). Deterministic: the mapping depends only on the trace
    /// and the predicate.
    pub fn reassign_ids(&mut self, mut keep: impl FnMut(u64) -> bool) {
        use std::collections::HashMap;
        let mut map: HashMap<u64, u64> = HashMap::new();
        let mut candidate = 0u64;
        for req in &mut self.requests {
            let new = *map.entry(req.id).or_insert_with(|| {
                while !keep(candidate) {
                    candidate += 1;
                }
                let id = candidate;
                candidate += 1;
                id
            });
            req.id = new;
        }
    }

    /// Tag every request with a model chosen from its *session id*
    /// (`f(id)`), so all chunks of one session land on the same model —
    /// the invariant multi-model serving requires. Deterministic: the
    /// assignment depends only on the ids and the function.
    pub fn assign_models(&mut self, mut f: impl FnMut(u64) -> ModelId) {
        for req in &mut self.requests {
            req.model = f(req.id);
        }
    }

    /// Poisson trace spread round-robin over `n_models` models
    /// (session id modulo model count): the standard mixed-model
    /// workload of the multi-model serving experiments.
    pub fn generate_multi(
        count: usize,
        rate_per_s: f64,
        mean_len: usize,
        vocab: usize,
        n_models: usize,
        seed: u64,
    ) -> Self {
        assert!(n_models >= 1);
        let mut trace = Self::generate(count, rate_per_s, mean_len, vocab, seed);
        trace.assign_models(|id| (id % n_models as u64) as ModelId);
        trace
    }

    /// The sub-trace of one model, arrival order preserved — the input
    /// for that model's single-model reference run in the
    /// bit-exactness tests.
    pub fn filter_model(&self, model: ModelId) -> RequestTrace {
        RequestTrace {
            requests: self
                .requests
                .iter()
                .filter(|r| r.model == model)
                .cloned()
                .collect(),
        }
    }

    /// Distinct models appearing in the trace, ascending.
    pub fn models(&self) -> Vec<ModelId> {
        let mut ms: Vec<ModelId> = self.requests.iter().map(|r| r.model).collect();
        ms.sort_unstable();
        ms.dedup();
        ms
    }

    pub fn total_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens.len()).sum()
    }

    /// Duration from first to last arrival, seconds.
    pub fn span_secs(&self) -> f64 {
        match (self.requests.first(), self.requests.last()) {
            (Some(a), Some(b)) => (b.arrival_ms - a.arrival_ms) / 1000.0,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape() {
        let trace = RequestTrace::generate(200, 50.0, 40, 96, 1);
        assert_eq!(trace.requests.len(), 200);
        assert!(trace.requests.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        assert!(trace.requests.iter().all(|r| r.tokens.iter().all(|&t| t < 96)));
        assert!(trace.total_tokens() > 200 * 10);
        // Mean arrival rate roughly matches.
        let span = trace.span_secs();
        let rate = 200.0 / span;
        assert!((20.0..120.0).contains(&rate), "rate {rate}");
    }

    #[test]
    fn deterministic() {
        let a = RequestTrace::generate(50, 10.0, 20, 96, 7);
        let b = RequestTrace::generate(50, 10.0, 20, 96, 7);
        assert_eq!(a.requests.len(), b.requests.len());
        assert_eq!(a.requests[17].tokens, b.requests[17].tokens);
    }

    #[test]
    fn bursty_trace_shape() {
        let trace = RequestTrace::generate_bursty(4, 6, 50.0, 20, 96, 3);
        assert_eq!(trace.requests.len(), 24);
        // Non-decreasing arrivals, grouped into 4 distinct instants.
        assert!(trace.requests.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        let mut instants: Vec<f64> = trace.requests.iter().map(|r| r.arrival_ms).collect();
        instants.dedup();
        assert_eq!(instants, vec![0.0, 50.0, 100.0, 150.0]);
        assert!(trace.requests.iter().all(|r| r.tokens.len() >= 2));
        // Deterministic.
        let again = RequestTrace::generate_bursty(4, 6, 50.0, 20, 96, 3);
        assert_eq!(trace.requests[13].tokens, again.requests[13].tokens);
    }

    #[test]
    fn reassign_ids_preserves_grouping_and_is_deterministic() {
        let mut trace = RequestTrace::generate(20, 100.0, 8, 96, 4);
        // Give the trace some multi-chunk sessions.
        trace.requests[5].id = trace.requests[2].id;
        trace.requests[9].id = trace.requests[2].id;
        let mut again = trace.clone();
        trace.reassign_ids(|id| id % 3 == 1);
        again.reassign_ids(|id| id % 3 == 1);
        assert!(trace.requests.iter().all(|r| r.id % 3 == 1));
        // Chunk grouping survives the remap.
        assert_eq!(trace.requests[5].id, trace.requests[2].id);
        assert_eq!(trace.requests[9].id, trace.requests[2].id);
        assert_ne!(trace.requests[3].id, trace.requests[2].id);
        // Distinct sessions stay distinct.
        let mut ids: Vec<u64> = trace.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 18); // 20 requests, 3 sharing one id
        // Deterministic.
        for (a, b) in trace.requests.iter().zip(&again.requests) {
            assert_eq!(a.id, b.id);
        }
    }

    #[test]
    fn multi_model_traces_tag_sessions_consistently() {
        let mut trace = RequestTrace::generate_multi(30, 200.0, 10, 96, 3, 12);
        // Give one session several chunks, then re-tag: chunks of a
        // session must share a model.
        trace.requests[9].id = trace.requests[4].id;
        trace.requests[21].id = trace.requests[4].id;
        trace.assign_models(|id| (id % 3) as ModelId);
        assert_eq!(trace.requests[9].model, trace.requests[4].model);
        assert_eq!(trace.requests[21].model, trace.requests[4].model);
        assert_eq!(trace.models(), vec![0, 1, 2]);
        // Per-model sub-traces partition the trace and keep order.
        let total: usize =
            (0..3).map(|m| trace.filter_model(m).requests.len()).sum();
        assert_eq!(total, trace.requests.len());
        let sub = trace.filter_model(1);
        assert!(sub.requests.iter().all(|r| r.model == 1));
        assert!(sub.requests.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        // Deterministic.
        let again = RequestTrace::generate_multi(30, 200.0, 10, 96, 3, 12);
        assert_eq!(again.requests[7].model, trace.requests[7].model);
    }

    #[test]
    fn staggered_trace_shape() {
        let trace = RequestTrace::generate_staggered(5, 8.0, 16, 96, 2);
        assert_eq!(trace.requests.len(), 5);
        for (i, r) in trace.requests.iter().enumerate() {
            assert_eq!(r.arrival_ms, i as f64 * 8.0);
            assert_eq!(r.tokens.len(), 16);
        }
    }
}
