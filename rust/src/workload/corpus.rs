//! Evaluation sets carved from the corpus artifact — the analogs of the
//! paper's three benchmark datasets (§5):
//!
//! * **Short** (VoiceSearch analog): many short utterances;
//! * **Long** (YouTube analog): few very long utterances — this is the
//!   robustness test, since quantization error can accumulate over
//!   time;
//! * **Noisy** (Telephony analog): short utterances with character
//!   corruption, stressing out-of-calibration inputs.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::model::lm::{tokenize, VOCAB};
use crate::util::Pcg32;

/// One evaluation set: token sequences + a label.
#[derive(Debug, Clone)]
pub struct EvalSet {
    pub name: &'static str,
    pub sequences: Vec<Vec<usize>>,
}

impl EvalSet {
    pub fn total_tokens(&self) -> usize {
        self.sequences.iter().map(Vec::len).sum()
    }
}

/// Slice the held-out tail of the corpus into the three eval sets.
///
/// The first `train_frac` of the corpus was seen by the trainer; eval
/// sets use only the tail.
pub fn load_eval_sets(
    corpus_path: impl AsRef<Path>,
    short_count: usize,
    short_len: usize,
    long_count: usize,
    long_len: usize,
    noise_rate: f64,
    seed: u64,
) -> Result<Vec<EvalSet>> {
    let text = std::fs::read_to_string(corpus_path.as_ref())
        .with_context(|| format!("reading {}", corpus_path.as_ref().display()))?;
    let tokens = tokenize(&text);
    // Hold out the last 20% (the trainer samples uniformly, so this is
    // only approximately unseen; quality deltas are still meaningful
    // because all three engines see identical data).
    let tail = &tokens[tokens.len() * 4 / 5..];
    ensure!(
        tail.len() > long_len + short_len,
        "corpus too small for requested eval sets"
    );
    let mut rng = Pcg32::seeded(seed);

    let sample = |rng: &mut Pcg32, count: usize, len: usize| -> Vec<Vec<usize>> {
        (0..count)
            .map(|_| {
                let start = rng.below((tail.len() - len) as u32) as usize;
                tail[start..start + len].to_vec()
            })
            .collect()
    };

    let short = sample(&mut rng, short_count, short_len);
    let long = sample(&mut rng, long_count, long_len);
    let mut noisy = sample(&mut rng, short_count, short_len);
    for seq in &mut noisy {
        for t in seq.iter_mut() {
            if rng.next_f64() < noise_rate {
                *t = rng.below(VOCAB as u32) as usize;
            }
        }
    }

    Ok(vec![
        EvalSet { name: "Short", sequences: short },
        EvalSet { name: "Long", sequences: long },
        EvalSet { name: "Noisy", sequences: noisy },
    ])
}

/// Calibration sequences (§4): a small sample from the *training*
/// region, as post-training quantization would use in practice. The
/// paper finds ~100 utterances suffice.
pub fn calibration_sequences(
    corpus_path: impl AsRef<Path>,
    count: usize,
    len: usize,
    seed: u64,
) -> Result<Vec<Vec<usize>>> {
    let text = std::fs::read_to_string(corpus_path.as_ref())?;
    let tokens = tokenize(&text);
    let head = &tokens[..tokens.len() * 4 / 5];
    ensure!(head.len() > len + 1, "corpus too small");
    let mut rng = Pcg32::seeded(seed);
    Ok((0..count)
        .map(|_| {
            let start = rng.below((head.len() - len) as u32) as usize;
            head[start..start + len].to_vec()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn eval_sets_from_synthetic_corpus() {
        let dir = std::env::temp_dir().join("iqrnn_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.txt");
        let mut f = std::fs::File::create(&path).unwrap();
        let mut text = String::new();
        for i in 0..3000 {
            text.push_str(&format!("sentence number {i} about kernels. "));
        }
        f.write_all(text.as_bytes()).unwrap();
        drop(f);

        let sets = load_eval_sets(&path, 10, 64, 2, 1000, 0.05, 42).unwrap();
        assert_eq!(sets.len(), 3);
        assert_eq!(sets[0].name, "Short");
        assert_eq!(sets[0].sequences.len(), 10);
        assert_eq!(sets[0].sequences[0].len(), 64);
        assert_eq!(sets[1].sequences[0].len(), 1000);
        assert!(sets.iter().all(|s| s
            .sequences
            .iter()
            .flatten()
            .all(|&t| t < VOCAB)));

        let calib = calibration_sequences(&path, 5, 32, 1).unwrap();
        assert_eq!(calib.len(), 5);
        assert_eq!(calib[0].len(), 32);

        // Deterministic for a fixed seed.
        let sets2 = load_eval_sets(&path, 10, 64, 2, 1000, 0.05, 42).unwrap();
        assert_eq!(sets[0].sequences, sets2[0].sequences);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn noisy_set_differs_from_short() {
        let dir = std::env::temp_dir().join("iqrnn_corpus_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.txt");
        std::fs::write(&path, "abcdefgh ".repeat(2000)).unwrap();
        let sets = load_eval_sets(&path, 4, 128, 1, 500, 0.2, 7).unwrap();
        // With 20% corruption the noisy set should differ from clean
        // resamples in a noticeable fraction of positions.
        let noisy = &sets[2].sequences;
        let mut diffs = 0usize;
        let mut total = 0usize;
        for seq in noisy {
            for w in seq.windows(2) {
                total += 1;
                if w[0] != w[1] {
                    diffs += 1;
                }
            }
        }
        assert!(diffs * 10 > total, "noise did not perturb the stream");
        std::fs::remove_file(&path).ok();
    }
}
