//! Evaluation metrics: quality (bits-per-char / divergence) and the
//! serving metrics the paper reports (RT factor, latency percentiles).

pub mod metrics;

pub use metrics::{LatencyStats, QualityReport, RtFactor};
