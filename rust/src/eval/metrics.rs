//! Metric containers and computations.

/// Quality of one engine on one eval set (Table 1 analog row cell).
#[derive(Debug, Clone)]
pub struct QualityReport {
    pub engine: &'static str,
    pub eval_set: String,
    /// Bits per character (lower is better; the WER analog).
    pub bits_per_char: f64,
    /// Mean |float output − engine output| divergence, when measured.
    pub divergence: Option<f64>,
}

/// Real-time factor: processing time / audio (stream) time. The paper
/// reports integer ≈ 2x faster than float in RT factor (§6). For the
/// char-LM substitution we define stream time via a nominal
/// tokens-per-second rate.
#[derive(Debug, Clone, Copy)]
pub struct RtFactor {
    pub processing_secs: f64,
    pub stream_secs: f64,
}

impl RtFactor {
    pub const NOMINAL_TOKENS_PER_SEC: f64 = 1000.0;

    pub fn from_tokens(processing_secs: f64, tokens: usize) -> Self {
        RtFactor {
            processing_secs,
            stream_secs: tokens as f64 / Self::NOMINAL_TOKENS_PER_SEC,
        }
    }

    pub fn value(&self) -> f64 {
        self.processing_secs / self.stream_secs
    }
}

/// Latency statistics over a set of request completions.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples_ms.iter().fold(f64::NAN, |m, &v| if m.is_nan() { v } else { m.max(v) })
    }

    /// Fold another histogram's samples into this one. Percentiles are
    /// computed over the sorted union of raw samples, so merging is
    /// order-independent: any permutation of worker merge order yields
    /// identical p50/p95/p99 (pinned by `merge_is_order_independent`).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_ms.extend_from_slice(&other.samples_ms);
    }

    /// Render a percentile for a report line: `-` when the histogram
    /// is empty (a missing measurement must never print as a plausible
    /// `0.0`), otherwise the value with `decimals` fraction digits.
    pub fn fmt_percentile(&self, p: f64, decimals: usize) -> String {
        if self.samples_ms.is_empty() {
            "-".to_string()
        } else {
            format!("{:.*}", decimals, self.percentile(p))
        }
    }

    /// Render the mean the same way (`-` when empty).
    pub fn fmt_mean(&self, decimals: usize) -> String {
        if self.samples_ms.is_empty() {
            "-".to_string()
        } else {
            format!("{:.*}", decimals, self.mean())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rt_factor() {
        let rt = RtFactor::from_tokens(0.5, 1000);
        assert!((rt.value() - 0.5).abs() < 1e-12);
        assert!((rt.stream_secs - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyStats::new();
        for i in 1..=100 {
            l.record(f64::from(i));
        }
        assert_eq!(l.count(), 100);
        assert!((l.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile(99.0) - 99.0).abs() <= 1.0);
        assert!((l.mean() - 50.5).abs() < 1e-9);
        assert_eq!(l.max(), 100.0);
    }

    #[test]
    fn empty_latency_is_nan() {
        let l = LatencyStats::new();
        assert!(l.percentile(50.0).is_nan());
        assert!(l.mean().is_nan());
    }

    #[test]
    fn empty_latency_formats_as_dash_not_zero() {
        let l = LatencyStats::new();
        assert_eq!(l.fmt_percentile(50.0, 1), "-");
        assert_eq!(l.fmt_percentile(99.0, 3), "-");
        assert_eq!(l.fmt_mean(2), "-");
        let mut one = LatencyStats::new();
        one.record(1.25);
        assert_eq!(one.fmt_percentile(50.0, 2), "1.25");
        assert_eq!(one.fmt_mean(1), "1.2");
    }

    #[test]
    fn merge_is_order_independent() {
        // Three workers' histograms with deliberately interleaved
        // values; every permutation of merge order must pin identical
        // percentiles.
        let mut workers = Vec::new();
        for seed in 0..3u64 {
            let mut l = LatencyStats::new();
            for i in 0..40u64 {
                // Cheap deterministic scatter, no RNG dependency.
                l.record(((seed * 40 + i) * 7919 % 1000) as f64 / 10.0);
            }
            workers.push(l);
        }
        let perms: [[usize; 3]; 6] = [
            [0, 1, 2],
            [0, 2, 1],
            [1, 0, 2],
            [1, 2, 0],
            [2, 0, 1],
            [2, 1, 0],
        ];
        let mut reference: Option<(f64, f64, f64)> = None;
        for perm in perms {
            let mut merged = LatencyStats::new();
            for &w in &perm {
                merged.merge(&workers[w]);
            }
            assert_eq!(merged.count(), 120);
            let got =
                (merged.percentile(50.0), merged.percentile(95.0), merged.percentile(99.0));
            match reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(got, want, "merge order {perm:?} diverged"),
            }
        }
    }
}
