//! Metric containers and computations.

/// Quality of one engine on one eval set (Table 1 analog row cell).
#[derive(Debug, Clone)]
pub struct QualityReport {
    pub engine: &'static str,
    pub eval_set: String,
    /// Bits per character (lower is better; the WER analog).
    pub bits_per_char: f64,
    /// Mean |float output − engine output| divergence, when measured.
    pub divergence: Option<f64>,
}

/// Real-time factor: processing time / audio (stream) time. The paper
/// reports integer ≈ 2x faster than float in RT factor (§6). For the
/// char-LM substitution we define stream time via a nominal
/// tokens-per-second rate.
#[derive(Debug, Clone, Copy)]
pub struct RtFactor {
    pub processing_secs: f64,
    pub stream_secs: f64,
}

impl RtFactor {
    pub const NOMINAL_TOKENS_PER_SEC: f64 = 1000.0;

    pub fn from_tokens(processing_secs: f64, tokens: usize) -> Self {
        RtFactor {
            processing_secs,
            stream_secs: tokens as f64 / Self::NOMINAL_TOKENS_PER_SEC,
        }
    }

    pub fn value(&self) -> f64 {
        self.processing_secs / self.stream_secs
    }
}

/// Latency statistics over a set of request completions.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples_ms.iter().fold(f64::NAN, |m, &v| if m.is_nan() { v } else { m.max(v) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rt_factor() {
        let rt = RtFactor::from_tokens(0.5, 1000);
        assert!((rt.value() - 0.5).abs() < 1e-12);
        assert!((rt.stream_secs - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyStats::new();
        for i in 1..=100 {
            l.record(f64::from(i));
        }
        assert_eq!(l.count(), 100);
        assert!((l.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile(99.0) - 99.0).abs() <= 1.0);
        assert!((l.mean() - 50.5).abs() < 1e-9);
        assert_eq!(l.max(), 100.0);
    }

    #[test]
    fn empty_latency_is_nan() {
        let l = LatencyStats::new();
        assert!(l.percentile(50.0).is_nan());
        assert!(l.mean().is_nan());
    }
}
