//! Integer-only non-linear activation functions (§3.2.1).
//!
//! Sigmoid and tanh evaluated entirely in 32-bit fixed point — no
//! floating point, no lookup tables, no inner-loop branching (the three
//! design principles of §3). Inputs are int16 in `Q_{m.15-m}` (the paper
//! selects `Q3.12` as the optimum, see [`error`] for the analysis) and
//! outputs are int16 in `Q0.15`, slightly clamped to
//! `[-1, 32767/32768]`.
//!
//! The algorithms are the gemmlowp family used by TFLite's integer LSTM:
//! range-reduced exponential with a barrel shifter of precomputed
//! `exp(-2^k)` multipliers, and Newton–Raphson reciprocal for
//! `1/(1+x)` — all expressed with saturating rounding doubling high
//! multiplies.

pub mod error;
pub mod exp;
pub mod fx;
pub mod sigmoid;
#[cfg(target_arch = "x86_64")]
pub mod simd;
pub mod tanh;

pub use exp::exp_on_negative_values;
pub use fx::Fx;
pub use sigmoid::{sigmoid_fx, sigmoid_q15};
pub use tanh::{tanh_fx, tanh_q15};

use crate::fixedpoint::mul::{rounding_divide_by_pot, saturate_i32_to_i16};

/// Evaluate integer sigmoid over a slice of int16 `Q_{ib.15-ib}` values
/// into int16 `Q0.15` outputs. Dispatches to the bit-exact AVX2 path
/// when available.
pub fn sigmoid_q15_slice(input: &[i16], integer_bits: u32, out: &mut [i16]) {
    assert_eq!(input.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    {
        if crate::util::avx2_enabled() {
            // SAFETY: feature checked.
            unsafe { simd::sigmoid_q15_slice_avx2(input, integer_bits, out) };
            return;
        }
    }
    for (o, &x) in out.iter_mut().zip(input) {
        *o = sigmoid_q15(x, integer_bits);
    }
}

/// Evaluate integer tanh over a slice of int16 `Q_{ib.15-ib}` values
/// into int16 `Q0.15` outputs. Dispatches to the bit-exact AVX2 path
/// when available.
pub fn tanh_q15_slice(input: &[i16], integer_bits: u32, out: &mut [i16]) {
    assert_eq!(input.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    {
        if crate::util::avx2_enabled() {
            // SAFETY: feature checked.
            unsafe { simd::tanh_q15_slice_avx2(input, integer_bits, out) };
            return;
        }
    }
    for (o, &x) in out.iter_mut().zip(input) {
        *o = tanh_q15(x, integer_bits);
    }
}

/// Convert a `Q0.31` raw value to `Q0.15` int16 (rounding, saturating).
#[inline]
pub(crate) fn q31_to_q15(raw: i32) -> i16 {
    saturate_i32_to_i16(rounding_divide_by_pot(raw, 16))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_helpers_match_scalar() {
        let xs: Vec<i16> = (-40..40).map(|i| (i * 800) as i16).collect();
        let mut s = vec![0i16; xs.len()];
        let mut t = vec![0i16; xs.len()];
        sigmoid_q15_slice(&xs, 3, &mut s);
        tanh_q15_slice(&xs, 3, &mut t);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(s[i], sigmoid_q15(x, 3));
            assert_eq!(t[i], tanh_q15(x, 3));
        }
    }
}
