//! The clamping-vs-resolution error analysis of §3.2.1.
//!
//! For an activation `f` evaluated on an int16 input in `Q_{m.15-m}`:
//!
//! * **clamping error** — inputs beyond `±2^m` saturate, contributing
//!   at most `f(∞) - f(2^m)`;
//! * **resolution error** — every value within a quantization bucket is
//!   represented by one point, contributing at most
//!   `2^-(15-m) * max f'(x)` (for tanh the max gradient is 1 at x = 0,
//!   so the paper's example is `tanh(2^-12) ≈ 2.44e-4`).
//!
//! As `m` grows the clamping error shrinks but the resolution error
//! doubles; the paper balances them and selects `Q3.12`. The
//! [`optimal_integer_bits`] function reproduces that conclusion
//! analytically, and `benches/activation_error.rs` regenerates the full
//! sweep (experiment E3 in DESIGN.md).

/// Which activation function the analysis applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Tanh,
    Sigmoid,
}

impl Activation {
    pub fn eval(&self, x: f64) -> f64 {
        match self {
            Activation::Tanh => x.tanh(),
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }

    /// Supremum of the derivative (attained at x = 0 for both).
    pub fn max_gradient(&self) -> f64 {
        match self {
            Activation::Tanh => 1.0,
            Activation::Sigmoid => 0.25,
        }
    }

    /// Limit at `+∞`.
    pub fn limit(&self) -> f64 {
        1.0
    }
}

/// Worst-case clamping error for input format `Q_{m.15-m}`:
/// `f(∞) - f(2^m)`.
///
/// Computed via the cancellation-free closed forms
/// `1 - tanh(x) = 2 / (e^{2x} + 1)` and `1 - σ(x) = 1 / (1 + e^x)`, so
/// the value stays meaningful for large `m` where the naive difference
/// underflows to zero in f64.
pub fn clamping_error(act: Activation, integer_bits: u32) -> f64 {
    let bound = 2f64.powi(integer_bits as i32);
    match act {
        Activation::Tanh => 2.0 / ((2.0 * bound).exp() + 1.0),
        Activation::Sigmoid => 1.0 / (1.0 + bound.exp()),
    }
}

/// Worst-case resolution error for input format `Q_{m.15-m}`:
/// `2^-(15-m) * max f'`.
pub fn resolution_error(act: Activation, integer_bits: u32) -> f64 {
    2f64.powi(integer_bits as i32 - 15) * act.max_gradient()
}

/// Total worst-case error model: clamping + resolution.
pub fn total_error(act: Activation, integer_bits: u32) -> f64 {
    clamping_error(act, integer_bits) + resolution_error(act, integer_bits)
}

/// The `m` in `Q_{m.15-m}` minimizing the total error model.
///
/// For tanh the optimum is exactly the paper's `Q3.12`. For sigmoid the
/// minimum is shallow between `m = 3` and `m = 4` (the smaller max
/// gradient of 1/4 discounts the resolution term); the paper selects
/// the *shared* format `Q3.12` for both activations, since the same
/// gate pre-activation tensor feeds either non-linearity and a single
/// format avoids a rescale (§3.2.1).
pub fn optimal_integer_bits(act: Activation) -> u32 {
    (0..=10)
        .min_by(|&a, &b| {
            total_error(act, a)
                .partial_cmp(&total_error(act, b))
                .unwrap()
        })
        .unwrap()
}

/// Measured maximum absolute error (in `Q0.15` output LSBs) of the
/// integer implementation against an f64 oracle, over the whole int16
/// input domain. Used by the E3 bench to show the implementation
/// tracks the analytical model.
pub fn measured_max_error_lsb(act: Activation, integer_bits: u32) -> f64 {
    let mut max_err: f64 = 0.0;
    for raw in (i32::from(i16::MIN)..=i32::from(i16::MAX)).step_by(3) {
        let x = raw as i16;
        let xf = f64::from(x) * 2f64.powi(integer_bits as i32 - 15);
        let got = match act {
            Activation::Tanh => f64::from(super::tanh_q15(x, integer_bits)),
            Activation::Sigmoid => f64::from(super::sigmoid_q15(x, integer_bits)),
        } / 32768.0;
        max_err = max_err.max((got - act.eval(xf)).abs() * 32768.0);
    }
    max_err
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_clamping_example() {
        // Paper: restricting tanh input to [-8, 8] (Q3.12) leaves a
        // clamping error of "1 - tanh(8) = 2.35e-7". The exact value is
        // 2.2507e-7 (the paper rounds loosely); assert the exact one.
        let e = clamping_error(Activation::Tanh, 3);
        assert!((e - 2.2507e-7).abs() < 0.01e-7, "got {e}");
    }

    #[test]
    fn paper_resolution_example() {
        // Paper: max resolution error for Q3.12 tanh is
        // tanh(2^-12) ≈ 2.44e-4.
        let e = resolution_error(Activation::Tanh, 3);
        assert!((e - 2.44e-4).abs() < 0.01e-4, "got {e}");
    }

    #[test]
    fn q312_is_optimal_for_tanh_and_near_optimal_for_sigmoid() {
        assert_eq!(optimal_integer_bits(Activation::Tanh), 3);
        // Sigmoid's minimum is shallow at m=4; m=3 must be within 4x of
        // it (and the shared-format argument picks m=3, see docs).
        let m = optimal_integer_bits(Activation::Sigmoid);
        assert!((3..=4).contains(&m), "sigmoid optimum m={m}");
        let at3 = total_error(Activation::Sigmoid, 3);
        let atm = total_error(Activation::Sigmoid, m);
        assert!(at3 <= 4.0 * atm, "m=3 err {at3} vs optimum {atm}");
    }

    #[test]
    fn error_tradeoff_shape() {
        // Clamping error decreases with m; resolution error increases.
        // (Closed forms keep the clamping error nonzero even for large
        // m, so strict monotonicity holds across the whole sweep.)
        for m in 0..8 {
            assert!(
                clamping_error(Activation::Tanh, m)
                    > clamping_error(Activation::Tanh, m + 1),
                "clamping not decreasing at m={m}"
            );
            assert!(
                resolution_error(Activation::Tanh, m)
                    < resolution_error(Activation::Tanh, m + 1)
            );
        }
    }
}
