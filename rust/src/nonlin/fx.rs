//! A runtime-parameterized 32-bit fixed-point value type.
//!
//! `Fx` carries a raw `i32` plus its number of *integer bits* `ib`; the
//! value represented is `raw * 2^-(31-ib)`. This mirrors gemmlowp's
//! `FixedPoint<int32, tIntegerBits>` but with the integer-bit count as
//! data rather than a type parameter, because the paper's recipe uses
//! *measured* cell-state formats (`Q_{m.15-m}` with data-dependent `m`,
//! §3.2.2) that are only known at quantization time.

use crate::fixedpoint::mul::{
    rounding_half_sum, saturating_rounding_doubling_high_mul,
    saturating_rounding_multiply_by_pot,
};

/// A signed fixed-point number: value = `raw * 2^-(31 - ib)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fx {
    pub raw: i32,
    /// Integer bits; fractional bits are `31 - ib`.
    pub ib: u32,
}

impl Fx {
    #[inline]
    pub const fn from_raw(raw: i32, ib: u32) -> Self {
        Fx { raw, ib }
    }

    /// Fractional bit count.
    #[inline]
    pub const fn frac_bits(&self) -> u32 {
        31 - self.ib
    }

    #[inline]
    pub const fn zero(ib: u32) -> Self {
        Fx { raw: 0, ib }
    }

    /// The representation of 1.0; saturated to `i32::MAX` when `ib == 0`
    /// (gemmlowp convention: `Q0.31` cannot represent 1 exactly).
    #[inline]
    pub const fn one(ib: u32) -> Self {
        if ib == 0 {
            Fx { raw: i32::MAX, ib }
        } else {
            Fx { raw: 1 << (31 - ib), ib }
        }
    }

    /// `2^exponent` as a fixed-point constant.
    #[inline]
    pub fn constant_pot(exponent: i32, ib: u32) -> Self {
        let offset = 31 - ib as i32 + exponent;
        assert!(
            (0..31).contains(&offset),
            "constant 2^{exponent} not representable with ib={ib}"
        );
        Fx { raw: 1 << offset, ib }
    }

    /// Build from a float (test/build-time only).
    pub fn from_f64(v: f64, ib: u32) -> Self {
        let scaled = v * 2f64.powi(31 - ib as i32);
        Fx { raw: scaled.round().clamp(i32::MIN as f64, i32::MAX as f64) as i32, ib }
    }

    /// Convert to float (test/build-time only).
    pub fn to_f64(&self) -> f64 {
        f64::from(self.raw) * 2f64.powi(-(31 - self.ib as i32))
    }

    /// Saturating addition; operands must share the same format.
    #[inline]
    pub fn add(self, rhs: Fx) -> Fx {
        debug_assert_eq!(self.ib, rhs.ib);
        Fx { raw: self.raw.saturating_add(rhs.raw), ib: self.ib }
    }

    /// Saturating subtraction; operands must share the same format.
    #[inline]
    pub fn sub(self, rhs: Fx) -> Fx {
        debug_assert_eq!(self.ib, rhs.ib);
        Fx { raw: self.raw.saturating_sub(rhs.raw), ib: self.ib }
    }

    /// Negation (saturates `i32::MIN`).
    #[inline]
    pub fn neg(self) -> Fx {
        Fx { raw: self.raw.saturating_neg(), ib: self.ib }
    }

    /// Fixed-point multiplication: result has `ib_a + ib_b` integer bits.
    #[inline]
    pub fn mul(self, rhs: Fx) -> Fx {
        Fx {
            raw: saturating_rounding_doubling_high_mul(self.raw, rhs.raw),
            ib: self.ib + rhs.ib,
        }
    }

    /// Exact multiply by a power of two (changes value, keeps format).
    #[inline]
    pub fn mul_by_pot(self, exponent: i32) -> Fx {
        Fx {
            raw: saturating_rounding_multiply_by_pot(self.raw, exponent),
            ib: self.ib,
        }
    }

    /// Convert to a different integer-bit count (same represented value,
    /// saturating if it does not fit).
    #[inline]
    pub fn rescale(self, to_ib: u32) -> Fx {
        let exponent = self.ib as i32 - to_ib as i32;
        Fx {
            raw: saturating_rounding_multiply_by_pot(self.raw, exponent),
            ib: to_ib,
        }
    }

    /// Rounding average of two same-format values.
    #[inline]
    pub fn half_sum(self, rhs: Fx) -> Fx {
        debug_assert_eq!(self.ib, rhs.ib);
        Fx { raw: rounding_half_sum(self.raw, rhs.raw), ib: self.ib }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f64() {
        for &(v, ib) in &[(0.5, 0u32), (-0.25, 0), (3.75, 3), (-7.99, 3), (1.0, 2)] {
            let f = Fx::from_f64(v, ib);
            assert!((f.to_f64() - v).abs() < 1e-8, "{v} ib={ib} -> {}", f.to_f64());
        }
    }

    #[test]
    fn one_is_saturated_at_ib0() {
        assert_eq!(Fx::one(0).raw, i32::MAX);
        assert!((Fx::one(0).to_f64() - 1.0).abs() < 1e-9);
        assert_eq!(Fx::one(2).raw, 1 << 29);
    }

    #[test]
    fn mul_adds_integer_bits() {
        let a = Fx::from_f64(0.5, 0);
        let b = Fx::from_f64(0.5, 2);
        let c = a.mul(b);
        assert_eq!(c.ib, 2);
        assert!((c.to_f64() - 0.25).abs() < 1e-8);
    }

    #[test]
    fn rescale_preserves_value() {
        let a = Fx::from_f64(1.5, 4);
        let b = a.rescale(2);
        assert_eq!(b.ib, 2);
        assert!((b.to_f64() - 1.5).abs() < 1e-7);
        // Saturates when the value does not fit the narrower format.
        let big = Fx::from_f64(7.5, 3);
        let sat = big.rescale(0);
        assert_eq!(sat.raw, i32::MAX);
    }

    #[test]
    fn constant_pot_values() {
        assert!((Fx::constant_pot(-2, 0).to_f64() - 0.25).abs() < 1e-12);
        assert!((Fx::constant_pot(0, 2).to_f64() - 1.0).abs() < 1e-12);
        assert!((Fx::constant_pot(1, 3).to_f64() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn arithmetic_basics() {
        let a = Fx::from_f64(0.3, 0);
        let b = Fx::from_f64(0.4, 0);
        assert!((a.add(b).to_f64() - 0.7).abs() < 1e-8);
        assert!((b.sub(a).to_f64() - 0.1).abs() < 1e-8);
        assert!((a.neg().to_f64() + 0.3).abs() < 1e-8);
        assert!((a.half_sum(b).to_f64() - 0.35).abs() < 1e-8);
        assert!((a.mul_by_pot(1).to_f64() - 0.6).abs() < 1e-8);
    }
}
