//! Integer-only hyperbolic tangent.
//!
//! `tanh(x) = sign(x) * (1 - exp(-2|x|)) / (1 + exp(-2|x|))`, computed
//! with the integer exponential of [`super::exp`] and a Newton–Raphson
//! reciprocal — gemmlowp's `tanh`, runtime-parameterized over the input
//! integer-bit count so the cell state's measured `Q_{m.15-m}` format
//! can feed tanh directly without a rescale (§3.2.2).

use super::exp::exp_on_negative_values;
use super::fx::Fx;
use super::q31_to_q15;

/// `(1 - x) / (1 + x)` for `x ∈ [0, 1]`, input/output `Q0.31`.
///
/// Newton–Raphson for the reciprocal of `(1 + x) / 2 ∈ [1/2, 1]`,
/// starting from the classic `48/17 - 32/17 * d` estimate, three
/// iterations (exact to Q0.31 resolution).
pub(crate) fn one_minus_x_over_one_plus_x_for_x_in_0_1(a: Fx) -> Fx {
    debug_assert_eq!(a.ib, 0);
    debug_assert!(a.raw >= 0);
    // half_denominator = (a + 1) / 2 in Q0.31, in [1/2, 1].
    let half_denominator = a.half_sum(Fx::one(0));
    // Newton-Raphson iterations in Q2.29.
    const CONSTANT_48_OVER_17: i32 = 1_515_870_810;
    const CONSTANT_NEG_32_OVER_17: i32 = -1_010_580_540;
    let mut x = Fx::from_raw(CONSTANT_48_OVER_17, 2)
        .add(half_denominator.mul(Fx::from_raw(CONSTANT_NEG_32_OVER_17, 2)));
    for _ in 0..3 {
        let half_denominator_times_x = half_denominator.mul(x); // ib 0+2=2
        let one_minus_half_denominator_times_x =
            Fx::one(2).sub(half_denominator_times_x);
        x = x.add(x.mul(one_minus_half_denominator_times_x).rescale(2));
    }
    // x ≈ 2 / (1 + a) in Q2.29; result = x - 1 = (1 - a) / (1 + a).
    x.sub(Fx::constant_pot(0, 2)).rescale(0)
}

/// tanh on a fixed-point value; input `Q_{ib.31-ib}`, output `Q0.31`.
pub fn tanh_fx(a: Fx) -> Fx {
    let neg_abs = Fx::from_raw(-(a.raw.saturating_abs()), a.ib);
    // exp(-2|a|): the doubling is *exact* — reinterpret the same raw
    // with one more integer bit (gemmlowp's `ExactMulByPot<1>`), so no
    // saturation occurs even at the edge of the input range.
    let exp_in = Fx::from_raw(neg_abs.raw, a.ib + 1);
    let e = exp_on_negative_values(exp_in);
    let t = one_minus_x_over_one_plus_x_for_x_in_0_1(e);
    if a.raw == 0 {
        Fx::zero(0)
    } else if a.raw < 0 {
        t.neg()
    } else {
        t
    }
}

/// tanh on an int16 `Q_{ib.15-ib}` value, returning int16 `Q0.15`.
///
/// This is the activation the paper's gates use (§3.2.1): the 16-bit
/// input is widened to `Q_{ib.31-ib}`, evaluated, and the `Q0.31`
/// result is rounded back down to `Q0.15`, clamping the output to
/// `[-1, 32767/32768]`.
#[inline]
pub fn tanh_q15(x: i16, integer_bits: u32) -> i16 {
    let widened = Fx::from_raw(i32::from(x) << 16, integer_bits);
    q31_to_q15(tanh_fx(widened).raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_minus_over_one_plus_accuracy() {
        for i in 0..=1000 {
            let v = f64::from(i) / 1000.0;
            let a = Fx::from_f64(v, 0);
            let got = one_minus_x_over_one_plus_x_for_x_in_0_1(a).to_f64();
            let want = (1.0 - v) / (1.0 + v);
            assert!((got - want).abs() < 1e-6, "x={v} got={got} want={want}");
        }
    }

    fn check_tanh_q15(ib: u32, tol_lsb: f64) {
        let mut max_err: f64 = 0.0;
        for raw in (i32::from(i16::MIN)..=i32::from(i16::MAX)).step_by(7) {
            let x = raw as i16;
            let xf = f64::from(x) * 2f64.powi(-(15 - ib as i32));
            let got = f64::from(tanh_q15(x, ib)) / 32768.0;
            let want = xf.tanh();
            max_err = max_err.max((got - want).abs() * 32768.0);
        }
        assert!(
            max_err <= tol_lsb,
            "ib={ib}: max error {max_err} Q0.15 LSBs"
        );
    }

    #[test]
    fn tanh_q312_accurate_to_few_lsb() {
        // Q3.12: the paper's chosen activation format.
        check_tanh_q15(3, 4.0);
    }

    #[test]
    fn tanh_q411_accurate() {
        // Q4.11: cell-state format fed directly to tanh (§3.2.2 example).
        check_tanh_q15(4, 4.0);
    }

    #[test]
    fn tanh_q015_and_wide_formats() {
        check_tanh_q15(0, 4.0);
        check_tanh_q15(1, 4.0);
        check_tanh_q15(2, 4.0);
        check_tanh_q15(5, 4.0);
        check_tanh_q15(6, 4.0);
    }

    #[test]
    fn tanh_odd_symmetry() {
        for ib in [0u32, 3, 4] {
            for x in [-30000i16, -12345, -512, -1, 0, 1, 512, 12345, 30000] {
                let p = tanh_q15(x, ib);
                let n = tanh_q15(x.saturating_neg(), ib);
                assert!(
                    (i32::from(p) + i32::from(n)).abs() <= 1,
                    "ib={ib} x={x}: {p} vs {n}"
                );
            }
        }
    }

    #[test]
    fn tanh_monotone() {
        let ib = 3;
        let mut prev = i16::MIN;
        for raw in (i32::from(i16::MIN)..=i32::from(i16::MAX)).step_by(11) {
            let y = tanh_q15(raw as i16, ib);
            assert!(y >= prev, "tanh not monotone at {raw}");
            prev = y;
        }
    }

    #[test]
    fn tanh_saturates_at_extremes() {
        // tanh(8) = 0.99999977; in Q0.15 that rounds to 32767.
        assert_eq!(tanh_q15(i16::MAX, 3), 32767);
        assert_eq!(tanh_q15(i16::MIN, 3), -32768);
        assert_eq!(tanh_q15(0, 3), 0);
    }
}
