//! Integer-only logistic sigmoid.
//!
//! `σ(x) = 1 / (1 + exp(-|x|))` for `x >= 0` and `1 - σ(|x|)` for
//! `x < 0`, with the integer exponential of [`super::exp`] and a
//! Newton–Raphson reciprocal (gemmlowp's `logistic`).

use super::exp::exp_on_negative_values;
use super::fx::Fx;
use super::q31_to_q15;

/// `1 / (1 + x)` for `x ∈ [0, 1]`, input/output `Q0.31`.
pub(crate) fn one_over_one_plus_x_for_x_in_0_1(a: Fx) -> Fx {
    debug_assert_eq!(a.ib, 0);
    debug_assert!(a.raw >= 0);
    let half_denominator = a.half_sum(Fx::one(0));
    const CONSTANT_48_OVER_17: i32 = 1_515_870_810;
    const CONSTANT_NEG_32_OVER_17: i32 = -1_010_580_540;
    let mut x = Fx::from_raw(CONSTANT_48_OVER_17, 2)
        .add(half_denominator.mul(Fx::from_raw(CONSTANT_NEG_32_OVER_17, 2)));
    for _ in 0..3 {
        let half_denominator_times_x = half_denominator.mul(x);
        let one_minus_half_denominator_times_x =
            Fx::one(2).sub(half_denominator_times_x);
        x = x.add(x.mul(one_minus_half_denominator_times_x).rescale(2));
    }
    // x ≈ 2 / (1 + a) in Q2.29; halve and narrow to Q0.31.
    x.mul_by_pot(-1).rescale(0)
}

/// Logistic sigmoid; input `Q_{ib.31-ib}`, output `Q0.31`.
pub fn sigmoid_fx(a: Fx) -> Fx {
    let neg_abs = Fx::from_raw(-(a.raw.saturating_abs()), a.ib);
    let e = exp_on_negative_values(neg_abs);
    let result_if_positive = one_over_one_plus_x_for_x_in_0_1(e);
    if a.raw >= 0 {
        result_if_positive
    } else {
        // 1 - σ(|a|); Q0.31 "one" is saturated, matching gemmlowp.
        Fx::one(0).sub(result_if_positive)
    }
}

/// Sigmoid on an int16 `Q_{ib.15-ib}` value, returning int16 `Q0.15`.
#[inline]
pub fn sigmoid_q15(x: i16, integer_bits: u32) -> i16 {
    let widened = Fx::from_raw(i32::from(x) << 16, integer_bits);
    q31_to_q15(sigmoid_fx(widened).raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reciprocal_accuracy() {
        for i in 0..=1000 {
            let v = f64::from(i) / 1000.0;
            let a = Fx::from_f64(v, 0);
            let got = one_over_one_plus_x_for_x_in_0_1(a).to_f64();
            let want = 1.0 / (1.0 + v);
            assert!((got - want).abs() < 1e-6, "x={v} got={got} want={want}");
        }
    }

    fn check_sigmoid_q15(ib: u32, tol_lsb: f64) {
        let mut max_err: f64 = 0.0;
        for raw in (i32::from(i16::MIN)..=i32::from(i16::MAX)).step_by(7) {
            let x = raw as i16;
            let xf = f64::from(x) * 2f64.powi(-(15 - ib as i32));
            let got = f64::from(sigmoid_q15(x, ib)) / 32768.0;
            let want = 1.0 / (1.0 + (-xf).exp());
            max_err = max_err.max((got - want).abs() * 32768.0);
        }
        assert!(max_err <= tol_lsb, "ib={ib}: max error {max_err} Q0.15 LSBs");
    }

    #[test]
    fn sigmoid_q312_accurate_to_few_lsb() {
        check_sigmoid_q15(3, 4.0);
    }

    #[test]
    fn sigmoid_other_formats() {
        for ib in [0u32, 1, 2, 4, 5, 6] {
            check_sigmoid_q15(ib, 4.0);
        }
    }

    #[test]
    fn sigmoid_at_zero_is_half() {
        for ib in 0..=6 {
            let y = sigmoid_q15(0, ib);
            assert!((i32::from(y) - 16384).abs() <= 1, "ib={ib} y={y}");
        }
    }

    #[test]
    fn sigmoid_complement_symmetry() {
        // σ(-x) = 1 - σ(x)
        for x in [-30000i16, -5000, -100, 100, 5000, 30000] {
            let p = i32::from(sigmoid_q15(x, 3));
            let n = i32::from(sigmoid_q15(x.saturating_neg(), 3));
            assert!(
                (p + n - 32768).abs() <= 2,
                "x={x}: σ(x)={p} σ(-x)={n}"
            );
        }
    }

    #[test]
    fn sigmoid_monotone_and_bounded() {
        let mut prev = i16::MIN;
        for raw in (i32::from(i16::MIN)..=i32::from(i16::MAX)).step_by(13) {
            let y = sigmoid_q15(raw as i16, 3);
            assert!(y >= prev);
            assert!(y >= 0, "sigmoid must be nonnegative, got {y}");
            prev = y;
        }
        // σ(8 - 2^-12) = 0.9996645 -> 32757 in Q0.15 (not saturated:
        // unlike tanh, sigmoid at the Q3.12 edge is still well inside
        // the representable range).
        assert_eq!(sigmoid_q15(i16::MAX, 3), 32757);
        // σ(-8) = 3.3535e-4 -> 11 in Q0.15.
        assert_eq!(sigmoid_q15(i16::MIN, 3), 11);
        // At wider formats the edges do saturate.
        assert_eq!(sigmoid_q15(i16::MAX, 6), 32767);
        assert_eq!(sigmoid_q15(i16::MIN, 6), 0);
    }
}
