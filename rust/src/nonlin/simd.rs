//! AVX2 8-lane implementation of the integer activations.
//!
//! **Bit-exact** with the scalar path in [`super::exp`]/[`super::tanh`]/
//! [`super::sigmoid`] — asserted over the entire int16 input domain for
//! every integer-bit count by `simd_matches_scalar_everywhere`. The
//! barrel shifter and sign handling become branchless lane blends,
//! which is also how the paper's "no inner loop branching" principle
//! deploys on SIMD CPUs.

#![cfg(target_arch = "x86_64")]
#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

const I32_MAX_V: i32 = i32::MAX;

/// Saturating i32 lane add (mirrors `i32::saturating_add`).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn sat_add(a: __m256i, b: __m256i) -> __m256i {
    let sum = _mm256_add_epi32(a, b);
    // Overflow iff sign(a) == sign(b) != sign(sum).
    let ov = _mm256_and_si256(_mm256_xor_si256(a, sum), _mm256_xor_si256(b, sum));
    let ov_mask = _mm256_srai_epi32(ov, 31);
    // Saturated value: MAX if a >= 0 else MIN (a's sign picks).
    let sat = _mm256_xor_si256(
        _mm256_set1_epi32(I32_MAX_V),
        _mm256_srai_epi32(a, 31),
    );
    _mm256_blendv_epi8(sum, sat, ov_mask)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn sat_sub(a: __m256i, b: __m256i) -> __m256i {
    let diff = _mm256_sub_epi32(a, b);
    let ov = _mm256_and_si256(_mm256_xor_si256(a, b), _mm256_xor_si256(a, diff));
    let ov_mask = _mm256_srai_epi32(ov, 31);
    let sat = _mm256_xor_si256(
        _mm256_set1_epi32(I32_MAX_V),
        _mm256_srai_epi32(a, 31),
    );
    _mm256_blendv_epi8(diff, sat, ov_mask)
}

/// Saturating rounding doubling high multiply on 8 i32 lanes.
///
/// Mirrors `saturating_rounding_doubling_high_mul`: 64-bit product,
/// nudge, truncating divide by 2^31, with the MIN*MIN saturation.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn srdhm(a: __m256i, b: __m256i) -> __m256i {
    // Scalar computes trunc((ab + nudge) / 2^31) with nudge = 2^30 for
    // ab >= 0 and 1 - 2^30 for ab < 0. Truncating division of a
    // negative v by 2^31 equals floor((v + 2^31 - 1) / 2^31), and
    // (1 - 2^30) + (2^31 - 1) = 2^30 — identical to the positive-path
    // constant. So for *both* signs: result = (ab + 2^30) >> 31
    // (floor), one add, no blends. The shift is a logical 64-bit shift:
    // the result fits i32, so the low 32 bits are correct.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn half(a64: __m256i, b64: __m256i) -> __m256i {
        let ab = _mm256_mul_epi32(a64, b64); // 4 × i64
        let v = _mm256_add_epi64(ab, _mm256_set1_epi64x(1 << 30));
        _mm256_srli_epi64(v, 31)
    }
    // Even lanes (0,2,4,6) already sit in i64-lane low halves.
    let even = half(a, b);
    // Odd lanes: shift them down into the low halves.
    let odd = half(_mm256_srli_epi64(a, 32), _mm256_srli_epi64(b, 32));
    // Interleave low 32 bits of each i64: even lanes keep position,
    // odd go back up.
    let result = _mm256_blend_epi32(even, _mm256_slli_epi64(odd, 32), 0b10101010);
    // Saturate the MIN*MIN case.
    let min = _mm256_set1_epi32(i32::MIN);
    let both_min = _mm256_and_si256(_mm256_cmpeq_epi32(a, min), _mm256_cmpeq_epi32(b, min));
    _mm256_blendv_epi8(result, _mm256_set1_epi32(I32_MAX_V), both_min)
}

/// Rounding divide by power of two (runtime exponent), 8 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn rdbp(x: __m256i, exponent: i32) -> __m256i {
    if exponent == 0 {
        return x;
    }
    let mask = _mm256_set1_epi32(((1i64 << exponent) - 1) as i32);
    let remainder = _mm256_and_si256(x, mask);
    let one_if_neg = _mm256_srli_epi32(_mm256_srai_epi32(x, 31), 31);
    let threshold = _mm256_add_epi32(_mm256_srai_epi32(mask, 1), one_if_neg);
    let shifted = _mm256_sra_epi32(x, _mm_cvtsi32_si128(exponent));
    let add_one = _mm256_srli_epi32(_mm256_cmpgt_epi32(remainder, threshold), 31);
    _mm256_add_epi32(shifted, add_one)
}

/// Saturating multiply by 2^exponent (runtime exponent), 8 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn srmbp(x: __m256i, exponent: i32) -> __m256i {
    if exponent == 0 {
        x
    } else if exponent < 0 {
        rdbp(x, -exponent)
    } else {
        let hi = _mm256_set1_epi32(I32_MAX_V >> exponent);
        let lo = _mm256_set1_epi32(i32::MIN >> exponent);
        let over = _mm256_cmpgt_epi32(x, hi);
        let under = _mm256_cmpgt_epi32(lo, x);
        let shifted = _mm256_sll_epi32(
            _mm256_max_epi32(lo, _mm256_min_epi32(hi, x)),
            _mm_cvtsi32_si128(exponent),
        );
        let r = _mm256_blendv_epi8(shifted, _mm256_set1_epi32(I32_MAX_V), over);
        _mm256_blendv_epi8(r, _mm256_set1_epi32(i32::MIN), under)
    }
}

/// Rounding half sum (mirrors scalar `rounding_half_sum`).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn half_sum(a: __m256i, b: __m256i) -> __m256i {
    // Values here are in [0, 2^31-1] + [2^31-1] — the only caller uses
    // a >= 0, b = i32::MAX — so sum >= 0 and (sum + 1) / 2 suffices; do
    // it in 64-bit halves to avoid overflow.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn half(a64: __m256i, b64: __m256i) -> __m256i {
        let mask = _mm256_set1_epi64x(0xFFFF_FFFF);
        let s = _mm256_add_epi64(
            _mm256_and_si256(a64, mask),
            _mm256_and_si256(b64, mask),
        );
        // Inputs are nonnegative i32s: plain (s+1)>>1.
        _mm256_srli_epi64(_mm256_add_epi64(s, _mm256_set1_epi64x(1)), 1)
    }
    let even = half(a, b);
    let odd = half(_mm256_srli_epi64(a, 32), _mm256_srli_epi64(b, 32));
    _mm256_blend_epi32(even, _mm256_slli_epi64(odd, 32), 0b10101010)
}

const EXP_BARREL: [(i32, i32); 7] = [
    (-2, 1_672_461_947),
    (-1, 1_302_514_674),
    (0, 790_015_084),
    (1, 290_630_308),
    (2, 39_332_535),
    (3, 720_401),
    (4, 242),
];

/// exp on [-1/4, 0) interval, Q0.31 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn exp_interval(a: __m256i) -> __m256i {
    let ct = _mm256_set1_epi32(1_895_147_668);
    let third = _mm256_set1_epi32(715_827_883);
    let x = sat_add(a, _mm256_set1_epi32(1 << 28));
    let x2 = srdhm(x, x);
    let x3 = srdhm(x2, x);
    let x4 = srdhm(x2, x2);
    let x4_over_4 = rdbp(x4, 2);
    let inner = sat_add(srdhm(sat_add(x4_over_4, x3), third), x2);
    let poly = rdbp(inner, 1);
    sat_add(ct, srdhm(ct, sat_add(x, poly)))
}

/// exp(a) for a <= 0; lanes hold raw values with `31-ib` fractional
/// bits; result Q0.31. Mirrors `exp_on_negative_values` exactly.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn exp_neg(a: __m256i, ib: i32) -> __m256i {
    let frac_bits = 31 - ib;
    let one_quarter = _mm256_set1_epi32(1 << (frac_bits - 2));
    let mask = _mm256_set1_epi32((1 << (frac_bits - 2)) - 1);
    let a_mod = _mm256_sub_epi32(_mm256_and_si256(a, mask), one_quarter);
    let interval_in = srmbp(a_mod, ib);
    let mut result = exp_interval(interval_in);
    let remainder = _mm256_sub_epi32(a_mod, a); // wrapping, like scalar
    for (exponent, multiplier) in EXP_BARREL {
        if ib > exponent {
            let pos = frac_bits + exponent;
            if (0..31).contains(&pos) {
                let bit = _mm256_set1_epi32(1 << pos);
                let fire = _mm256_cmpeq_epi32(
                    _mm256_and_si256(remainder, bit),
                    bit,
                );
                let mul = srdhm(result, _mm256_set1_epi32(multiplier));
                result = _mm256_blendv_epi8(result, mul, fire);
            }
        }
    }
    if ib > 5 {
        let clamp = _mm256_set1_epi32(-(1i64 << (frac_bits + 5)) as i32);
        let below = _mm256_cmpgt_epi32(clamp, a);
        result = _mm256_andnot_si256(below, result);
    }
    let zero_in = _mm256_cmpeq_epi32(a, _mm256_setzero_si256());
    _mm256_blendv_epi8(result, _mm256_set1_epi32(I32_MAX_V), zero_in)
}

/// Newton–Raphson `2/(1+a)` core shared by both reciprocal forms.
/// Input a in [0,1] Q0.31, output x ≈ 2/(1+a) in Q2.29.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn newton_two_over_one_plus(a: __m256i) -> __m256i {
    let half_denominator = half_sum(a, _mm256_set1_epi32(I32_MAX_V));
    let mut x = sat_add(
        _mm256_set1_epi32(1_515_870_810),
        srdhm(half_denominator, _mm256_set1_epi32(-1_010_580_540)),
    );
    for _ in 0..3 {
        let hdx = srdhm(half_denominator, x);
        let one_minus = sat_sub(_mm256_set1_epi32(1 << 29), hdx);
        let delta = srmbp(srdhm(x, one_minus), 2);
        x = sat_add(x, delta);
    }
    x
}

/// `(1-x)/(1+x)` on Q0.31 lanes (mirrors scalar).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn one_minus_over_one_plus(a: __m256i) -> __m256i {
    let x = newton_two_over_one_plus(a);
    srmbp(sat_sub(x, _mm256_set1_epi32(1 << 29)), 2)
}

/// `1/(1+x)` on Q0.31 lanes (mirrors scalar).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn one_over_one_plus(a: __m256i) -> __m256i {
    let x = newton_two_over_one_plus(a);
    srmbp(rdbp(x, 1), 2)
}

/// Q0.31 lanes -> Q0.15 int16 (matches scalar `q31_to_q15`).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn q31_to_q15(raw: __m256i) -> __m256i {
    let q = rdbp(raw, 16);
    _mm256_max_epi32(
        _mm256_set1_epi32(-32768),
        _mm256_min_epi32(_mm256_set1_epi32(32767), q),
    )
}

/// `-(x.saturating_abs())` per lane (scalar semantics: MIN maps to
/// MIN+1, not MIN).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn neg_abs_saturating(x: __m256i) -> __m256i {
    let abs = _mm256_abs_epi32(x); // MIN wraps to MIN
    let is_min = _mm256_cmpeq_epi32(x, _mm256_set1_epi32(i32::MIN));
    let abs_sat = _mm256_blendv_epi8(abs, _mm256_set1_epi32(I32_MAX_V), is_min);
    _mm256_sub_epi32(_mm256_setzero_si256(), abs_sat)
}

/// 8-lane tanh: input int16 `Q_{ib.15-ib}` widened in lanes, output
/// int16 `Q0.15` in lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn tanh8(widened: __m256i, ib: i32) -> __m256i {
    let zero = _mm256_setzero_si256();
    let neg_abs = neg_abs_saturating(widened);
    let e = exp_neg(neg_abs, ib + 1);
    let t = one_minus_over_one_plus(e);
    let negative = _mm256_cmpgt_epi32(zero, widened);
    let signed = _mm256_blendv_epi8(t, _mm256_sub_epi32(zero, t), negative);
    let is_zero = _mm256_cmpeq_epi32(widened, zero);
    q31_to_q15(_mm256_andnot_si256(is_zero, signed))
}

/// 8-lane sigmoid.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn sigmoid8(widened: __m256i, ib: i32) -> __m256i {
    let zero = _mm256_setzero_si256();
    let neg_abs = neg_abs_saturating(widened);
    let e = exp_neg(neg_abs, ib);
    let pos = one_over_one_plus(e);
    let negative = _mm256_cmpgt_epi32(zero, widened);
    let flipped = sat_sub(_mm256_set1_epi32(I32_MAX_V), pos);
    q31_to_q15(_mm256_blendv_epi8(pos, flipped, negative))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn load_widened(src: &[i16], i: usize) -> __m256i {
    let x16 = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
    _mm256_slli_epi32(_mm256_cvtepi16_epi32(x16), 16)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn store_q15(dst: &mut [i16], i: usize, lanes: __m256i) {
    // Lanes are already clamped to i16 range; pack via shuffle.
    let packed = _mm256_packs_epi32(lanes, lanes); // duplicates per 128 lane
    let lo = _mm256_castsi256_si128(packed);
    let hi = _mm256_extracti128_si256(packed, 1);
    let out = _mm_unpacklo_epi64(lo, hi);
    _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, out);
}

/// AVX2 tanh over a slice (called from `nonlin::tanh_q15_slice`).
///
/// # Safety
/// Caller must have verified AVX2 support.
#[target_feature(enable = "avx2")]
pub unsafe fn tanh_q15_slice_avx2(input: &[i16], ib: u32, out: &mut [i16]) {
    let n = input.len();
    let mut i = 0;
    while i + 8 <= n {
        let w = load_widened(input, i);
        store_q15(out, i, tanh8(w, ib as i32));
        i += 8;
    }
    for j in i..n {
        out[j] = super::tanh::tanh_q15(input[j], ib);
    }
}

/// AVX2 sigmoid over a slice.
///
/// # Safety
/// Caller must have verified AVX2 support.
#[target_feature(enable = "avx2")]
pub unsafe fn sigmoid_q15_slice_avx2(input: &[i16], ib: u32, out: &mut [i16]) {
    let n = input.len();
    let mut i = 0;
    while i + 8 <= n {
        let w = load_widened(input, i);
        store_q15(out, i, sigmoid8(w, ib as i32));
        i += 8;
    }
    for j in i..n {
        out[j] = super::sigmoid::sigmoid_q15(input[j], ib);
    }
}

#[cfg(test)]
mod tests {
    use crate::nonlin::{sigmoid_q15, tanh_q15};

    fn avx2() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    #[test]
    fn simd_matches_scalar_everywhere() {
        if !avx2() {
            eprintln!("no AVX2; skipping");
            return;
        }
        // Entire int16 domain for the formats the cell uses.
        for ib in 0..=6u32 {
            let input: Vec<i16> =
                (i16::MIN..=i16::MAX).step_by(1).collect();
            let mut got_t = vec![0i16; input.len()];
            let mut got_s = vec![0i16; input.len()];
            unsafe {
                super::tanh_q15_slice_avx2(&input, ib, &mut got_t);
                super::sigmoid_q15_slice_avx2(&input, ib, &mut got_s);
            }
            for (k, &x) in input.iter().enumerate() {
                assert_eq!(got_t[k], tanh_q15(x, ib), "tanh ib={ib} x={x}");
                assert_eq!(got_s[k], sigmoid_q15(x, ib), "sigmoid ib={ib} x={x}");
            }
        }
    }

    #[test]
    fn simd_handles_short_tails() {
        if !avx2() {
            return;
        }
        for n in [0usize, 1, 3, 7, 8, 9, 15, 17] {
            let input: Vec<i16> = (0..n).map(|i| (i as i16) * 991).collect();
            let mut out = vec![0i16; n];
            unsafe { super::tanh_q15_slice_avx2(&input, 3, &mut out) };
            for (k, &x) in input.iter().enumerate() {
                assert_eq!(out[k], tanh_q15(x, 3));
            }
        }
    }
}

// ---------------------------------------------------------------------
// Fused integer-cell elementwise kernels (used by lstm::integer_cell).
// ---------------------------------------------------------------------

use crate::fixedpoint::Rescale;

/// `MultiplyByQuantizedMultiplier` on 8 lanes — mirrors
/// `Rescale::apply` exactly (saturating pre-shift, srdhm, rounding
/// post-shift).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn apply_rescale8(x: __m256i, r: Rescale) -> __m256i {
    let left = if r.shift > 0 { r.shift } else { 0 };
    let right = if r.shift > 0 { 0 } else { -r.shift };
    let shifted = if left == 0 {
        x
    } else if left >= 31 {
        // Mirror the scalar saturation-by-sign path.
        let pos = _mm256_cmpgt_epi32(x, _mm256_setzero_si256());
        let neg = _mm256_cmpgt_epi32(_mm256_setzero_si256(), x);
        let mut v = _mm256_setzero_si256();
        v = _mm256_blendv_epi8(v, _mm256_set1_epi32(I32_MAX_V), pos);
        _mm256_blendv_epi8(v, _mm256_set1_epi32(i32::MIN), neg)
    } else {
        srmbp(x, left)
    };
    let prod = srdhm(shifted, _mm256_set1_epi32(r.multiplier));
    if right == 0 { prod } else { rdbp(prod, right) }
}

/// Fused gate pre-activation (fig 3, no peephole):
/// `out = sat_i16(rescale(acc_x, eff_x) + rescale(acc_h, eff_h))`.
///
/// # Safety
/// Caller must have verified AVX2 support; slices must share length.
#[target_feature(enable = "avx2")]
pub unsafe fn gate_rescale_avx2(
    acc_x: &[i32],
    eff_x: Rescale,
    acc_h: &[i32],
    eff_h: Rescale,
    out: &mut [i16],
) {
    let n = out.len();
    let mut i = 0;
    while i + 8 <= n {
        let ax = _mm256_loadu_si256(acc_x.as_ptr().add(i) as *const __m256i);
        let ah = _mm256_loadu_si256(acc_h.as_ptr().add(i) as *const __m256i);
        // Scalar uses plain `+` between the two rescaled i32s (each
        // bounded well inside i16 after saturation to the gate domain):
        let sum = _mm256_add_epi32(apply_rescale8(ax, eff_x), apply_rescale8(ah, eff_h));
        let clamped = _mm256_max_epi32(
            _mm256_set1_epi32(-32768),
            _mm256_min_epi32(_mm256_set1_epi32(32767), sum),
        );
        store_q15(out, i, clamped);
        i += 8;
    }
    for j in i..n {
        let sum = eff_x.apply(acc_x[j]) + eff_h.apply(acc_h[j]);
        out[j] = crate::fixedpoint::mul::saturate_i32_to_i16(sum);
    }
}

/// Fused gate pre-activation with peephole (`P ⊙ c` rescaled in):
/// `out = sat_i16(rescale(acc_x) + rescale(acc_h) + rescale(P*c))`.
///
/// # Safety
/// AVX2 must be available; slices must share length.
#[target_feature(enable = "avx2")]
pub unsafe fn gate_rescale_peephole_avx2(
    acc_x: &[i32],
    eff_x: Rescale,
    acc_h: &[i32],
    eff_h: Rescale,
    peephole: &[i16],
    c: &[i16],
    eff_c: Rescale,
    out: &mut [i16],
) {
    let n = out.len();
    let mut i = 0;
    while i + 8 <= n {
        let ax = _mm256_loadu_si256(acc_x.as_ptr().add(i) as *const __m256i);
        let ah = _mm256_loadu_si256(acc_h.as_ptr().add(i) as *const __m256i);
        let p = _mm256_cvtepi16_epi32(
            _mm_loadu_si128(peephole.as_ptr().add(i) as *const __m128i),
        );
        let cc = _mm256_cvtepi16_epi32(
            _mm_loadu_si128(c.as_ptr().add(i) as *const __m128i),
        );
        let pc = _mm256_mullo_epi32(p, cc);
        let sum = _mm256_add_epi32(
            _mm256_add_epi32(apply_rescale8(ax, eff_x), apply_rescale8(ah, eff_h)),
            apply_rescale8(pc, eff_c),
        );
        let clamped = _mm256_max_epi32(
            _mm256_set1_epi32(-32768),
            _mm256_min_epi32(_mm256_set1_epi32(32767), sum),
        );
        store_q15(out, i, clamped);
        i += 8;
    }
    for j in i..n {
        let pc = i32::from(peephole[j]) * i32::from(c[j]);
        let sum = eff_x.apply(acc_x[j]) + eff_h.apply(acc_h[j]) + eff_c.apply(pc);
        out[j] = crate::fixedpoint::mul::saturate_i32_to_i16(sum);
    }
}

/// Fused hidden-state production (§3.2.7):
/// `m = sat_i8(rescale(o ⊙ tanh_c, eff) + zp)`.
///
/// # Safety
/// AVX2 must be available; slices must share length.
#[target_feature(enable = "avx2")]
pub unsafe fn hidden_rescale_avx2(
    o_act: &[i16],
    tanh_c: &[i16],
    eff: Rescale,
    zp: i32,
    out: &mut [i8],
) {
    let n = out.len();
    let mut i = 0;
    while i + 8 <= n {
        let o = _mm256_cvtepi16_epi32(
            _mm_loadu_si128(o_act.as_ptr().add(i) as *const __m128i),
        );
        let t = _mm256_cvtepi16_epi32(
            _mm_loadu_si128(tanh_c.as_ptr().add(i) as *const __m128i),
        );
        let prod = _mm256_mullo_epi32(o, t);
        let v = _mm256_add_epi32(apply_rescale8(prod, eff), _mm256_set1_epi32(zp));
        let clamped = _mm256_max_epi32(
            _mm256_set1_epi32(-128),
            _mm256_min_epi32(_mm256_set1_epi32(127), v),
        );
        // Pack 8 × i32 -> 8 × i8.
        let packed16 = _mm256_packs_epi32(clamped, clamped);
        let lo = _mm256_castsi256_si128(packed16);
        let hi = _mm256_extracti128_si256(packed16, 1);
        let both16 = _mm_unpacklo_epi64(lo, hi);
        let packed8 = _mm_packs_epi16(both16, both16);
        let lanes: [i8; 16] = std::mem::transmute(packed8);
        out[i..i + 8].copy_from_slice(&lanes[..8]);
        i += 8;
    }
    for j in i..n {
        let prod = i32::from(o_act[j]) * i32::from(tanh_c[j]);
        out[j] = crate::fixedpoint::mul::saturate_i32_to_i8(eff.apply(prod) + zp);
    }
}

#[cfg(test)]
mod fused_tests {
    use crate::fixedpoint::mul::{saturate_i32_to_i16, saturate_i32_to_i8};
    use crate::fixedpoint::Rescale;
    use crate::util::proptest;

    fn avx2() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    #[test]
    fn gate_rescale_matches_scalar() {
        if !avx2() {
            return;
        }
        proptest::check("gate-rescale-simd", |rng| {
            let n = rng.below(70) as usize;
            let ax: Vec<i32> = (0..n).map(|_| rng.range_i32(-(1 << 24), 1 << 24)).collect();
            let ah: Vec<i32> = (0..n).map(|_| rng.range_i32(-(1 << 24), 1 << 24)).collect();
            let rx = Rescale::from_scale(rng.uniform(1e-6, 4.0));
            let rh = Rescale::from_scale(rng.uniform(1e-6, 4.0));
            let mut got = vec![0i16; n];
            unsafe { super::gate_rescale_avx2(&ax, rx, &ah, rh, &mut got) };
            for j in 0..n {
                let want = saturate_i32_to_i16(rx.apply(ax[j]) + rh.apply(ah[j]));
                assert_eq!(got[j], want, "j={j}");
            }
        });
    }

    #[test]
    fn gate_rescale_peephole_matches_scalar() {
        if !avx2() {
            return;
        }
        proptest::check("gate-rescale-ph-simd", |rng| {
            let n = rng.below(40) as usize;
            let ax: Vec<i32> = (0..n).map(|_| rng.range_i32(-(1 << 24), 1 << 24)).collect();
            let ah: Vec<i32> = (0..n).map(|_| rng.range_i32(-(1 << 24), 1 << 24)).collect();
            let p: Vec<i16> = (0..n).map(|_| rng.range_i32(-32767, 32767) as i16).collect();
            let c: Vec<i16> = (0..n).map(|_| rng.range_i32(-32768, 32767) as i16).collect();
            let rx = Rescale::from_scale(rng.uniform(1e-6, 2.0));
            let rh = Rescale::from_scale(rng.uniform(1e-6, 2.0));
            let rc = Rescale::from_scale(rng.uniform(1e-9, 0.1));
            let mut got = vec![0i16; n];
            unsafe {
                super::gate_rescale_peephole_avx2(&ax, rx, &ah, rh, &p, &c, rc, &mut got)
            };
            for j in 0..n {
                let pc = i32::from(p[j]) * i32::from(c[j]);
                let want =
                    saturate_i32_to_i16(rx.apply(ax[j]) + rh.apply(ah[j]) + rc.apply(pc));
                assert_eq!(got[j], want, "j={j}");
            }
        });
    }

    #[test]
    fn hidden_rescale_matches_scalar() {
        if !avx2() {
            return;
        }
        proptest::check("hidden-rescale-simd", |rng| {
            let n = rng.below(70) as usize;
            let o: Vec<i16> = (0..n).map(|_| rng.range_i32(0, 32767) as i16).collect();
            let t: Vec<i16> = (0..n).map(|_| rng.range_i32(-32768, 32767) as i16).collect();
            let eff = Rescale::from_scale(rng.uniform(1e-9, 1e-3));
            let zp = rng.range_i32(-128, 127);
            let mut got = vec![0i8; n];
            unsafe { super::hidden_rescale_avx2(&o, &t, eff, zp, &mut got) };
            for j in 0..n {
                let prod = i32::from(o[j]) * i32::from(t[j]);
                let want = saturate_i32_to_i8(eff.apply(prod) + zp);
                assert_eq!(got[j], want, "j={j}");
            }
        });
    }
}
