//! Integer-only `exp(x)` for `x <= 0` — the backbone of integer sigmoid
//! and tanh.
//!
//! Range reduction: write `x = r + Σ_k b_k · (-2^k)` with
//! `r ∈ (-1/4, 0]`; evaluate `exp(r)` with a 4th-order Taylor expansion
//! around `-1/8`, then multiply by precomputed `Q0.31` constants
//! `exp(-2^k)` selected by the bits `b_k` of the remainder (a "barrel
//! shifter" — branchless in SIMD deployments, the paper's principle #2).
//! This is gemmlowp's `exp_on_negative_values`, generalized to a runtime
//! integer-bit count.

use super::fx::Fx;

/// `exp(a + 1/4) * exp(-1/4)`-style interval kernel:
/// evaluates `exp(a)` for `a ∈ [-1/4, 0)` given in `Q0.31`.
///
/// Uses the Taylor expansion of `exp` around `-1/8`:
/// `exp(-1/8) * (1 + x + x²/2 + x³/6 + x⁴/24)` with `x = a + 1/8`,
/// computed as gemmlowp does (constants in `Q0.31`).
pub(crate) fn exp_on_interval_between_negative_one_quarter_and_0_excl(a: Fx) -> Fx {
    debug_assert_eq!(a.ib, 0);
    debug_assert!(a.raw <= 0);
    const CONSTANT_TERM: i32 = 1_895_147_668; // exp(-1/8) in Q0.31
    const CONSTANT_1_OVER_3: i32 = 715_827_883; // 1/3 in Q0.31
    let constant_term = Fx::from_raw(CONSTANT_TERM, 0);
    let constant_1_over_3 = Fx::from_raw(CONSTANT_1_OVER_3, 0);
    // x = a + 1/8 is the offset from the expansion point -1/8, so
    // x ∈ [-1/8, 1/8) and exp(a) = exp(-1/8) * exp(x).
    let x = a.add(Fx::constant_pot(-3, 0));
    let x2 = x.mul(x);
    let x3 = x2.mul(x);
    let x4 = x2.mul(x2);
    let x4_over_4 = x4.mul_by_pot(-2);
    let x4_over_24_plus_x3_over_6_plus_x2_over_2 =
        x4_over_4.add(x3).mul(constant_1_over_3).add(x2).mul_by_pot(-1);
    constant_term.add(
        constant_term.mul(x.add(x4_over_24_plus_x3_over_6_plus_x2_over_2)),
    )
}

/// Barrel-shifter multipliers: `exp(-2^k) * 2^31` for
/// `k = -2, -1, 0, 1, 2, 3, 4` (gemmlowp's constants).
const EXP_BARREL: [(i32, i32); 7] = [
    (-2, 1_672_461_947), // exp(-1/4)
    (-1, 1_302_514_674), // exp(-1/2)
    (0, 790_015_084),    // exp(-1)
    (1, 290_630_308),    // exp(-2)
    (2, 39_332_535),     // exp(-4)
    (3, 720_401),        // exp(-8)
    (4, 242),            // exp(-16)
];

/// `exp(a)` for `a <= 0`, input in `Q_{ib.31-ib}`, output in `Q0.31`.
pub fn exp_on_negative_values(a: Fx) -> Fx {
    debug_assert!(a.raw <= 0, "exp_on_negative_values requires a <= 0");
    let ib = a.ib as i32;
    let frac_bits = 31 - ib;
    if ib == 0 {
        // Input already in (-1, 0]; reduce within [-1/4, 0) directly.
        return exp_ib0(a);
    }
    let one_quarter: i32 = 1 << (frac_bits - 2);
    let mask = one_quarter - 1;
    // a_mod_quarter_minus_one_quarter in [-1/4, 0).
    let a_mod = (a.raw & mask) - one_quarter;
    let interval_input = Fx::from_raw(a_mod, a.ib).rescale(0);
    let mut result = exp_on_interval_between_negative_one_quarter_and_0_excl(interval_input);
    // remainder holds which multiples of powers of two were subtracted.
    let remainder = a_mod.wrapping_sub(a.raw);
    for &(exponent, multiplier) in &EXP_BARREL {
        if ib > exponent {
            let shift = frac_bits + exponent;
            if (0..31).contains(&shift) && remainder & (1 << shift) != 0 {
                result = result.mul(Fx::from_raw(multiplier, 0));
            }
        }
    }
    if ib > 5 {
        // Clamp: exp(x) for x < -32 is 0 at Q0.31 resolution.
        let clamp_raw = -(1i64 << (frac_bits + 5)) as i32;
        if a.raw < clamp_raw {
            result = Fx::zero(0);
        }
    }
    if a.raw == 0 {
        result = Fx::one(0);
    }
    result
}

/// `exp` for the `ib == 0` case (`a ∈ (-1, 0]`).
fn exp_ib0(a: Fx) -> Fx {
    debug_assert_eq!(a.ib, 0);
    let frac_bits = 31;
    let one_quarter: i32 = 1 << (frac_bits - 2);
    let mask = one_quarter - 1;
    let a_mod = (a.raw & mask) - one_quarter;
    let mut result =
        exp_on_interval_between_negative_one_quarter_and_0_excl(Fx::from_raw(a_mod, 0));
    let remainder = a_mod.wrapping_sub(a.raw);
    // Only the k = -2 and k = -1 barrel steps can fire for |a| < 1.
    for &(exponent, multiplier) in &EXP_BARREL[..2] {
        let shift = frac_bits + exponent;
        if remainder & (1 << shift) != 0 {
            result = result.mul(Fx::from_raw(multiplier, 0));
        }
    }
    if a.raw == 0 {
        result = Fx::one(0);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_exp(ib: u32, tolerance: f64) {
        let steps = 4001;
        let min = -(2f64.powi(ib as i32));
        for i in 0..steps {
            let v = min * f64::from(i) / f64::from(steps - 1);
            let a = Fx::from_f64(v, ib);
            if a.raw > 0 {
                continue;
            }
            let got = exp_on_negative_values(a).to_f64();
            let want = a.to_f64().exp();
            assert!(
                (got - want).abs() < tolerance,
                "ib={ib} x={v:.6} got={got:.9} want={want:.9}"
            );
        }
    }

    #[test]
    fn exp_accuracy_q0() {
        check_exp(0, 3e-7);
    }

    #[test]
    fn exp_accuracy_q3() {
        check_exp(3, 3e-7);
    }

    #[test]
    fn exp_accuracy_q4() {
        check_exp(4, 5e-7);
    }

    #[test]
    fn exp_accuracy_q5() {
        check_exp(5, 1e-6);
    }

    #[test]
    fn exp_of_zero_is_one() {
        for ib in 0..=6 {
            let r = exp_on_negative_values(Fx::zero(ib));
            assert!((r.to_f64() - 1.0).abs() < 1e-9, "ib={ib}");
        }
    }

    #[test]
    fn exp_clamps_below_minus_32() {
        let a = Fx::from_f64(-40.0, 6);
        assert_eq!(exp_on_negative_values(a).raw, 0);
    }

    #[test]
    fn exp_monotone_nonincreasing_in_magnitude() {
        let ib = 4;
        let mut prev = f64::INFINITY;
        for i in 0..1000 {
            let v = -16.0 * f64::from(i) / 999.0;
            let got = exp_on_negative_values(Fx::from_f64(v, ib)).to_f64();
            assert!(got <= prev + 2e-9, "x={v} got={got} prev={prev}");
            prev = got;
        }
    }
}
