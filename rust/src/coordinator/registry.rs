//! The model registry: several quantized LSTM variants served over one
//! worker pool.
//!
//! The paper's economics argument — 8-bit integer LSTMs are cheap
//! enough to deploy widely — plays out in production as *many* model
//! variants resident on one CPU fleet: per-locale heads, A/B
//! quantization recipes, fully-integer vs. hybrid engines. Packed int8
//! weights are the dominant resident cost, so which workers hold which
//! model's weights is a first-class placement decision.
//!
//! A [`ModelRegistry`] holds N registered variants ([`ModelSpec`]:
//! float master weights, calibration stats, quantization recipe, and
//! engine kind). Each variant gets a dense [`ModelId`] and a
//! [`Residency`] policy mapping it onto a subset of the pool's
//! workers. The rest of the coordinator keys on `(model, session)`:
//!
//! * the [`router`] homes sessions onto workers where the model is
//!   resident and only lets a thief steal sessions whose model it
//!   hosts;
//! * the [`scheduler`] runs one [`LmBatchState`] wave **per resident
//!   model per worker** — lanes never mix models;
//! * the session/budget machinery accounts state per model, and the
//!   [`ServingReport`] breaks out per-model occupancy, steals,
//!   evictions, and resident weight bytes.
//!
//! Engines are **instantiated per worker** (their step scratch is not
//! shareable across threads); the registry is the shared, immutable
//! description the workers instantiate from.
//!
//! [`router`]: super::router
//! [`scheduler`]: super::scheduler
//! [`LmBatchState`]: crate::model::lm::LmBatchState
//! [`ServingReport`]: super::metrics::ServingReport

use crate::lstm::{CalibrationStats, QuantizeOptions, StackEngine, WeightBits};
use crate::model::lm::{CharLm, CharLmEngine};

/// Identifier of a registered model: the dense index assigned by
/// [`ModelRegistry::register`], in registration order.
pub type ModelId = u32;

/// Which workers hold a model's weights (and therefore its sessions).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Residency {
    /// Resident on every worker of the pool (the default; best
    /// occupancy, highest memory).
    All,
    /// Resident on `n` workers, placed round-robin from the model id
    /// (`(model + i) % workers` for `i < n`) — deterministic and
    /// spreads models across the pool.
    Count(usize),
    /// Resident on an explicit worker set (indices outside the pool are
    /// ignored; the effective set must stay non-empty).
    Workers(Vec<usize>),
}

/// Everything needed to build one model variant's engine.
pub struct ModelSpec<'a> {
    /// Operator-facing name ("en-US", "recipe-B", ...).
    pub name: String,
    /// Float master weights (stack + head).
    pub lm: &'a CharLm,
    /// Execution engine kind for this variant.
    pub engine: StackEngine,
    /// Calibration statistics (required for the integer engine).
    pub stats: Option<&'a [CalibrationStats]>,
    /// Quantization recipe options for this variant.
    pub opts: QuantizeOptions,
    /// Which workers hold this model.
    pub residency: Residency,
}

struct Registered<'a> {
    spec: ModelSpec<'a>,
    weight_bytes: usize,
    state_bytes: usize,
}

/// The registry: an ordered set of model variants sharded over one
/// worker pool. Immutable once serving starts; shared by reference
/// across worker threads (it holds no engine instances, only the
/// specs to build them from).
#[derive(Default)]
pub struct ModelRegistry<'a> {
    models: Vec<Registered<'a>>,
}

impl<'a> ModelRegistry<'a> {
    /// An empty registry.
    pub fn new() -> Self {
        ModelRegistry { models: Vec::new() }
    }

    /// Register one model variant and return its [`ModelId`]. Builds a
    /// probe engine once, at load time, to validate the spec (the
    /// integer engine requires calibration stats) and to record the
    /// packed weight and per-stream state footprints for the memory
    /// accounting. The probe is a deliberate trade-off: exact byte
    /// accounting needs the built engine (block-sparse sizes under
    /// `sparse_weights` depend on which weight tiles pruning zeroed,
    /// not just the spec — a 90%-pruned model registers a fraction of
    /// its dense footprint), and registration happens once per variant
    /// at load time, never on the serving path.
    pub fn register(&mut self, spec: ModelSpec<'a>) -> ModelId {
        if spec.engine == StackEngine::Integer {
            assert!(spec.stats.is_some(), "integer engine needs calibration stats");
        }
        if let Residency::Workers(ws) = &spec.residency {
            assert!(!ws.is_empty(), "explicit residency must name a worker");
        }
        if let Residency::Count(n) = spec.residency {
            assert!(n > 0, "residency count must be at least 1");
        }
        let probe = spec.lm.engine(spec.engine, spec.stats, spec.opts);
        let id = self.models.len() as ModelId;
        self.models.push(Registered {
            weight_bytes: probe.weight_bytes(),
            state_bytes: probe.state_bytes(),
            spec,
        });
        id
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// True when no model is registered.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Operator-facing name of a model.
    pub fn name(&self, model: ModelId) -> &str {
        &self.models[model as usize].spec.name
    }

    /// All model names in [`ModelId`] order — the label set the live
    /// metrics snapshot ([`super::net`]) is keyed by.
    pub fn names(&self) -> Vec<String> {
        self.models.iter().map(|r| r.spec.name.clone()).collect()
    }

    /// Engine kind of a model.
    pub fn engine_kind(&self, model: ModelId) -> StackEngine {
        self.models[model as usize].spec.engine
    }

    /// Packed weight bytes of one replica of a model (stack + head
    /// under its engine).
    pub fn weight_bytes(&self, model: ModelId) -> usize {
        self.models[model as usize].weight_bytes
    }

    /// Bytes of one stream's persistent state under this model's
    /// engine (recurrent layers + hidden/logits scratch).
    pub fn state_bytes(&self, model: ModelId) -> usize {
        self.models[model as usize].state_bytes
    }

    /// The sorted worker set a model is resident on, for a pool of
    /// `workers` workers.
    pub fn resident_workers(&self, model: ModelId, workers: usize) -> Vec<usize> {
        assert!(workers > 0);
        match &self.models[model as usize].spec.residency {
            Residency::All => (0..workers).collect(),
            Residency::Count(n) => {
                let n = (*n).min(workers);
                let mut ws: Vec<usize> =
                    (0..n).map(|i| (model as usize + i) % workers).collect();
                ws.sort_unstable();
                ws
            }
            Residency::Workers(ws) => {
                let mut ws: Vec<usize> =
                    ws.iter().copied().filter(|&w| w < workers).collect();
                ws.sort_unstable();
                ws.dedup();
                assert!(
                    !ws.is_empty(),
                    "model {model} has no resident worker in a pool of {workers}"
                );
                ws
            }
        }
    }

    /// Per-model resident worker sets for a pool of `workers` workers
    /// (the shape [`ShardRouter::with_residency`] consumes).
    ///
    /// [`ShardRouter::with_residency`]:
    ///     super::router::ShardRouter::with_residency
    pub fn residency(&self, workers: usize) -> Vec<Vec<usize>> {
        (0..self.models.len())
            .map(|m| self.resident_workers(m as ModelId, workers))
            .collect()
    }

    /// Whether `model` is resident on `worker` in a pool of `workers`.
    pub fn resident_on(&self, model: ModelId, worker: usize, workers: usize) -> bool {
        self.resident_workers(model, workers).contains(&worker)
    }

    /// Build engine instances for the models resident on `worker`
    /// (index = [`ModelId`]; `None` for models not resident there).
    /// Each worker thread calls this once — engines carry per-step
    /// scratch and are not shareable across threads.
    pub fn instantiate(&self, worker: usize, workers: usize) -> Vec<Option<CharLmEngine>> {
        self.models
            .iter()
            .enumerate()
            .map(|(m, r)| {
                if self.resident_on(m as ModelId, worker, workers) {
                    Some(r.spec.lm.engine(r.spec.engine, r.spec.stats, r.spec.opts))
                } else {
                    None
                }
            })
            .collect()
    }

    /// Build one engine instance per model, regardless of residency —
    /// the form the single-threaded simulators and sequential oracles
    /// use (one instance can serve every simulated worker).
    pub fn instantiate_all(&self) -> Vec<CharLmEngine> {
        self.models
            .iter()
            .map(|r| r.spec.lm.engine(r.spec.engine, r.spec.stats, r.spec.opts))
            .collect()
    }

    /// The largest per-stream state footprint across registered models
    /// — the number to size the `--session-budget` byte budget with: a
    /// worker's lane-holding and pending sessions never hibernate, so
    /// the budget must cover at least
    /// `max_lanes * max_state_bytes()` for the resident-state bound to
    /// be enforceable on every worker.
    ///
    /// Panics on an empty registry: a zero budget floor would silently
    /// disable the resident-state bound, so an unregistered pool is a
    /// configuration bug, not a zero.
    pub fn max_state_bytes(&self) -> usize {
        assert!(
            !self.models.is_empty(),
            "max_state_bytes on an empty registry: register models before \
             sizing the session budget"
        );
        self.models.iter().map(|r| r.state_bytes).max().expect("non-empty")
    }

    /// Total packed weight bytes resident across the pool: each
    /// model's replica size times its resident worker count — the
    /// number the "weights are the dominant resident cost" trade-off
    /// is made against.
    pub fn total_resident_weight_bytes(&self, workers: usize) -> usize {
        (0..self.models.len())
            .map(|m| {
                self.weight_bytes(m as ModelId)
                    * self.resident_workers(m as ModelId, workers).len()
            })
            .sum()
    }

    /// Weight bit-width of a model's quantization recipe.
    pub fn weight_bits(&self, model: ModelId) -> WeightBits {
        self.models[model as usize].spec.opts.weight_bits
    }

    /// Whether [`Self::demote_to_int4`] can re-pack this model: the
    /// engine must actually quantize weights (hybrid or integer —
    /// weight bits are a no-op for the float engine), the model must
    /// not be block-sparse (the BSR kernel is int8-only), and it must
    /// still be at int8.
    pub fn can_demote_to_int4(&self, model: ModelId) -> bool {
        let spec = &self.models[model as usize].spec;
        spec.engine != StackEngine::Float
            && !spec.opts.sparse_weights
            && spec.opts.weight_bits == WeightBits::Int8
    }

    /// Re-pack one registered model's weights to int4 nibble panels —
    /// the byte-pressure relief valve that runs *before* eviction:
    /// halving a cold model's resident weights keeps it servable
    /// everywhere it was resident, where eviction would force a
    /// cold-start re-quantization on the next request.
    ///
    /// Re-probes the engine under the demoted recipe and refreshes the
    /// byte accounting. Pre-serving only: the registry is shared
    /// immutably across worker threads once serving starts, so demotion
    /// happens at load/planning time (`&mut self` enforces this).
    ///
    /// Panics when the model is not demotable ([`Self::can_demote_to_int4`])
    /// — silently leaving a float or sparse model at full size would
    /// defeat the budget arithmetic the caller is doing.
    pub fn demote_to_int4(&mut self, model: ModelId) {
        assert!(
            self.can_demote_to_int4(model),
            "model {model} ({}) is not demotable to int4: engine={:?} sparse={} bits={}",
            self.name(model),
            self.engine_kind(model),
            self.models[model as usize].spec.opts.sparse_weights,
            self.weight_bits(model).label(),
        );
        let r = &mut self.models[model as usize];
        r.spec.opts.weight_bits = WeightBits::Int4;
        let probe = r.spec.lm.engine(r.spec.engine, r.spec.stats, r.spec.opts);
        r.weight_bytes = probe.weight_bytes();
        r.state_bytes = probe.state_bytes();
    }

    /// Demote cold models to int4 until the pool-wide resident weight
    /// bytes fit `budget_bytes`, coldest first: fewest resident workers
    /// is the coldness proxy (a model pinned to one worker is the tail
    /// of the popularity curve), ties broken by largest resident
    /// footprint (biggest relief per demotion), then by id for
    /// determinism. Returns the demoted ids in demotion order; stops
    /// early once the budget fits or no demotable model remains — the
    /// caller decides whether a still-over-budget registry escalates to
    /// eviction.
    pub fn enforce_weight_budget(
        &mut self,
        budget_bytes: usize,
        workers: usize,
    ) -> Vec<ModelId> {
        let mut demoted = Vec::new();
        while self.total_resident_weight_bytes(workers) > budget_bytes {
            let mut candidates: Vec<(usize, usize, ModelId)> = (0..self.models.len())
                .filter(|&m| self.can_demote_to_int4(m as ModelId))
                .map(|m| {
                    let replicas = self.resident_workers(m as ModelId, workers).len();
                    (replicas, self.weight_bytes(m as ModelId) * replicas, m as ModelId)
                })
                .collect();
            candidates
                .sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)).then(a.2.cmp(&b.2)));
            let Some(&(_, _, pick)) = candidates.first() else { break };
            self.demote_to_int4(pick);
            demoted.push(pick);
        }
        demoted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, StackWeights};
    use crate::model::lm::VOCAB;
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm(seed: u64, hidden: usize) -> CharLm {
        let mut rng = Pcg32::seeded(seed);
        let spec = LstmSpec::plain(VOCAB, hidden);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, hidden);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden, depth: 1 }
    }

    #[test]
    fn register_assigns_dense_ids_and_accounts_weights() {
        let a = tiny_lm(1, 16);
        let b = tiny_lm(2, 24);
        let mut reg = ModelRegistry::new();
        let ida = reg.register(ModelSpec {
            name: "a".into(),
            lm: &a,
            engine: StackEngine::Float,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        let idb = reg.register(ModelSpec {
            name: "b".into(),
            lm: &b,
            engine: StackEngine::Hybrid,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::Count(1),
        });
        assert_eq!((ida, idb), (0, 1));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.name(1), "b");
        assert_eq!(reg.engine_kind(0), StackEngine::Float);
        assert!(reg.weight_bytes(0) > 0);
        assert!(reg.state_bytes(0) > 0);
        assert_eq!(
            reg.max_state_bytes(),
            reg.state_bytes(0).max(reg.state_bytes(1))
        );
        // Hybrid packs int8 weights: smaller than the float replica of
        // a wider model.
        assert!(reg.weight_bytes(1) < reg.weight_bytes(0) * 4);
        // Resident bytes: model 0 on all 4 workers, model 1 on one.
        assert_eq!(
            reg.total_resident_weight_bytes(4),
            reg.weight_bytes(0) * 4 + reg.weight_bytes(1)
        );
    }

    #[test]
    fn residency_policies_place_deterministically() {
        let a = tiny_lm(3, 16);
        let mut reg = ModelRegistry::new();
        for (i, res) in [
            Residency::All,
            Residency::Count(2),
            Residency::Workers(vec![3, 1, 1, 9]),
        ]
        .into_iter()
        .enumerate()
        {
            let id = reg.register(ModelSpec {
                name: format!("m{i}"),
                lm: &a,
                engine: StackEngine::Float,
                stats: None,
                opts: QuantizeOptions::default(),
                residency: res,
            });
            assert_eq!(id as usize, i);
        }
        assert_eq!(reg.resident_workers(0, 4), vec![0, 1, 2, 3]);
        // Count(2) for model 1: workers (1, 2).
        assert_eq!(reg.resident_workers(1, 4), vec![1, 2]);
        // Explicit set: out-of-range 9 dropped, duplicates deduped.
        assert_eq!(reg.resident_workers(2, 4), vec![1, 3]);
        assert!(reg.resident_on(1, 2, 4));
        assert!(!reg.resident_on(1, 0, 4));
        // Count never exceeds the pool.
        assert_eq!(reg.resident_workers(1, 1), vec![0]);
    }

    #[test]
    fn instantiate_respects_residency() {
        let a = tiny_lm(4, 16);
        let mut reg = ModelRegistry::new();
        reg.register(ModelSpec {
            name: "everywhere".into(),
            lm: &a,
            engine: StackEngine::Float,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        reg.register(ModelSpec {
            name: "pinned".into(),
            lm: &a,
            engine: StackEngine::Float,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::Workers(vec![1]),
        });
        let w0 = reg.instantiate(0, 2);
        let w1 = reg.instantiate(1, 2);
        assert!(w0[0].is_some() && w0[1].is_none());
        assert!(w1[0].is_some() && w1[1].is_some());
        assert_eq!(reg.instantiate_all().len(), 2);
    }

    #[test]
    #[should_panic(expected = "integer engine needs calibration stats")]
    fn integer_without_stats_panics() {
        let a = tiny_lm(5, 16);
        let mut reg = ModelRegistry::new();
        reg.register(ModelSpec {
            name: "bad".into(),
            lm: &a,
            engine: StackEngine::Integer,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
    }

    fn calib(lm: &CharLm, seed: u64) -> Vec<crate::lstm::CalibrationStats> {
        let mut rng = Pcg32::seeded(seed);
        let seqs: Vec<Vec<usize>> = (0..4)
            .map(|_| (0..24).map(|_| rng.below(VOCAB as u32) as usize).collect())
            .collect();
        lm.calibrate(&seqs)
    }

    #[test]
    fn demotion_halves_integer_model_bytes() {
        let a = tiny_lm(6, 32);
        let stats = calib(&a, 7);
        let mut reg = ModelRegistry::new();
        let id = reg.register(ModelSpec {
            name: "demotable".into(),
            lm: &a,
            engine: StackEngine::Integer,
            stats: Some(&stats),
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        let before = reg.weight_bytes(id);
        assert!(reg.can_demote_to_int4(id));
        assert_eq!(reg.weight_bits(id), WeightBits::Int8);
        reg.demote_to_int4(id);
        assert_eq!(reg.weight_bits(id), WeightBits::Int4);
        let after = reg.weight_bytes(id);
        // Acceptance bar: int4 residency at most 55% of the int8 packing.
        assert!(
            after as f64 <= before as f64 * 0.55,
            "int4 {after}B vs int8 {before}B"
        );
        // Demotion changes weights, not per-stream state.
        assert!(reg.state_bytes(id) > 0);
        // Once at int4 a second demotion is a caller bug.
        assert!(!reg.can_demote_to_int4(id));
    }

    #[test]
    #[should_panic(expected = "not demotable to int4")]
    fn demoting_float_model_panics() {
        let a = tiny_lm(8, 16);
        let mut reg = ModelRegistry::new();
        let id = reg.register(ModelSpec {
            name: "float".into(),
            lm: &a,
            engine: StackEngine::Float,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        reg.demote_to_int4(id);
    }

    #[test]
    #[should_panic(expected = "empty registry")]
    fn max_state_bytes_on_empty_registry_panics() {
        ModelRegistry::new().max_state_bytes();
    }

    #[test]
    fn weight_budget_demotes_coldest_first_and_stops_when_fit() {
        let a = tiny_lm(9, 32);
        let stats = calib(&a, 10);
        let mut reg = ModelRegistry::new();
        // Hot: resident everywhere. Cold: pinned to one worker.
        let hot = reg.register(ModelSpec {
            name: "hot".into(),
            lm: &a,
            engine: StackEngine::Integer,
            stats: Some(&stats),
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        let cold = reg.register(ModelSpec {
            name: "cold".into(),
            lm: &a,
            engine: StackEngine::Integer,
            stats: Some(&stats),
            opts: QuantizeOptions::default(),
            residency: Residency::Count(1),
        });
        let workers = 4;
        let total = reg.total_resident_weight_bytes(workers);
        // A budget just below the current total: demoting the cold
        // model alone must satisfy it, and the hot model must be left
        // untouched.
        let budget = total - reg.weight_bytes(cold) / 4;
        let demoted = reg.enforce_weight_budget(budget, workers);
        assert_eq!(demoted, vec![cold]);
        assert_eq!(reg.weight_bits(cold), WeightBits::Int4);
        assert_eq!(reg.weight_bits(hot), WeightBits::Int8);
        assert!(reg.total_resident_weight_bytes(workers) <= budget);
        // An impossible budget demotes everything demotable, then
        // stops rather than looping.
        let demoted = reg.enforce_weight_budget(0, workers);
        assert_eq!(demoted, vec![hot]);
        assert!(reg.total_resident_weight_bytes(workers) > 0);
    }
}
