//! Continuous batching: the lane scheduler that keeps the batched
//! int8 path saturated under streaming arrivals.
//!
//! PR 1's coordinator packed *waves*: every lane of a batch started and
//! (modulo prefix truncation) ended together, so occupancy collapsed
//! whenever sessions arrived mid-wave or finished at different lengths.
//! This scheduler runs one *persistent* wave whose lanes turn over
//! independently:
//!
//! * between token positions, pending sessions are admitted into free
//!   lanes ([`ContinuousScheduler::admit_ready`] →
//!   [`CharLmEngine::admit_lane`]);
//! * every [`ContinuousScheduler::step`] advances all live lanes one
//!   token position with a single batched step;
//! * lanes whose items are exhausted are scattered back to their
//!   sessions and compacted out
//!   ([`CharLmEngine::compact_lanes`]), so live lanes stay a dense
//!   prefix and the GEMM never touches dead rows.
//!
//! Scheduling invariants (locked down by
//! `rust/tests/continuous_batching.rs`):
//!
//! 1. at most one lane per session at any time (a stream's state must
//!    advance in arrival order);
//! 2. the batch width always equals the live lane count;
//! 3. every session's output is bit-exact with running it alone on the
//!    sequential `step` path — admission order, lane moves, and
//!    compaction never touch the numerics.
//!
//! The scheduler is deliberately free of threads and wall-clock
//! decisions: the serving worker drives it from a [`Batcher`], and
//! [`simulate_trace`] drives it from a virtual clock so tests and
//! benches get deterministic, replayable schedules.
//!
//! [`Batcher`]: super::batcher::Batcher
//! [`CharLmEngine::admit_lane`]: crate::model::lm::CharLmEngine::admit_lane
//! [`CharLmEngine::compact_lanes`]: crate::model::lm::CharLmEngine::compact_lanes

use std::collections::VecDeque;
use std::time::Instant;

use crate::model::lm::{nll_bits, CharLmEngine, LmBatchState};
use crate::workload::synth::RequestTrace;
use super::session::{SessionId, SessionManager};

/// Which scheduling discipline the coordinator runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// PR 1 baseline: admit only into an empty batch — every wave is
    /// packed once and runs to completion.
    Wave,
    /// Admit into free lanes between token positions.
    Continuous,
}

impl SchedulerMode {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerMode::Wave => "wave",
            SchedulerMode::Continuous => "continuous",
        }
    }
}

/// One unit of work: a request's token chunk for a session.
pub struct StreamItem {
    pub session: SessionId,
    pub tokens: Vec<usize>,
    /// When the request entered the system (end-to-end latency base).
    pub submitted: Instant,
}

/// Completion record for one finished item.
#[derive(Debug, Clone)]
pub struct StreamDone {
    pub session: SessionId,
    pub tokens: usize,
    /// Total next-char negative log2-likelihood over the item.
    pub nll_bits: f64,
    pub latency_ms: f64,
}

/// One live lane of the persistent wave.
struct Lane {
    session: SessionId,
    tokens: Vec<usize>,
    /// Next token position to feed.
    pos: usize,
    /// Accumulated nll over this item (token order, f64).
    nll: f64,
    submitted: Instant,
}

/// Counters the scheduler keeps about its own behaviour.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    /// Batched step invocations (one per token position of the wave).
    pub batched_steps: usize,
    /// Lane-steps executed (= tokens through the batched path).
    pub lane_steps: usize,
    /// Widest live batch observed.
    pub peak_lanes: usize,
    /// Lane turnover: admissions into the wave.
    pub admissions: usize,
    /// Lane turnover: retirements out of the wave.
    pub retirements: usize,
    /// Total time items waited between submission and admission.
    pub admission_wait_ms: f64,
}

impl SchedulerStats {
    /// Mean lanes per batched step — the occupancy this whole refactor
    /// exists to lift.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Mean wait between submission and lane admission.
    pub fn mean_admission_ms(&self) -> f64 {
        if self.admissions == 0 {
            0.0
        } else {
            self.admission_wait_ms / self.admissions as f64
        }
    }
}

/// The continuous-batching lane scheduler for one worker.
pub struct ContinuousScheduler<'a> {
    engine: &'a CharLmEngine,
    sessions: SessionManager,
    bs: LmBatchState,
    lanes: Vec<Lane>,
    pending: VecDeque<StreamItem>,
    done: Vec<StreamDone>,
    toks: Vec<usize>,
    max_lanes: usize,
    mode: SchedulerMode,
    stats: SchedulerStats,
}

impl<'a> ContinuousScheduler<'a> {
    /// Continuous-mode scheduler with at most `max_lanes` live lanes.
    pub fn new(engine: &'a CharLmEngine, max_lanes: usize) -> Self {
        Self::with_mode(engine, max_lanes, SchedulerMode::Continuous)
    }

    pub fn with_mode(
        engine: &'a CharLmEngine,
        max_lanes: usize,
        mode: SchedulerMode,
    ) -> Self {
        assert!(max_lanes >= 1, "need at least one lane");
        ContinuousScheduler {
            engine,
            sessions: SessionManager::new(),
            bs: engine.new_batch_state(0),
            lanes: Vec::new(),
            pending: VecDeque::new(),
            done: Vec::new(),
            toks: Vec::new(),
            max_lanes,
            mode,
            stats: SchedulerStats::default(),
        }
    }

    /// Enqueue an item for admission (FIFO per session).
    pub fn offer(&mut self, item: StreamItem) {
        self.pending.push_back(item);
    }

    /// Move pending items into free lanes: at most `max_lanes` live
    /// lanes, at most one lane per session, earliest pending item per
    /// session first. In wave mode admission only happens into an empty
    /// batch. Returns how many lanes were admitted.
    pub fn admit_ready(&mut self) -> usize {
        if self.mode == SchedulerMode::Wave && !self.lanes.is_empty() {
            return 0;
        }
        let engine = self.engine;
        let mut admitted = 0;
        let mut i = 0;
        while self.lanes.len() < self.max_lanes && i < self.pending.len() {
            let sess = self.pending[i].session;
            if self.lanes.iter().any(|l| l.session == sess) {
                // A lane for this session is live; its next chunk must
                // wait so the stream's state advances in order.
                i += 1;
                continue;
            }
            let item = self.pending.remove(i).expect("index in bounds");
            if item.tokens.is_empty() {
                // Nothing to execute: complete immediately.
                self.done.push(StreamDone {
                    session: item.session,
                    tokens: 0,
                    nll_bits: 0.0,
                    latency_ms: item.submitted.elapsed().as_secs_f64() * 1e3,
                });
                continue;
            }
            self.stats.admissions += 1;
            self.stats.admission_wait_ms +=
                item.submitted.elapsed().as_secs_f64() * 1e3;
            let lane = {
                let state = &self.sessions.get_or_create(item.session, engine).state;
                engine.admit_lane(state, &mut self.bs)
            };
            debug_assert_eq!(lane, self.lanes.len());
            self.lanes.push(Lane {
                session: item.session,
                tokens: item.tokens,
                pos: 0,
                nll: 0.0,
                submitted: item.submitted,
            });
            admitted += 1;
        }
        self.stats.peak_lanes = self.stats.peak_lanes.max(self.lanes.len());
        admitted
    }

    /// Advance every live lane one token position with a single batched
    /// step, then scatter finished lanes back to their sessions and
    /// compact them out. No-op when no lane is live.
    pub fn step(&mut self) {
        if self.lanes.is_empty() {
            return;
        }
        debug_assert_eq!(self.bs.batch(), self.lanes.len());
        let engine = self.engine;
        self.toks.clear();
        self.toks.extend(self.lanes.iter().map(|l| l.tokens[l.pos]));
        engine.step_tokens(&self.toks, &mut self.bs);
        self.stats.batched_steps += 1;
        self.stats.lane_steps += self.lanes.len();
        for (lane, l) in self.lanes.iter_mut().enumerate() {
            if let Some(&next) = l.tokens.get(l.pos + 1) {
                l.nll += nll_bits(self.bs.logits.row(lane), next);
            }
            l.pos += 1;
        }
        if self.lanes.iter().any(|l| l.pos >= l.tokens.len()) {
            let mut keep = Vec::with_capacity(self.lanes.len());
            for (lane, l) in self.lanes.iter().enumerate() {
                let finished = l.pos >= l.tokens.len();
                keep.push(!finished);
                if finished {
                    let session = self.sessions.get_or_create(l.session, engine);
                    engine.scatter_session(&self.bs, &mut session.state, lane);
                    session.tokens_seen += l.tokens.len();
                    session.nll_bits += l.nll;
                    self.stats.retirements += 1;
                    self.done.push(StreamDone {
                        session: l.session,
                        tokens: l.tokens.len(),
                        nll_bits: l.nll,
                        latency_ms: l.submitted.elapsed().as_secs_f64() * 1e3,
                    });
                }
            }
            engine.compact_lanes(&mut self.bs, &keep);
            let mut it = keep.into_iter();
            self.lanes.retain(|_| it.next().unwrap());
        }
    }

    /// Drain the completion buffer.
    pub fn take_completed(&mut self) -> Vec<StreamDone> {
        std::mem::take(&mut self.done)
    }

    /// True while anything is live or waiting (including buffered
    /// completions not yet drained).
    pub fn has_live_work(&self) -> bool {
        !self.lanes.is_empty() || !self.pending.is_empty() || !self.done.is_empty()
    }

    pub fn live_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Current width of the underlying batch state (must always equal
    /// [`Self::live_lanes`] — an invariant the test suite checks).
    pub fn batch_width(&self) -> usize {
        self.bs.batch()
    }

    /// Session ids of the live lanes, in lane order.
    pub fn lane_sessions(&self) -> Vec<SessionId> {
        self.lanes.iter().map(|l| l.session).collect()
    }

    pub fn mode(&self) -> SchedulerMode {
        self.mode
    }

    pub fn stats(&self) -> SchedulerStats {
        self.stats
    }

    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }
}

/// Deterministic virtual-time replay of a [`RequestTrace`]: one batched
/// step consumes `tick_ms` of virtual time, requests are offered when
/// their arrival time is due, and idle gaps jump straight to the next
/// arrival. No threads, no wall clock — the same trace, mode, and tick
/// always produce the same schedule, so occupancy comparisons and
/// bit-exactness assertions are replayable.
///
/// Returns the scheduler (for stats and final session states) and all
/// completions in completion order.
pub fn simulate_trace<'a>(
    engine: &'a CharLmEngine,
    trace: &RequestTrace,
    max_lanes: usize,
    mode: SchedulerMode,
    tick_ms: f64,
) -> (ContinuousScheduler<'a>, Vec<StreamDone>) {
    assert!(tick_ms > 0.0);
    let mut sched = ContinuousScheduler::with_mode(engine, max_lanes, mode);
    let mut completed = Vec::new();
    let mut next = 0usize;
    let mut now_ms = 0f64;
    while next < trace.requests.len() || sched.has_live_work() {
        while next < trace.requests.len() && trace.requests[next].arrival_ms <= now_ms {
            let r = &trace.requests[next];
            sched.offer(StreamItem {
                session: r.id,
                tokens: r.tokens.clone(),
                submitted: Instant::now(),
            });
            next += 1;
        }
        sched.admit_ready();
        if sched.live_lanes() == 0 {
            completed.append(&mut sched.take_completed());
            if next < trace.requests.len() {
                // Idle: jump to the next arrival.
                now_ms = now_ms.max(trace.requests[next].arrival_ms);
                continue;
            }
            break;
        }
        sched.step();
        completed.append(&mut sched.take_completed());
        now_ms += tick_ms;
    }
    (sched, completed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, QuantizeOptions, StackEngine, StackWeights};
    use crate::model::lm::{CharLm, VOCAB};
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(41);
        let spec = LstmSpec::plain(VOCAB, 16);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 16);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 16, depth: 1 }
    }

    fn item(session: SessionId, tokens: Vec<usize>) -> StreamItem {
        StreamItem { session, tokens, submitted: Instant::now() }
    }

    #[test]
    fn continuous_admits_mid_flight_wave_does_not() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        for (mode, expect_mid_wave) in
            [(SchedulerMode::Continuous, true), (SchedulerMode::Wave, false)]
        {
            let mut sched = ContinuousScheduler::with_mode(&engine, 4, mode);
            sched.offer(item(1, vec![3; 6]));
            assert_eq!(sched.admit_ready(), 1);
            sched.step();
            // A second session arrives while lane 0 is mid-flight.
            sched.offer(item(2, vec![5; 4]));
            let admitted = sched.admit_ready();
            assert_eq!(admitted == 1, expect_mid_wave, "{mode:?}");
            while sched.has_live_work() {
                sched.admit_ready();
                sched.step();
                sched.take_completed();
            }
            assert_eq!(sched.stats().retirements, 2, "{mode:?}");
            assert_eq!(sched.stats().lane_steps, 10, "{mode:?}");
        }
    }

    #[test]
    fn same_session_chunks_never_coexist() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 4);
        sched.offer(item(9, vec![1; 5]));
        sched.offer(item(9, vec![2; 5]));
        sched.offer(item(7, vec![3; 3]));
        while sched.has_live_work() {
            sched.admit_ready();
            let ids = sched.lane_sessions();
            let unique: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(unique.len(), ids.len(), "session double-occupied: {ids:?}");
            assert_eq!(sched.batch_width(), ids.len());
            sched.step();
            sched.take_completed();
        }
        let s = sched.sessions().get(9).unwrap();
        assert_eq!(s.tokens_seen, 10);
    }

    #[test]
    fn empty_item_completes_immediately() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 2);
        sched.offer(item(5, Vec::new()));
        sched.admit_ready();
        let done = sched.take_completed();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, 0);
        assert_eq!(sched.live_lanes(), 0);
        assert!(!sched.has_live_work());
    }

    #[test]
    fn simulate_trace_completes_everything_deterministically() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let trace = RequestTrace::generate(12, 800.0, 10, VOCAB, 3);
        let (s1, d1) = simulate_trace(&engine, &trace, 4, SchedulerMode::Continuous, 1.0);
        let (s2, d2) = simulate_trace(&engine, &trace, 4, SchedulerMode::Continuous, 1.0);
        assert_eq!(d1.len(), 12);
        assert_eq!(d2.len(), 12);
        assert_eq!(s1.stats().batched_steps, s2.stats().batched_steps);
        assert_eq!(s1.stats().lane_steps, s2.stats().lane_steps);
        for (a, b) in d1.iter().zip(&d2) {
            assert_eq!(a.session, b.session);
            assert_eq!(a.nll_bits.to_bits(), b.nll_bits.to_bits());
        }
    }
}
