//! Continuous batching: the lane scheduler that keeps the batched
//! int8 path saturated under streaming arrivals — now with one
//! persistent wave **per resident model**.
//!
//! PR 1's coordinator packed *waves*: every lane of a batch started and
//! (modulo prefix truncation) ended together, so occupancy collapsed
//! whenever sessions arrived mid-wave or finished at different lengths.
//! This scheduler runs persistent waves whose lanes turn over
//! independently:
//!
//! * between token positions, pending sessions are admitted into free
//!   lanes ([`ContinuousScheduler::admit_ready`] →
//!   [`CharLmEngine::admit_lane`]);
//! * every [`ContinuousScheduler::step`] advances all live lanes one
//!   token position with one batched step per model wave;
//! * lanes whose items are exhausted are scattered back to their
//!   sessions and compacted out
//!   ([`CharLmEngine::compact_lanes`]), so live lanes stay a dense
//!   prefix and the GEMM never touches dead rows.
//!
//! With the model registry, a worker hosts one [`LmBatchState`] wave
//! per resident model: **lanes never mix models** (a wave's GEMMs run
//! one model's packed weights), the `max_lanes` budget is shared
//! across waves, and when free lanes are scarce admission splits them
//! across models **weighted by per-model backlog** (proportional
//! largest-remainder shares, deterministic, FIFO within each model).
//! With one resident model all of this degenerates to exactly the
//! single-wave scheduler of PRs 2–4.
//!
//! Scheduling invariants (locked down by
//! `rust/tests/continuous_batching.rs`, `rust/tests/sharded_serving.rs`
//! and `rust/tests/multi_model.rs`):
//!
//! 1. at most one lane per `(model, session)` stream at any time (a
//!    stream's state must advance in arrival order);
//! 2. each wave's batch width always equals its live lane count, and a
//!    wave only ever holds lanes of its own model;
//! 3. every stream's output is bit-exact with running it alone on the
//!    sequential `step` path of its model — admission order, lane
//!    moves, cross-model interleaving, and compaction never touch the
//!    numerics.
//!
//! The scheduler is deliberately free of threads and wall-clock
//! decisions: the serving worker drives it from a [`ShardRouter`],
//! [`simulate_trace`] drives one instance from a virtual clock, and
//! [`simulate_shard_trace`] / [`simulate_multi_shard_trace`] drive a
//! whole worker pool (with work stealing) the same way — so tests and
//! benches get deterministic, replayable schedules.
//!
//! [`ShardRouter`]: super::router::ShardRouter
//! [`LmBatchState`]: crate::model::lm::LmBatchState
//! [`CharLmEngine::admit_lane`]: crate::model::lm::CharLmEngine::admit_lane
//! [`CharLmEngine::compact_lanes`]: crate::model::lm::CharLmEngine::compact_lanes

use std::collections::VecDeque;
use std::time::Instant;

use crate::model::lm::{nll_bits, CharLmEngine, LmBatchState};
use crate::tensor::qmatmul::kernel_counters::{self, KernelCounters};
use crate::workload::synth::RequestTrace;
use super::hibernate::{ColdTier, SpillCodec};
use super::registry::{ModelId, ModelRegistry};
use super::router::{ShardPoll, ShardRouter};
use super::session::{SessionId, SessionKey, SessionManager};
use super::trace::{EventKind, StageLatencies, TraceConfig, TraceEvent, TraceLevel, TraceRing};

/// Which scheduling discipline the coordinator runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// PR 1 baseline: admit only into an empty batch — every wave is
    /// packed once and runs to completion.
    Wave,
    /// Admit into free lanes between token positions.
    Continuous,
}

impl SchedulerMode {
    /// Short name used in reports and bench JSON ("wave"/"continuous").
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerMode::Wave => "wave",
            SchedulerMode::Continuous => "continuous",
        }
    }
}

/// One unit of work: a request's token chunk for a stream.
#[derive(Debug)]
pub struct StreamItem {
    /// The model this chunk executes under (the registry id; 0 in a
    /// single-model deployment).
    pub model: ModelId,
    /// The stream this chunk belongs to (scheduling is sticky per
    /// `(model, session)`: chunks apply to one evolving state, in
    /// order).
    pub session: SessionId,
    /// The token chunk to feed through the model.
    pub tokens: Vec<usize>,
    /// When the request entered the system (end-to-end latency base).
    pub submitted: Instant,
}

/// Completion record for one finished item.
///
/// Both latency fields are **wall-clock** milliseconds (measured from
/// [`StreamItem::submitted`]). Inside the virtual-time simulators they
/// are real elapsed time of the replay, *not* virtual ticks — schedule
/// metrics (steps, occupancy, makespan) live in [`SchedulerStats`] and
/// [`ShardSimReport::ticks`]; the two clocks are never mixed in one
/// field.
#[derive(Debug, Clone)]
pub struct StreamDone {
    /// The model the finished chunk executed under.
    pub model: ModelId,
    /// The stream the finished chunk belonged to.
    pub session: SessionId,
    /// Tokens executed for this item.
    pub tokens: usize,
    /// Total next-char negative log2-likelihood over the item.
    pub nll_bits: f64,
    /// Submission→completion wall-clock latency in milliseconds
    /// (formerly the ambiguously named `latency_ms`).
    pub wall_ms: f64,
    /// Submission→first-executed-token wall-clock latency in
    /// milliseconds (equals `wall_ms` for empty items, which execute
    /// nothing).
    pub first_token_wall_ms: f64,
}

/// One executed token position of one stream — emitted by the
/// scheduler when token recording is on
/// ([`ContinuousScheduler::set_record_tokens`]), so a streaming
/// front-end can forward per-token predictions as they happen and
/// tests can compare token streams bit-exactly across serving paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenEvent {
    /// The model that executed the position.
    pub model: ModelId,
    /// The stream the position belongs to.
    pub session: SessionId,
    /// Position within the item's token chunk (0-based).
    pub pos: usize,
    /// Greedy next-token prediction at this position: the first
    /// maximum of the logits row (deterministic tie-break).
    pub pred: usize,
}

/// One live lane of a model's persistent wave.
struct Lane {
    session: SessionId,
    tokens: Vec<usize>,
    /// Next token position to feed.
    pos: usize,
    /// Accumulated nll over this item (token order, f64).
    nll: f64,
    submitted: Instant,
    /// Wall-clock submission→first-token latency, stamped when the
    /// lane executes its first position (`None` until then).
    first_ms: Option<f64>,
}

/// One model's persistent wave on a worker: its batch state plus the
/// live lane bookkeeping. Lanes never mix models.
struct ModelWave {
    bs: LmBatchState,
    lanes: Vec<Lane>,
}

/// Counters the scheduler keeps about its own behaviour (kept both in
/// aggregate and per model).
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    /// Batched step invocations (one per token position per model
    /// wave — each is one pass of that model's GEMMs).
    pub batched_steps: usize,
    /// Lane-steps executed (= tokens through the batched path).
    pub lane_steps: usize,
    /// Lane-slots executed including SIMD tile padding: the *physical*
    /// GEMM width summed per batched step (always `>= lane_steps`).
    /// The gap between this and `lane_steps` is the zero-lane work the
    /// padding contract trades for tail-free full-tile kernels — kept
    /// separate so `mean_occupancy` stays an honest live-lane metric.
    pub padded_lane_steps: usize,
    /// Widest live batch observed (total live lanes for the aggregate
    /// stats; per-wave width for the per-model stats).
    pub peak_lanes: usize,
    /// Lane turnover: admissions into the wave.
    pub admissions: usize,
    /// Lane turnover: retirements out of the wave.
    pub retirements: usize,
    /// Total time items waited between submission and admission.
    pub admission_wait_ms: f64,
    /// Sessions evicted by [`ContinuousScheduler::enforce_session_budget`].
    pub evictions: usize,
    /// Sessions evicted by [`ContinuousScheduler::enforce_idle_budget`]
    /// (the idle-age policy; reported separately from the count-budget
    /// evictions).
    pub idle_evictions: usize,
    /// Sessions hibernated into the cold tier by
    /// [`ContinuousScheduler::enforce_state_budget`] (unlike an
    /// eviction, a spill is lossless — the stream resumes from its
    /// restored state).
    pub spills: usize,
    /// Sessions restored from the cold tier (transparently before lane
    /// admission, or by [`ContinuousScheduler::restore_all`]).
    pub restores: usize,
    /// Largest resident-state byte total observed by
    /// [`ContinuousScheduler::sample_resident_peak`] — sampled after
    /// budget enforcement each tick, so `peak <= budget` is the byte
    /// invariant `rust/tests/hibernation.rs` asserts.
    pub peak_resident_state_bytes: usize,
    /// Measured GEMM invocations and MAC counts by weight format,
    /// folded from the kernel-level counters
    /// ([`crate::tensor::qmatmul::kernel_counters`]) around each
    /// batched step. Zero unless the scheduler runs at
    /// [`TraceLevel::Counters`] or above.
    pub kernels: KernelCounters,
}

impl SchedulerStats {
    /// Mean lanes per batched step — the occupancy this whole refactor
    /// exists to lift.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Mean wait between submission and lane admission.
    pub fn mean_admission_ms(&self) -> f64 {
        if self.admissions == 0 {
            0.0
        } else {
            self.admission_wait_ms / self.admissions as f64
        }
    }

    /// Mean *physical* lanes per batched step — what the GEMMs actually
    /// executed, pad lanes included (always `>=` [`Self::mean_occupancy`]).
    pub fn padded_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.padded_lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Fraction of executed lane-slots that carried a live stream
    /// (`lane_steps / padded_lane_steps`; 1.0 = no padding waste —
    /// every live width was already a tile multiple).
    pub fn padding_efficiency(&self) -> f64 {
        if self.padded_lane_steps == 0 {
            1.0
        } else {
            self.lane_steps as f64 / self.padded_lane_steps as f64
        }
    }

    fn absorb(&mut self, other: &SchedulerStats) {
        self.batched_steps += other.batched_steps;
        self.lane_steps += other.lane_steps;
        self.padded_lane_steps += other.padded_lane_steps;
        self.peak_lanes = self.peak_lanes.max(other.peak_lanes);
        self.admissions += other.admissions;
        self.retirements += other.retirements;
        self.admission_wait_ms += other.admission_wait_ms;
        self.evictions += other.evictions;
        self.idle_evictions += other.idle_evictions;
        self.spills += other.spills;
        self.restores += other.restores;
        self.peak_resident_state_bytes =
            self.peak_resident_state_bytes.max(other.peak_resident_state_bytes);
        self.kernels.add(&other.kernels);
    }
}

/// The continuous-batching lane scheduler for one worker: one
/// persistent wave per resident model, a shared lane budget, and one
/// session table spanning all of them.
pub struct ContinuousScheduler<'a> {
    /// Engines by [`ModelId`]; `None` where the model is not resident
    /// on this worker.
    engines: Vec<Option<&'a CharLmEngine>>,
    sessions: SessionManager,
    /// Waves parallel to `engines` (`Some` exactly where resident).
    waves: Vec<Option<ModelWave>>,
    pending: VecDeque<StreamItem>,
    done: Vec<StreamDone>,
    toks: Vec<usize>,
    max_lanes: usize,
    mode: SchedulerMode,
    stats: SchedulerStats,
    model_stats: Vec<SchedulerStats>,
    /// When true, [`Self::step`] records one [`TokenEvent`] per
    /// executed lane position (off by default — simulators and trace
    /// replay don't pay for the argmax unless they ask).
    record_tokens: bool,
    token_events: Vec<TokenEvent>,
    /// Hibernated sessions (see [`super::hibernate`]): spilled out of
    /// the hot table by [`Self::enforce_state_budget`], restored
    /// transparently before lane admission.
    cold: ColdTier,
    /// Per-model session state bytes (`engine.state_bytes()`; 0 for
    /// non-resident models) — the prices the byte accounting uses.
    state_bytes: Vec<usize>,
    /// The observability ring (see [`super::trace`]): every lifecycle
    /// transition is emitted here at [`TraceLevel::Full`]; a no-op
    /// below that. Never consulted by any scheduling decision.
    trace: TraceRing,
    /// Per-stage wall-clock duration histograms, accumulated at
    /// [`TraceLevel::Counters`] and above.
    stage: StageLatencies,
}

/// First maximum of a logits row — the deterministic greedy decode
/// used for streamed per-token predictions (strictly-greater compare,
/// so ties resolve to the lowest index on every engine and path).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

impl<'a> ContinuousScheduler<'a> {
    /// Continuous-mode single-model scheduler with at most `max_lanes`
    /// live lanes (the model gets id 0).
    pub fn new(engine: &'a CharLmEngine, max_lanes: usize) -> Self {
        Self::with_mode(engine, max_lanes, SchedulerMode::Continuous)
    }

    /// A single-model scheduler with an explicit [`SchedulerMode`] (the
    /// wave mode is the PR 1 baseline kept for A/B runs).
    pub fn with_mode(
        engine: &'a CharLmEngine,
        max_lanes: usize,
        mode: SchedulerMode,
    ) -> Self {
        Self::multi(vec![Some(engine)], max_lanes, mode)
    }

    /// A multi-model scheduler: `engines[m]` is model `m`'s engine
    /// instance, `None` where the model is not resident on this worker.
    /// The `max_lanes` budget is shared across every resident model's
    /// wave. A worker with no resident model at all is legal (narrow
    /// residency policies leave such workers idle): it simply never
    /// admits work.
    pub fn multi(
        engines: Vec<Option<&'a CharLmEngine>>,
        max_lanes: usize,
        mode: SchedulerMode,
    ) -> Self {
        assert!(max_lanes >= 1, "need at least one lane");
        let waves = engines
            .iter()
            .map(|e| {
                e.map(|engine| ModelWave { bs: engine.new_batch_state(0), lanes: Vec::new() })
            })
            .collect();
        let n = engines.len();
        let state_bytes = engines
            .iter()
            .map(|e| e.map_or(0, |e| e.state_bytes()))
            .collect();
        ContinuousScheduler {
            engines,
            sessions: SessionManager::new(),
            waves,
            pending: VecDeque::new(),
            done: Vec::new(),
            toks: Vec::new(),
            max_lanes,
            mode,
            stats: SchedulerStats::default(),
            model_stats: vec![SchedulerStats::default(); n],
            record_tokens: false,
            token_events: Vec::new(),
            cold: ColdTier::new(SpillCodec::Exact),
            state_bytes,
            trace: TraceRing::new(TraceConfig::default(), 0),
            stage: StageLatencies::default(),
        }
    }

    /// Configure observability for this scheduler: the recording level
    /// and the worker index stamped onto emitted events. Replaces the
    /// ring, so call before any work runs (events emitted earlier are
    /// discarded).
    pub fn set_trace(&mut self, config: TraceConfig, worker: u32) {
        self.trace = TraceRing::new(config, worker);
        self.stage = StageLatencies::default();
    }

    /// The recording level this scheduler runs at.
    pub fn trace_level(&self) -> TraceLevel {
        self.trace.level()
    }

    /// Set the virtual-step clock stamped onto subsequent trace events
    /// (the simulators call this with their tick counter; the threaded
    /// server with its per-worker loop iteration).
    pub fn set_trace_step(&mut self, step: u64) {
        self.trace.set_step(step);
    }

    /// Emit one trace event on this scheduler's ring on behalf of the
    /// driving loop (e.g. the simulator's `Steal` events, which happen
    /// at the router, outside the scheduler proper). No-op below
    /// [`TraceLevel::Full`], like every emission.
    pub fn trace_event(
        &mut self,
        kind: EventKind,
        model: ModelId,
        session: SessionId,
        arg: u64,
    ) {
        self.trace.emit(kind, model, session, arg);
    }

    /// Drain the recorded trace events (emission order; empty below
    /// [`TraceLevel::Full`]).
    pub fn take_trace_events(&mut self) -> Vec<TraceEvent> {
        self.trace.take()
    }

    /// Events dropped to the ring's capacity bound so far.
    pub fn trace_dropped(&self) -> u64 {
        self.trace.dropped()
    }

    /// The per-stage duration histograms accumulated so far (all empty
    /// below [`TraceLevel::Counters`]).
    pub fn stage_latencies(&self) -> &StageLatencies {
        &self.stage
    }

    /// Take the per-stage duration histograms, leaving empty ones.
    pub fn take_stage_latencies(&mut self) -> StageLatencies {
        std::mem::take(&mut self.stage)
    }

    /// Turn per-token event recording on or off (see [`TokenEvent`]).
    pub fn set_record_tokens(&mut self, record: bool) {
        self.record_tokens = record;
    }

    /// Drain the recorded token events (empty unless
    /// [`Self::set_record_tokens`] enabled recording).
    pub fn take_token_events(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.token_events)
    }

    /// Select the hibernation codec (exact by default; int8 behind
    /// `--spill-quantized`). Must be called before anything spills —
    /// the cold tier cannot re-encode what it already holds.
    pub fn set_spill_codec(&mut self, codec: SpillCodec) {
        assert!(self.cold.is_empty(), "cannot change codec with sessions hibernated");
        self.cold = ColdTier::new(codec);
    }

    /// Enqueue an item for admission (FIFO per stream). The item's
    /// model must be resident on this worker. An out-of-range
    /// [`ModelId`] is a routing/registry wiring bug, not an absent
    /// model, and panics rather than being folded into the
    /// "non-resident" message (the silent-default contract of
    /// [`Self::live_lanes_model`]).
    pub fn offer(&mut self, item: StreamItem) {
        debug_assert!(
            (item.model as usize) < self.engines.len(),
            "model {} out of range: scheduler holds {} model slot(s)",
            item.model,
            self.engines.len()
        );
        assert!(
            self.engines[item.model as usize].is_some(),
            "model {} not resident on this worker",
            item.model
        );
        self.pending.push_back(item);
    }

    /// Move pending items into free lanes: at most `max_lanes` live
    /// lanes across all waves, at most one lane per `(model, session)`
    /// stream, earliest pending item per stream first. When free lanes
    /// are scarce they are split across models in proportion to their
    /// pending backlog (largest-remainder rounding, ties to the lower
    /// model id — deterministic), then filled FIFO within each model.
    /// In wave mode admission only happens into an empty scheduler.
    /// Returns how many lanes were admitted.
    pub fn admit_ready(&mut self) -> usize {
        let live = self.live_lanes();
        if self.mode == SchedulerMode::Wave && live > 0 {
            return 0;
        }
        let free = self.max_lanes.saturating_sub(live);
        if free == 0 || self.pending.is_empty() {
            self.stats.peak_lanes = self.stats.peak_lanes.max(live);
            return 0;
        }

        // Backlog-weighted lane quotas across models: when free lanes
        // are scarcer than the total backlog, each model gets its
        // proportional share (largest-remainder rounding, leftover
        // lanes to the largest remainders, ties to the lower model id
        // — deterministic). A single resident model degenerates to
        // `quota = min(free, backlog)`, i.e. plain FIFO.
        //
        // Backlog counts only *admittable* work — one per distinct
        // pending stream that is not already holding a lane, skipping
        // zero-token items. Raw queue depth would hand quota to a
        // model whose queued chunks can only wait (all behind one live
        // lane) while another model's admittable streams starve behind
        // a zero quota.
        let n = self.engines.len();
        let mut backlog = vec![0usize; n];
        let mut has_empty = false;
        let mut seen: Vec<SessionKey> = Vec::with_capacity(self.pending.len());
        for item in &self.pending {
            if item.tokens.is_empty() {
                has_empty = true;
                continue;
            }
            let key = (item.model, item.session);
            if seen.contains(&key) {
                continue;
            }
            seen.push(key);
            let m = item.model as usize;
            let laned = self.waves[m]
                .as_ref()
                .is_some_and(|w| w.lanes.iter().any(|l| l.session == item.session));
            if !laned {
                backlog[m] += 1;
            }
        }
        let total: usize = backlog.iter().sum();
        let mut quota = vec![0usize; n];
        if total <= free {
            quota.copy_from_slice(&backlog);
        } else {
            let mut assigned = 0usize;
            let mut remainders: Vec<(usize, usize)> = Vec::with_capacity(n);
            for m in 0..n {
                quota[m] = free * backlog[m] / total;
                assigned += quota[m];
                remainders.push((free * backlog[m] % total, m));
            }
            remainders.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
            let mut leftover = free - assigned;
            for &(_, m) in &remainders {
                if leftover == 0 {
                    break;
                }
                if quota[m] < backlog[m] {
                    quota[m] += 1;
                    leftover -= 1;
                }
            }
        }

        let mut admitted = 0;
        let mut i = 0;
        while i < self.pending.len() && (has_empty || quota.iter().any(|&q| q > 0)) {
            let model = self.pending[i].model;
            let m = model as usize;
            let is_empty = self.pending[i].tokens.is_empty();
            if !is_empty && quota[m] == 0 {
                i += 1;
                continue;
            }
            let sess = self.pending[i].session;
            if is_empty
                && self
                    .pending
                    .iter()
                    .take(i)
                    .any(|p| p.model == model && p.session == sess)
            {
                // FIFO per stream: items before index `i` were skipped
                // this pass, so an empty chunk behind an unadmitted
                // chunk of its own stream must not complete first.
                i += 1;
                continue;
            }
            let wave = self.waves[m].as_ref().expect("resident wave");
            if wave.lanes.iter().any(|l| l.session == sess) {
                // A lane for this stream is live; its next chunk must
                // wait so the stream's state advances in order.
                i += 1;
                continue;
            }
            let item = self.pending.remove(i).expect("index in bounds");
            if item.tokens.is_empty() {
                // Nothing to execute: complete immediately (consumes no
                // lane and no quota). The lifecycle log still pairs an
                // Admit with a Done, so every Admit has exactly one
                // completion regardless of chunk length.
                self.trace.emit(EventKind::Admit, item.model, item.session, 0);
                self.trace.emit(EventKind::Done, item.model, item.session, 0);
                let wall_ms = item.submitted.elapsed().as_secs_f64() * 1e3;
                self.done.push(StreamDone {
                    model: item.model,
                    session: item.session,
                    tokens: 0,
                    nll_bits: 0.0,
                    wall_ms,
                    first_token_wall_ms: wall_ms,
                });
                continue;
            }
            quota[m] -= 1;
            let wait_ms = item.submitted.elapsed().as_secs_f64() * 1e3;
            self.stats.admissions += 1;
            self.stats.admission_wait_ms += wait_ms;
            self.model_stats[m].admissions += 1;
            self.model_stats[m].admission_wait_ms += wait_ms;
            if self.trace.level() >= TraceLevel::Counters {
                self.stage.admission_wait.record(wait_ms);
            }
            self.trace.emit(
                EventKind::Admit,
                item.model,
                item.session,
                item.tokens.len() as u64,
            );
            let engine = self.engines[m].expect("resident engine");
            // Restore-before-admit: if this stream hibernated, wake it
            // into the hot table first, so the lane machinery below
            // (and every test of it) never sees a hibernated session.
            if self.cold.contains((item.model, item.session)) {
                let t0 =
                    (self.trace.level() >= TraceLevel::Counters).then(Instant::now);
                let s = self
                    .cold
                    .restore((item.model, item.session), engine)
                    .expect("contained key restores");
                self.sessions.insert(s);
                if let Some(t0) = t0 {
                    self.stage.spill_restore.record(t0.elapsed().as_secs_f64() * 1e3);
                }
                self.trace.emit(EventKind::Restore, item.model, item.session, 0);
                self.stats.restores += 1;
                self.model_stats[m].restores += 1;
            }
            let wave = self.waves[m].as_mut().expect("resident wave");
            let lane = {
                let session =
                    self.sessions.get_or_create(item.model, item.session, engine);
                if session.tokens_seen == 0 {
                    // `get_or_create` only ever creates at admission,
                    // and a session retires its first lane with
                    // `tokens_seen > 0` — so zero here means the state
                    // was materialized just now: the stream's bind.
                    self.trace.emit(EventKind::Bind, item.model, item.session, 0);
                }
                engine.admit_lane(&session.state, &mut wave.bs)
            };
            debug_assert_eq!(lane, wave.lanes.len());
            wave.lanes.push(Lane {
                session: item.session,
                tokens: item.tokens,
                pos: 0,
                nll: 0.0,
                submitted: item.submitted,
                first_ms: None,
            });
            self.model_stats[m].peak_lanes =
                self.model_stats[m].peak_lanes.max(wave.lanes.len());
            admitted += 1;
        }
        self.stats.peak_lanes = self.stats.peak_lanes.max(self.live_lanes());
        admitted
    }

    /// Advance every live lane one token position — one batched step
    /// per model wave with live lanes — then scatter finished lanes
    /// back to their sessions and compact them out. Advances the
    /// session table's logical activity clock by one tick. No-op when
    /// no lane is live anywhere.
    pub fn step(&mut self) {
        if self.live_lanes() == 0 {
            return;
        }
        self.sessions.tick();
        // Timing and counter folding are read *around* the batched
        // step, never inside any scheduling decision — the
        // tracing-never-perturbs-schedules invariant.
        let timed = self.trace.level() >= TraceLevel::Counters;
        for m in 0..self.waves.len() {
            let Some(wave) = self.waves[m].as_mut() else { continue };
            if wave.lanes.is_empty() {
                continue;
            }
            let engine = self.engines[m].expect("resident engine");
            debug_assert_eq!(wave.bs.batch(), wave.lanes.len());
            self.toks.clear();
            self.toks.extend(wave.lanes.iter().map(|l| l.tokens[l.pos]));
            if timed {
                kernel_counters::reset();
            }
            let t0 = timed.then(Instant::now);
            engine.step_tokens(&self.toks, &mut wave.bs);
            if let Some(t0) = t0 {
                let us = t0.elapsed().as_micros() as u64;
                let k = kernel_counters::take();
                self.stats.kernels.add(&k);
                self.model_stats[m].kernels.add(&k);
                self.stage.execute.record(us as f64 / 1e3);
                self.trace.emit_dur(
                    EventKind::StepBatch,
                    m as ModelId,
                    0,
                    wave.lanes.len() as u64,
                    us,
                );
            }
            self.stats.batched_steps += 1;
            self.stats.lane_steps += wave.lanes.len();
            self.stats.padded_lane_steps += wave.bs.padded_batch();
            self.model_stats[m].batched_steps += 1;
            self.model_stats[m].lane_steps += wave.lanes.len();
            self.model_stats[m].padded_lane_steps += wave.bs.padded_batch();
            for (lane, l) in wave.lanes.iter_mut().enumerate() {
                if l.first_ms.is_none() {
                    l.first_ms = Some(l.submitted.elapsed().as_secs_f64() * 1e3);
                    self.trace.emit(
                        EventKind::FirstToken,
                        m as ModelId,
                        l.session,
                        l.pos as u64,
                    );
                }
                if self.record_tokens {
                    self.token_events.push(TokenEvent {
                        model: m as ModelId,
                        session: l.session,
                        pos: l.pos,
                        pred: argmax(wave.bs.logits.row(lane)),
                    });
                }
                if let Some(&next) = l.tokens.get(l.pos + 1) {
                    l.nll += nll_bits(wave.bs.logits.row(lane), next);
                }
                l.pos += 1;
            }
            if wave.lanes.iter().any(|l| l.pos >= l.tokens.len()) {
                let mut keep = Vec::with_capacity(wave.lanes.len());
                for (lane, l) in wave.lanes.iter().enumerate() {
                    let finished = l.pos >= l.tokens.len();
                    keep.push(!finished);
                    if finished {
                        let session =
                            self.sessions.get_or_create(m as ModelId, l.session, engine);
                        engine.scatter_session(&wave.bs, &mut session.state, lane);
                        session.tokens_seen += l.tokens.len();
                        session.nll_bits += l.nll;
                        self.stats.retirements += 1;
                        self.model_stats[m].retirements += 1;
                        self.trace.emit(
                            EventKind::Done,
                            m as ModelId,
                            l.session,
                            l.tokens.len() as u64,
                        );
                        let wall_ms = l.submitted.elapsed().as_secs_f64() * 1e3;
                        self.done.push(StreamDone {
                            model: m as ModelId,
                            session: l.session,
                            tokens: l.tokens.len(),
                            nll_bits: l.nll,
                            wall_ms,
                            first_token_wall_ms: l.first_ms.unwrap_or(wall_ms),
                        });
                    }
                }
                engine.compact_lanes(&mut wave.bs, &keep);
                let mut it = keep.into_iter();
                wave.lanes.retain(|_| it.next().unwrap());
            }
        }
    }

    /// The protection set for eviction: streams holding a lane, streams
    /// with pending chunks, plus `also_protected`.
    fn protected_keys(&self, also_protected: &[SessionKey]) -> Vec<SessionKey> {
        let mut protected: Vec<SessionKey> = Vec::new();
        for (m, wave) in self.waves.iter().enumerate() {
            if let Some(wave) = wave {
                protected.extend(wave.lanes.iter().map(|l| (m as ModelId, l.session)));
            }
        }
        protected.extend(self.pending.iter().map(|p| (p.model, p.session)));
        protected.extend_from_slice(also_protected);
        protected
    }

    /// Enforce a resident-session memory budget: evict the
    /// longest-seen *idle* sessions until at most `keep_at_most`
    /// remain (across every model). Streams currently holding a lane,
    /// streams with pending chunks, and the keys in `also_protected`
    /// are never evicted — callers pass the streams whose next chunk is
    /// already queued at the ingest layer
    /// ([`ShardRouter::queued_sessions`]), so a stream with any
    /// in-flight work is never reset. The count can therefore stay
    /// above the budget while the waves are wide.
    ///
    /// Evicting a truly idle session *is* a stream reset: if a chunk
    /// for it arrives later, it restarts from zero state. Returns the
    /// evicted keys — a deterministic pure function of the session
    /// table and the protected sets (see
    /// [`SessionManager::evict_longest_protected`]).
    pub fn enforce_session_budget(
        &mut self,
        keep_at_most: usize,
        also_protected: &[SessionKey],
    ) -> Vec<SessionKey> {
        let protected = self.protected_keys(also_protected);
        let evicted = self.sessions.evict_longest_protected(keep_at_most, &protected);
        self.stats.evictions += evicted.len();
        for &(m, s) in &evicted {
            self.model_stats[m as usize].evictions += 1;
            self.trace.emit(EventKind::Evict, m, s, 0);
        }
        evicted
    }

    /// Enforce the idle-age policy: evict every unprotected session
    /// idle for more than `max_idle` scheduler ticks (one tick = one
    /// [`Self::step`] with live work; a session's clock resets at
    /// admission and retirement). Protection rules match
    /// [`Self::enforce_session_budget`]. Returns the evicted keys in
    /// deterministic order (see [`SessionManager::evict_idle_protected`]).
    pub fn enforce_idle_budget(
        &mut self,
        max_idle: u64,
        also_protected: &[SessionKey],
    ) -> Vec<SessionKey> {
        let protected = self.protected_keys(also_protected);
        let evicted = self.sessions.evict_idle_protected(max_idle, &protected);
        self.stats.idle_evictions += evicted.len();
        for &(m, s) in &evicted {
            self.model_stats[m as usize].idle_evictions += 1;
            self.trace.emit(EventKind::Evict, m, s, 1);
        }
        evicted
    }

    /// Bytes of session state resident in the hot table right now
    /// (per-model session counts × that model's
    /// [`CharLmEngine::state_bytes`] — the live number the registry's
    /// static accounting becomes under hibernation).
    pub fn resident_state_bytes(&self) -> usize {
        self.state_bytes
            .iter()
            .enumerate()
            .map(|(m, &b)| self.sessions.len_model(m as ModelId) * b)
            .sum()
    }

    /// Bytes held by the cold tier (encoded hibernated state).
    pub fn hibernated_state_bytes(&self) -> usize {
        self.cold.bytes()
    }

    /// The cold tier (hibernated-session counts, bytes, and codec).
    pub fn cold(&self) -> &ColdTier {
        &self.cold
    }

    /// Record the current resident-state byte total into
    /// [`SchedulerStats::peak_resident_state_bytes`]. The serving loop
    /// and the simulators call this *after* budget enforcement each
    /// tick, so the recorded peak is the post-enforcement quantity the
    /// byte-budget invariant is asserted on.
    pub fn sample_resident_peak(&mut self) {
        let bytes = self.resident_state_bytes();
        self.stats.peak_resident_state_bytes =
            self.stats.peak_resident_state_bytes.max(bytes);
    }

    /// Enforce a resident-state **byte** budget: hibernate the coldest
    /// idle sessions (by the `last_active` clock, ties by key — see
    /// [`SessionManager::coldest_first`]) until at most `budget` bytes
    /// of state remain resident. Streams holding a lane or with
    /// pending chunks are never spilled — but unlike eviction, streams
    /// whose next chunk is queued at the ingest layer need no
    /// protection here: a spill is lossless, and the chunk's admission
    /// restores the state transparently. The protected set is
    /// therefore bounded by `max_lanes`, so with `budget >= max_lanes ×
    /// state_bytes` the post-enforcement resident total never exceeds
    /// the budget. `budget = 0` spills everything idle — the
    /// forced-spill churn mode of the hibernation suite.
    ///
    /// Returns the spilled keys — a deterministic pure function of the
    /// session table and the live/pending sets.
    pub fn enforce_state_budget(&mut self, budget: usize) -> Vec<SessionKey> {
        let mut resident = self.resident_state_bytes();
        if resident <= budget {
            return Vec::new();
        }
        let protected = self.protected_keys(&[]);
        let order = self.sessions.coldest_first(&protected);
        let timed = self.trace.level() >= TraceLevel::Counters;
        let mut spilled = Vec::new();
        for key in order {
            if resident <= budget {
                break;
            }
            let s = self.sessions.take(key.0, key.1).expect("listed session resident");
            let engine = self.engines[key.0 as usize].expect("resident engine");
            resident -= self.state_bytes[key.0 as usize];
            let t0 = timed.then(Instant::now);
            let encoded = self.cold.spill(engine, s);
            if let Some(t0) = t0 {
                self.stage.spill_restore.record(t0.elapsed().as_secs_f64() * 1e3);
            }
            self.trace.emit(EventKind::Spill, key.0, key.1, encoded as u64);
            self.stats.spills += 1;
            self.model_stats[key.0 as usize].spills += 1;
            spilled.push(key);
        }
        spilled
    }

    /// Wake every hibernated session back into the hot table
    /// (deterministic key order). Test/drain convenience — steady-state
    /// serving restores on demand via admission. Returns how many
    /// sessions were restored.
    pub fn restore_all(&mut self) -> usize {
        let keys = self.cold.keys();
        for key in &keys {
            let engine = self.engines[key.0 as usize].expect("resident engine");
            let s = self.cold.restore(*key, engine).expect("listed key restores");
            self.sessions.insert(s);
            self.trace.emit(EventKind::Restore, key.0, key.1, 0);
            self.stats.restores += 1;
            self.model_stats[key.0 as usize].restores += 1;
        }
        keys.len()
    }

    /// Drain the completion buffer.
    pub fn take_completed(&mut self) -> Vec<StreamDone> {
        std::mem::take(&mut self.done)
    }

    /// True while anything is live or waiting (including buffered
    /// completions not yet drained).
    pub fn has_live_work(&self) -> bool {
        self.live_lanes() > 0 || !self.pending.is_empty() || !self.done.is_empty()
    }

    /// Number of live lanes across every model wave.
    pub fn live_lanes(&self) -> usize {
        self.waves.iter().flatten().map(|w| w.lanes.len()).sum()
    }

    /// Number of live lanes in one model's wave (0 for non-resident
    /// models). Panics on a [`ModelId`] the scheduler was never built
    /// with — an out-of-range id is a caller bug, not an idle model,
    /// and silently reporting 0 for it would hide broken registry
    /// wiring (the same defect class as the short-bias `unwrap_or(0)`
    /// fixed in `qmatmul::bias_at`).
    pub fn live_lanes_model(&self, model: ModelId) -> usize {
        debug_assert!(
            (model as usize) < self.waves.len(),
            "model {model} out of range: scheduler holds {} model slot(s)",
            self.waves.len()
        );
        self.waves[model as usize].as_ref().map_or(0, |w| w.lanes.len())
    }

    /// Number of items queued for admission.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Total width of the underlying batch states (must always equal
    /// [`Self::live_lanes`] — an invariant the test suite checks).
    pub fn batch_width(&self) -> usize {
        self.waves.iter().flatten().map(|w| w.bs.batch()).sum()
    }

    /// Width of one model's batch state (must equal
    /// [`Self::live_lanes_model`]; 0 for non-resident models). Like
    /// [`Self::live_lanes_model`], panics on an out-of-range
    /// [`ModelId`] instead of silently defaulting to 0.
    pub fn batch_width_model(&self, model: ModelId) -> usize {
        debug_assert!(
            (model as usize) < self.waves.len(),
            "model {model} out of range: scheduler holds {} model slot(s)",
            self.waves.len()
        );
        self.waves[model as usize].as_ref().map_or(0, |w| w.bs.batch())
    }

    /// Session ids of the live lanes, wave order then lane order (the
    /// single-model view; see [`Self::lane_model_sessions`]).
    pub fn lane_sessions(&self) -> Vec<SessionId> {
        self.lane_model_sessions().into_iter().map(|(_, s)| s).collect()
    }

    /// `(model, session)` keys of the live lanes, wave order then lane
    /// order.
    pub fn lane_model_sessions(&self) -> Vec<SessionKey> {
        let mut out = Vec::new();
        for (m, wave) in self.waves.iter().enumerate() {
            if let Some(wave) = wave {
                out.extend(wave.lanes.iter().map(|l| (m as ModelId, l.session)));
            }
        }
        out
    }

    /// The scheduling discipline this scheduler runs.
    pub fn mode(&self) -> SchedulerMode {
        self.mode
    }

    /// Snapshot of the scheduler's aggregate behaviour counters.
    pub fn stats(&self) -> SchedulerStats {
        self.stats
    }

    /// Per-model behaviour counters, indexed by [`ModelId`]
    /// (non-resident models report zeros).
    pub fn model_stats(&self) -> &[SchedulerStats] {
        &self.model_stats
    }

    /// Number of model slots this scheduler was built with.
    pub fn n_models(&self) -> usize {
        self.engines.len()
    }

    /// The worker's session table (persistent stream states).
    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }
}

/// Deterministic virtual-time replay of a [`RequestTrace`]: one batched
/// step consumes `tick_ms` of virtual time, requests are offered when
/// their arrival time is due, and idle gaps jump straight to the next
/// arrival. No threads, no wall clock — the same trace, mode, and tick
/// always produce the same schedule, so occupancy comparisons and
/// bit-exactness assertions are replayable.
///
/// Single-model: every request in the trace must carry model 0 (use
/// [`simulate_multi_shard_trace`] for mixed-model traces).
///
/// Returns the scheduler (for stats and final session states) and all
/// completions in completion order.
pub fn simulate_trace<'a>(
    engine: &'a CharLmEngine,
    trace: &RequestTrace,
    max_lanes: usize,
    mode: SchedulerMode,
    tick_ms: f64,
) -> (ContinuousScheduler<'a>, Vec<StreamDone>) {
    assert!(tick_ms > 0.0);
    let mut sched = ContinuousScheduler::with_mode(engine, max_lanes, mode);
    let mut completed = Vec::new();
    let mut next = 0usize;
    let mut now_ms = 0f64;
    while next < trace.requests.len() || sched.has_live_work() {
        while next < trace.requests.len() && trace.requests[next].arrival_ms <= now_ms {
            let r = &trace.requests[next];
            sched.offer(StreamItem {
                model: r.model,
                session: r.id,
                tokens: r.tokens.clone(),
                submitted: Instant::now(),
            });
            next += 1;
        }
        sched.admit_ready();
        if sched.live_lanes() == 0 {
            completed.append(&mut sched.take_completed());
            if next < trace.requests.len() {
                // Idle: jump to the next arrival.
                now_ms = now_ms.max(trace.requests[next].arrival_ms);
                continue;
            }
            break;
        }
        sched.step();
        completed.append(&mut sched.take_completed());
        now_ms += tick_ms;
    }
    (sched, completed)
}

/// Configuration of one multi-worker shard pool (threaded server and
/// virtual-time simulators share this shape).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Worker (shard) count; each worker owns one persistent wave per
    /// resident model.
    pub workers: usize,
    /// Maximum live lanes per worker, shared across its model waves.
    pub max_lanes: usize,
    /// Scheduling discipline of every worker.
    pub mode: SchedulerMode,
    /// Whether idle workers steal unbound sessions from backlogged
    /// peers (see [`ShardRouter`]).
    pub steal: bool,
    /// Per-worker cap on resident sessions (`None` = unbounded); see
    /// [`ContinuousScheduler::enforce_session_budget`].
    pub session_budget: Option<usize>,
    /// Evict sessions idle for more than this many scheduler ticks
    /// (`None` = never); see
    /// [`ContinuousScheduler::enforce_idle_budget`].
    pub evict_idle_after: Option<u64>,
    /// Per-worker resident-state **byte** budget (`None` = unbounded):
    /// hibernate coldest-first into the cold tier when exceeded; see
    /// [`ContinuousScheduler::enforce_state_budget`]. This is what the
    /// CLI's `--session-budget` now sets.
    pub state_budget: Option<usize>,
    /// Encode hibernated state int8 with per-vector scales instead of
    /// exact f32 bytes (`--spill-quantized`; lossy for float-engine
    /// state — see [`super::hibernate::SpillCodec`]).
    pub spill_quantized: bool,
    /// Test/chaos mode: every `k` ticks, spill *everything* idle
    /// (`enforce_state_budget(0)`) so churn suites can drive maximal
    /// spill/restore traffic deterministically (`None` = off).
    pub force_spill_every: Option<u64>,
    /// Virtual milliseconds one batched step consumes in simulation.
    pub tick_ms: f64,
    /// Record one [`TokenEvent`] per executed lane position (off by
    /// default; the correctness oracle the network front-end's
    /// loopback tests compare against).
    pub record_tokens: bool,
    /// Observability level and ring capacity for every worker (off by
    /// default; never changes token values or schedules — the
    /// invariant `rust/tests/trace_observability.rs` pins).
    pub trace: TraceConfig,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            workers: 2,
            max_lanes: 8,
            mode: SchedulerMode::Continuous,
            steal: true,
            session_budget: None,
            evict_idle_after: None,
            state_budget: None,
            spill_quantized: false,
            force_spill_every: None,
            tick_ms: 1.0,
            record_tokens: false,
            trace: TraceConfig::default(),
        }
    }
}

/// What one shard-pool simulation reports.
#[derive(Debug, Clone)]
pub struct ShardSimReport {
    /// Worker count the pool ran with.
    pub workers: usize,
    /// All completions, in completion order (worker index order within
    /// one tick).
    pub completions: Vec<StreamDone>,
    /// Per-worker scheduler counters.
    pub worker_stats: Vec<SchedulerStats>,
    /// Per-model scheduler counters aggregated across workers (indexed
    /// by [`ModelId`]; a single-model run reports one entry).
    pub per_model: Vec<SchedulerStats>,
    /// Steal invocations per worker (as thief).
    pub steal_events: Vec<usize>,
    /// Sessions stolen per worker (as thief).
    pub stolen_sessions: Vec<usize>,
    /// Sessions stolen per model.
    pub stolen_by_model: Vec<usize>,
    /// Virtual ticks in which at least one worker stepped — the
    /// makespan of the replay.
    pub ticks: usize,
    /// Streams evicted per worker under the session-count budget, in
    /// eviction order.
    pub evicted: Vec<Vec<SessionKey>>,
    /// Streams evicted per worker under the idle-age policy, in
    /// eviction order.
    pub idle_evicted: Vec<Vec<SessionKey>>,
    /// Streams hibernated per worker (byte budget or forced-spill), in
    /// spill order. A stream can appear repeatedly — every spill event
    /// is recorded, matching [`SchedulerStats::spills`].
    pub spilled: Vec<Vec<SessionKey>>,
    /// Per-token events in execution order (worker index order within
    /// one tick); empty unless [`ShardConfig::record_tokens`] was set.
    pub token_events: Vec<TokenEvent>,
    /// The merged lifecycle event log, ordered by `(tick, worker)`
    /// with each worker's emission order preserved; empty below
    /// [`TraceLevel::Full`]. The virtual-clock fields are a pure
    /// function of the simulated schedule, so
    /// [`super::trace::jsonl_string`] over this log is byte-stable
    /// across reruns of the same trace.
    pub trace_events: Vec<TraceEvent>,
    /// Pool-merged per-stage duration histograms (empty below
    /// [`TraceLevel::Counters`]).
    pub stage: StageLatencies,
}

impl ShardSimReport {
    /// Total lane-steps (tokens) executed across the pool.
    pub fn lane_steps(&self) -> usize {
        self.worker_stats.iter().map(|s| s.lane_steps).sum()
    }

    /// Pool occupancy: lane-steps per worker-tick. 1.0 means every
    /// worker averaged one live lane per tick; `max_lanes` is the
    /// ceiling. This is the metric stealing exists to lift: with
    /// skewed routing and no stealing, idle workers burn ticks at zero
    /// lanes while the hot worker's queue backs up.
    pub fn pool_occupancy(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.lane_steps() as f64 / (self.workers * self.ticks) as f64
        }
    }

    /// Total sessions moved between workers by stealing.
    pub fn total_stolen(&self) -> usize {
        self.stolen_sessions.iter().sum()
    }

    /// Total sessions evicted under the session-count budget.
    pub fn total_evicted(&self) -> usize {
        self.evicted.iter().map(|e| e.len()).sum()
    }

    /// Total sessions evicted under the idle-age policy.
    pub fn total_idle_evicted(&self) -> usize {
        self.idle_evicted.iter().map(|e| e.len()).sum()
    }

    /// Total spill events across the pool.
    pub fn total_spilled(&self) -> usize {
        self.spilled.iter().map(|e| e.len()).sum()
    }

    /// Total restore events across the pool.
    pub fn total_restored(&self) -> usize {
        self.worker_stats.iter().map(|s| s.restores).sum()
    }
}

/// Deterministic virtual-time replay of a single-model [`RequestTrace`]
/// through a whole sharded worker pool: `cfg.workers` schedulers fed by
/// one [`ShardRouter`], all driven from a single thread on a virtual
/// clock (one batched step per worker per tick). Each tick, workers
/// ingest in index order — draining their own queue first, then
/// stealing whole unbound sessions from the most-backlogged peer — then
/// every worker with live lanes steps once. Identical inputs always
/// produce identical schedules, steal decisions, and completions, so
/// the sharded-serving suite can assert bit-exactness and occupancy
/// wins reproducibly.
///
/// Returns the schedulers (for final session states) and the report.
pub fn simulate_shard_trace<'a>(
    engine: &'a CharLmEngine,
    trace: &RequestTrace,
    cfg: &ShardConfig,
) -> (Vec<ContinuousScheduler<'a>>, ShardSimReport) {
    let engines = std::slice::from_ref(engine);
    let residency = vec![(0..cfg.workers).collect::<Vec<usize>>()];
    simulate_multi_shard_trace(engines, &residency, trace, cfg)
}

/// [`simulate_shard_trace`] generalized to the model registry: one
/// engine instance per model (index = [`ModelId`]; a single instance
/// can serve every simulated worker — the replay is single-threaded),
/// plus the per-model resident worker sets the router should respect
/// (the shape [`ModelRegistry::residency`] produces). Every worker
/// hosts one wave per model resident on it; stealing only moves a
/// session to workers holding its model.
pub fn simulate_multi_shard_trace<'a>(
    engines: &'a [CharLmEngine],
    residency: &[Vec<usize>],
    trace: &RequestTrace,
    cfg: &ShardConfig,
) -> (Vec<ContinuousScheduler<'a>>, ShardSimReport) {
    assert!(cfg.tick_ms > 0.0);
    assert!(cfg.workers > 0);
    assert_eq!(engines.len(), residency.len(), "one residency set per model");
    let router = ShardRouter::with_residency(cfg.workers, cfg.steal, residency.to_vec());
    let mut scheds: Vec<ContinuousScheduler<'a>> = (0..cfg.workers)
        .map(|w| {
            let per_worker: Vec<Option<&CharLmEngine>> = engines
                .iter()
                .enumerate()
                .map(|(m, e)| residency[m].contains(&w).then_some(e))
                .collect();
            let mut sched =
                ContinuousScheduler::multi(per_worker, cfg.max_lanes, cfg.mode);
            sched.set_record_tokens(cfg.record_tokens);
            sched.set_trace(cfg.trace, w as u32);
            if cfg.spill_quantized {
                sched.set_spill_codec(SpillCodec::Int8);
            }
            sched
        })
        .collect();
    let mut completions = Vec::new();
    let mut token_events = Vec::new();
    let mut evicted: Vec<Vec<SessionKey>> = vec![Vec::new(); cfg.workers];
    let mut idle_evicted: Vec<Vec<SessionKey>> = vec![Vec::new(); cfg.workers];
    let mut spilled: Vec<Vec<SessionKey>> = vec![Vec::new(); cfg.workers];
    let mut steal_storm_guard = 0usize;
    let mut next = 0usize;
    let mut now_ms = 0f64;
    let mut ticks = 0usize;
    let mut closed = false;
    loop {
        while next < trace.requests.len() && trace.requests[next].arrival_ms <= now_ms {
            let r = &trace.requests[next];
            router.submit(StreamItem {
                model: r.model,
                session: r.id,
                tokens: r.tokens.clone(),
                submitted: Instant::now(),
            });
            next += 1;
        }
        if next >= trace.requests.len() && !closed {
            router.close();
            closed = true;
        }
        // Ingest + admit, worker index order (deterministic).
        for (w, sched) in scheds.iter_mut().enumerate() {
            // Stamp the virtual clock onto this tick's trace events —
            // the deterministic `step` field the JSONL log orders by.
            sched.set_trace_step(ticks as u64);
            let capacity = cfg
                .max_lanes
                .saturating_sub(sched.live_lanes() + sched.pending_len());
            if capacity > 0 {
                match router.poll(w, capacity) {
                    ShardPoll::Items(new) => {
                        for item in new {
                            sched.offer(item);
                        }
                    }
                    ShardPoll::Stolen { items: new, victim } => {
                        // One Steal event per stolen session (a steal
                        // moves whole sessions; their queued chunks
                        // arrive together).
                        let mut stolen: Vec<SessionKey> = Vec::new();
                        for item in new {
                            let key = (item.model, item.session);
                            if !stolen.contains(&key) {
                                stolen.push(key);
                                sched.trace_event(
                                    EventKind::Steal,
                                    item.model,
                                    item.session,
                                    victim as u64,
                                );
                            }
                            sched.offer(item);
                        }
                    }
                    ShardPoll::Empty | ShardPoll::Closed => {}
                }
            }
            sched.admit_ready();
        }
        // Step every live wave; drain completions and enforce budgets.
        let mut stepped = false;
        for (w, sched) in scheds.iter_mut().enumerate() {
            if sched.live_lanes() > 0 {
                sched.step();
                stepped = true;
            }
            if cfg.session_budget.is_some() || cfg.evict_idle_after.is_some() {
                let queued = router.queued_sessions(w);
                if let Some(budget) = cfg.session_budget {
                    evicted[w].extend(sched.enforce_session_budget(budget, &queued));
                }
                if let Some(max_idle) = cfg.evict_idle_after {
                    idle_evicted[w]
                        .extend(sched.enforce_idle_budget(max_idle, &queued));
                }
            }
            // Hibernation enforcement: the forced-spill churn mode
            // first (spill everything idle every k-th tick), then the
            // byte budget; the peak sample after both, so the recorded
            // peak is the post-enforcement invariant quantity.
            if let Some(every) = cfg.force_spill_every {
                if every > 0 && (ticks as u64 + 1) % every == 0 {
                    spilled[w].extend(sched.enforce_state_budget(0));
                }
            }
            if let Some(budget) = cfg.state_budget {
                spilled[w].extend(sched.enforce_state_budget(budget));
            }
            sched.sample_resident_peak();
            token_events.append(&mut sched.take_token_events());
            completions.append(&mut sched.take_completed());
        }
        if stepped {
            ticks += 1;
            now_ms += cfg.tick_ms;
        } else {
            if next < trace.requests.len() {
                // Idle: jump to the next arrival.
                now_ms = now_ms.max(trace.requests[next].arrival_ms);
                continue;
            }
            if scheds.iter().all(|s| !s.has_live_work()) && router.is_drained() {
                break;
            }
            steal_storm_guard += 1;
            assert!(steal_storm_guard < 1_000_000, "shard simulation failed to drain");
        }
    }
    let mut per_model = vec![SchedulerStats::default(); engines.len()];
    for sched in &scheds {
        for (m, st) in sched.model_stats().iter().enumerate() {
            per_model[m].absorb(st);
        }
    }
    let trace_events = super::trace::merge_events(
        scheds.iter_mut().map(|s| s.take_trace_events()).collect(),
    );
    let mut stage = StageLatencies::default();
    for sched in &scheds {
        stage.merge(sched.stage_latencies());
    }
    let report = ShardSimReport {
        workers: cfg.workers,
        completions,
        worker_stats: scheds.iter().map(|s| s.stats()).collect(),
        per_model,
        steal_events: router.steal_events(),
        stolen_sessions: router.stolen_sessions(),
        stolen_by_model: router.stolen_by_model(engines.len()),
        ticks,
        evicted,
        idle_evicted,
        spilled,
        token_events,
        trace_events,
        stage,
    };
    (scheds, report)
}

/// Convenience wrapper: simulate a mixed-model trace straight from a
/// [`ModelRegistry`] (builds one engine instance per model and the
/// residency map for `cfg.workers`).
pub fn simulate_registry_trace(
    registry: &ModelRegistry<'_>,
    trace: &RequestTrace,
    cfg: &ShardConfig,
) -> ShardSimReport {
    let engines = registry.instantiate_all();
    let residency = registry.residency(cfg.workers);
    let (_scheds, report) =
        simulate_multi_shard_trace(&engines, &residency, trace, cfg);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, QuantizeOptions, StackEngine, StackWeights};
    use crate::model::lm::{CharLm, VOCAB};
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(41);
        let spec = LstmSpec::plain(VOCAB, 16);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 16);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 16, depth: 1 }
    }

    fn item(session: SessionId, tokens: Vec<usize>) -> StreamItem {
        StreamItem { model: 0, session, tokens, submitted: Instant::now() }
    }

    fn item_m(model: ModelId, session: SessionId, tokens: Vec<usize>) -> StreamItem {
        StreamItem { model, session, tokens, submitted: Instant::now() }
    }

    #[test]
    fn continuous_admits_mid_flight_wave_does_not() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        for (mode, expect_mid_wave) in
            [(SchedulerMode::Continuous, true), (SchedulerMode::Wave, false)]
        {
            let mut sched = ContinuousScheduler::with_mode(&engine, 4, mode);
            sched.offer(item(1, vec![3; 6]));
            assert_eq!(sched.admit_ready(), 1);
            sched.step();
            // A second session arrives while lane 0 is mid-flight.
            sched.offer(item(2, vec![5; 4]));
            let admitted = sched.admit_ready();
            assert_eq!(admitted == 1, expect_mid_wave, "{mode:?}");
            while sched.has_live_work() {
                sched.admit_ready();
                sched.step();
                sched.take_completed();
            }
            assert_eq!(sched.stats().retirements, 2, "{mode:?}");
            assert_eq!(sched.stats().lane_steps, 10, "{mode:?}");
        }
    }

    #[test]
    fn same_session_chunks_never_coexist() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 4);
        sched.offer(item(9, vec![1; 5]));
        sched.offer(item(9, vec![2; 5]));
        sched.offer(item(7, vec![3; 3]));
        while sched.has_live_work() {
            sched.admit_ready();
            let ids = sched.lane_sessions();
            let unique: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(unique.len(), ids.len(), "session double-occupied: {ids:?}");
            assert_eq!(sched.batch_width(), ids.len());
            sched.step();
            sched.take_completed();
        }
        let s = sched.sessions().get(9).unwrap();
        assert_eq!(s.tokens_seen, 10);
    }

    #[test]
    fn empty_item_completes_immediately() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 2);
        sched.offer(item(5, Vec::new()));
        sched.admit_ready();
        let done = sched.take_completed();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, 0);
        assert_eq!(sched.live_lanes(), 0);
        assert!(!sched.has_live_work());
    }

    #[test]
    fn simulate_trace_completes_everything_deterministically() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let trace = RequestTrace::generate(12, 800.0, 10, VOCAB, 3);
        let (s1, d1) = simulate_trace(&engine, &trace, 4, SchedulerMode::Continuous, 1.0);
        let (s2, d2) = simulate_trace(&engine, &trace, 4, SchedulerMode::Continuous, 1.0);
        assert_eq!(d1.len(), 12);
        assert_eq!(d2.len(), 12);
        assert_eq!(s1.stats().batched_steps, s2.stats().batched_steps);
        assert_eq!(s1.stats().lane_steps, s2.stats().lane_steps);
        for (a, b) in d1.iter().zip(&d2) {
            assert_eq!(a.session, b.session);
            assert_eq!(a.nll_bits.to_bits(), b.nll_bits.to_bits());
        }
    }

    #[test]
    fn session_budget_never_evicts_live_or_pending_sessions() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 2);
        // Retire sessions 1 and 2 fully, then park 3 and 4 live with 5
        // pending behind them.
        sched.offer(item(1, vec![1; 2]));
        sched.offer(item(2, vec![2; 2]));
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        sched.offer(item(3, vec![3; 8]));
        sched.offer(item(4, vec![4; 8]));
        sched.offer(item(5, vec![5; 8]));
        sched.admit_ready();
        sched.step();
        assert_eq!(sched.lane_sessions(), vec![3, 4]);
        // Budget 0: only the idle sessions (1, 2) may go.
        let evicted = sched.enforce_session_budget(0, &[]);
        assert_eq!(evicted, vec![(0, 2), (0, 1)], "longest-first, ties by id desc");
        assert!(sched.sessions().get(3).is_some());
        assert!(sched.sessions().get(4).is_some());
        assert_eq!(sched.stats().evictions, 2);
        // Drain; the protected sessions completed untouched.
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        assert_eq!(sched.sessions().get(3).unwrap().tokens_seen, 8);
        assert_eq!(sched.sessions().get(5).unwrap().tokens_seen, 8);
    }

    #[test]
    fn session_budget_honours_externally_protected_sessions() {
        // A session whose next chunk is still queued at the ingest
        // layer is passed via `also_protected` and must survive even
        // when it is the longest idle stream.
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 2);
        sched.offer(item(1, vec![1; 6]));
        sched.offer(item(2, vec![2; 3]));
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        // Session 1 is the longest idle stream but its next chunk is
        // "in flight" upstream: only 2 may be evicted.
        let evicted = sched.enforce_session_budget(0, &[(0, 1)]);
        assert_eq!(evicted, vec![(0, 2)]);
        assert!(sched.sessions().get(1).is_some());
    }

    #[test]
    fn idle_budget_ages_out_retired_sessions_only() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 1);
        // Session 1 retires early, session 2 keeps stepping.
        sched.offer(item(1, vec![1; 2]));
        sched.offer(item(2, vec![2; 12]));
        let mut guard = 0;
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
            let evicted = sched.enforce_idle_budget(4, &[]);
            // Session 2 is live (or just retired, hence active) the
            // whole run: only 1 may ever age out.
            for (m, id) in evicted {
                assert_eq!((m, id), (0, 1), "only the idle session may age out");
            }
            guard += 1;
            assert!(guard < 100);
        }
        assert!(sched.sessions().get(1).is_none(), "session 1 must have aged out");
        assert!(sched.sessions().get(2).is_some());
        assert_eq!(sched.stats().idle_evictions, 1);
        assert_eq!(sched.stats().evictions, 0);
    }

    #[test]
    fn waves_never_mix_models_and_share_the_lane_budget() {
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let e1 = lm.engine(StackEngine::Hybrid, None, QuantizeOptions::default());
        let mut sched =
            ContinuousScheduler::multi(vec![Some(&e0), Some(&e1)], 4, SchedulerMode::Continuous);
        for s in 0..3u64 {
            sched.offer(item_m(0, s, vec![1; 6]));
            sched.offer(item_m(1, 100 + s, vec![2; 6]));
        }
        let mut guard = 0;
        while sched.has_live_work() {
            sched.admit_ready();
            // Lane budget shared across waves; per-wave widths honest.
            assert!(sched.live_lanes() <= 4);
            assert_eq!(
                sched.live_lanes(),
                sched.live_lanes_model(0) + sched.live_lanes_model(1)
            );
            assert_eq!(sched.batch_width_model(0), sched.live_lanes_model(0));
            assert_eq!(sched.batch_width_model(1), sched.live_lanes_model(1));
            // Lanes grouped per model, no cross-model keys.
            for (m, s) in sched.lane_model_sessions() {
                assert_eq!(m == 1, s >= 100, "lane in the wrong model wave");
            }
            sched.step();
            sched.take_completed();
            guard += 1;
            assert!(guard < 1000);
        }
        assert_eq!(sched.stats().retirements, 6);
        assert_eq!(sched.model_stats()[0].retirements, 3);
        assert_eq!(sched.model_stats()[1].retirements, 3);
        // 6 tokens x 3 sessions per model.
        assert_eq!(sched.model_stats()[0].lane_steps, 18);
        assert_eq!(sched.model_stats()[1].lane_steps, 18);
    }

    #[test]
    fn admission_splits_scarce_lanes_by_backlog() {
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let e1 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched =
            ContinuousScheduler::multi(vec![Some(&e0), Some(&e1)], 4, SchedulerMode::Continuous);
        // Backlog 3:1 across models, 4 free lanes: the whole backlog
        // fits, so model 0 gets three lanes and model 1 one.
        for s in 0..3u64 {
            sched.offer(item_m(0, s, vec![1; 4]));
        }
        sched.offer(item_m(1, 9, vec![2; 4]));
        assert_eq!(sched.admit_ready(), 4);
        assert_eq!(sched.live_lanes_model(0), 3);
        assert_eq!(sched.live_lanes_model(1), 1);

        // Scarcer still: 6 pending of model 0, 2 of model 1, but only
        // 4 lanes total — the proportional split is 3:1 (4·6/8 : 4·2/8),
        // so the smaller model is never starved by a dominant backlog.
        let mut sched =
            ContinuousScheduler::multi(vec![Some(&e0), Some(&e1)], 4, SchedulerMode::Continuous);
        for s in 0..6u64 {
            sched.offer(item_m(0, 10 + s, vec![1; 4]));
        }
        for s in 0..2u64 {
            sched.offer(item_m(1, 20 + s, vec![2; 4]));
        }
        assert_eq!(sched.admit_ready(), 4);
        assert_eq!(sched.live_lanes_model(0), 3);
        assert_eq!(sched.live_lanes_model(1), 1);
    }

    #[test]
    fn blocked_chunks_do_not_hoard_admission_quota() {
        // Model 0's queue is deep — but every queued chunk belongs to
        // the one session already holding a lane, so none of it is
        // admittable. The free lane must go to model 1's idle streams
        // (raw queue depth would give model 0 the whole quota and
        // starve model 1 until the live chunk retires).
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let e1 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched =
            ContinuousScheduler::multi(vec![Some(&e0), Some(&e1)], 2, SchedulerMode::Continuous);
        sched.offer(item_m(0, 7, vec![1; 8]));
        assert_eq!(sched.admit_ready(), 1);
        for _ in 0..3 {
            sched.offer(item_m(0, 7, vec![2; 4])); // chunks behind the live lane
        }
        sched.offer(item_m(1, 100, vec![3; 4]));
        sched.offer(item_m(1, 101, vec![3; 4]));
        assert_eq!(sched.admit_ready(), 1, "the free lane must not sit empty");
        assert_eq!(sched.live_lanes_model(0), 1);
        assert_eq!(sched.live_lanes_model(1), 1);
        assert_eq!(sched.lane_model_sessions(), vec![(0, 7), (1, 100)]);
    }

    #[test]
    fn multi_shard_simulation_is_deterministic() {
        let lm = tiny_lm();
        let engines =
            vec![
                lm.engine(StackEngine::Float, None, QuantizeOptions::default()),
                lm.engine(StackEngine::Hybrid, None, QuantizeOptions::default()),
            ];
        let residency = vec![vec![0, 1], vec![0, 1]];
        let mut trace = RequestTrace::generate(20, 900.0, 8, VOCAB, 51);
        trace.assign_models(|id| (id % 2) as ModelId);
        let cfg = ShardConfig { workers: 2, max_lanes: 4, ..ShardConfig::default() };
        let (_s1, r1) = simulate_multi_shard_trace(&engines, &residency, &trace, &cfg);
        let (_s2, r2) = simulate_multi_shard_trace(&engines, &residency, &trace, &cfg);
        assert_eq!(r1.completions.len(), 20);
        assert_eq!(r1.ticks, r2.ticks);
        assert_eq!(r1.stolen_by_model, r2.stolen_by_model);
        for (a, b) in r1.completions.iter().zip(&r2.completions) {
            assert_eq!((a.model, a.session), (b.model, b.session));
            assert_eq!(a.nll_bits.to_bits(), b.nll_bits.to_bits());
        }
        // Per-model counters cover the whole trace.
        let tokens: usize = trace.requests.iter().map(|r| r.tokens.len()).sum();
        assert_eq!(r1.per_model.iter().map(|s| s.lane_steps).sum::<usize>(), tokens);
    }

    #[test]
    #[should_panic]
    fn live_lanes_model_panics_on_out_of_range_model() {
        // Two model slots: asking about model 7 is a caller bug and
        // must panic, never silently report "0 live lanes".
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let sched =
            ContinuousScheduler::multi(vec![Some(&e0), None], 2, SchedulerMode::Continuous);
        let _ = sched.live_lanes_model(7);
    }

    #[test]
    #[should_panic]
    fn batch_width_model_panics_on_out_of_range_model() {
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let sched =
            ContinuousScheduler::multi(vec![Some(&e0), None], 2, SchedulerMode::Continuous);
        let _ = sched.batch_width_model(7);
    }

    #[test]
    fn non_resident_model_still_reports_zero_lanes() {
        // The fix must not change the legitimate `None → 0` mapping: an
        // in-range model that simply is not resident on this worker has
        // zero lanes and zero width, without panicking.
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let sched =
            ContinuousScheduler::multi(vec![Some(&e0), None], 2, SchedulerMode::Continuous);
        assert_eq!(sched.live_lanes_model(1), 0);
        assert_eq!(sched.batch_width_model(1), 0);
    }

    #[test]
    fn idle_budget_boundary_exact_age_survives() {
        // Pin the documented boundary of `--evict-idle-after N`: a
        // session idle for exactly N ticks survives; N+1 evicts ("idle
        // for *more than* N").
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 1);
        // Session 1 retires after 2 steps; session 2 then keeps the
        // scheduler ticking one step at a time.
        sched.offer(item(1, vec![1; 2]));
        sched.admit_ready();
        sched.step();
        sched.step();
        sched.take_completed();
        assert_eq!(sched.live_lanes(), 0);
        // Session 1 was last active at its retirement tick. Tick the
        // clock exactly N=3 more times via session 2's steps.
        sched.offer(item(2, vec![2; 3]));
        sched.admit_ready();
        for _ in 0..3 {
            sched.step();
        }
        sched.take_completed();
        // Idle age == 3: must survive a threshold of 3 …
        assert!(sched.enforce_idle_budget(3, &[]).is_empty());
        assert!(sched.sessions().get(1).is_some());
        // … and age 4 (one more tick) must evict under the same
        // threshold.
        sched.offer(item(3, vec![3; 1]));
        sched.admit_ready();
        sched.step();
        sched.take_completed();
        assert_eq!(sched.enforce_idle_budget(3, &[]), vec![(0, 1)]);
        assert!(sched.sessions().get(1).is_none());
        assert_eq!(sched.stats().idle_evictions, 1);
    }

    #[test]
    #[should_panic]
    fn offer_panics_on_out_of_range_model() {
        // One model slot: offering model 7 is a routing wiring bug and
        // must panic, never be silently mistaken for "non-resident".
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched =
            ContinuousScheduler::multi(vec![Some(&e0)], 2, SchedulerMode::Continuous);
        sched.offer(item_m(7, 1, vec![1; 2]));
    }

    #[test]
    #[should_panic]
    fn offer_panics_on_non_resident_model() {
        // In-range but not resident here: still a panic (the router
        // must never deliver a non-resident model's chunk), with the
        // descriptive message in both debug and release builds.
        let lm = tiny_lm();
        let e0 = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched =
            ContinuousScheduler::multi(vec![Some(&e0), None], 2, SchedulerMode::Continuous);
        sched.offer(item_m(1, 1, vec![1; 2]));
    }

    #[test]
    fn state_budget_spills_coldest_and_restores_on_admission() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let per = engine.state_bytes();
        let mut sched = ContinuousScheduler::new(&engine, 2);
        // Retire three sessions at staggered activity times.
        for (id, len) in [(1u64, 4usize), (2, 4), (3, 4)] {
            sched.offer(item(id, vec![1; len]));
            while sched.has_live_work() {
                sched.admit_ready();
                sched.step();
                sched.take_completed();
            }
        }
        assert_eq!(sched.resident_state_bytes(), 3 * per);
        // Budget for one resident session: the two coldest spill.
        let spilled = sched.enforce_state_budget(per);
        assert_eq!(spilled, vec![(0, 1), (0, 2)], "coldest-first by last_active");
        assert_eq!(sched.resident_state_bytes(), per);
        assert_eq!(sched.hibernated_state_bytes(), 2 * per);
        assert_eq!(sched.stats().spills, 2);
        assert_eq!(sched.sessions().evicted(), 0, "a spill is not an eviction");
        // Idempotent under the budget.
        assert!(sched.enforce_state_budget(per).is_empty());
        // The next chunk for a hibernated stream restores transparently
        // on admission and the stream history is intact.
        assert!(sched.sessions().get(1).is_none(), "session 1 must be hibernated");
        sched.offer(item(1, vec![2; 3]));
        sched.admit_ready();
        assert_eq!(sched.stats().restores, 1);
        let s = sched.sessions().get(1).expect("restored");
        assert_eq!(s.tokens_seen, 4);
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        assert_eq!(sched.sessions().get(1).unwrap().tokens_seen, 7);
        // restore_all wakes the remaining one.
        assert_eq!(sched.restore_all(), 1);
        assert!(sched.cold().is_empty());
        assert_eq!(sched.stats().restores, 2);
        assert_eq!(sched.resident_state_bytes(), 3 * per);
    }

    #[test]
    fn state_budget_never_spills_live_or_pending_streams() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 1);
        sched.offer(item(1, vec![1; 6]));
        sched.offer(item(2, vec![2; 6]));
        sched.admit_ready();
        sched.step();
        // Session 1 holds the lane; 2 is pending. Budget 0 must spill
        // neither (there is nothing idle).
        assert!(sched.enforce_state_budget(0).is_empty());
        assert_eq!(sched.lane_sessions(), vec![1]);
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        assert_eq!(sched.sessions().get(1).unwrap().tokens_seen, 6);
        assert_eq!(sched.sessions().get(2).unwrap().tokens_seen, 6);
    }

    #[test]
    fn forced_spill_churn_in_simulation_is_bit_exact() {
        // Chaos mode: every tick, everything idle spills; every
        // follow-up chunk restores. Completions must match the
        // no-hibernation run bit for bit (exact codec).
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut trace = RequestTrace::generate(18, 700.0, 8, VOCAB, 29);
        // Fold onto 6 streams so sessions span several chunks.
        for r in &mut trace.requests {
            r.id %= 6;
        }
        let base = ShardConfig { workers: 2, max_lanes: 3, ..ShardConfig::default() };
        let churn =
            ShardConfig { force_spill_every: Some(1), ..base.clone() };
        let (_s0, r0) = simulate_shard_trace(&engine, &trace, &base);
        let (_s1, r1) = simulate_shard_trace(&engine, &trace, &churn);
        assert!(r1.total_spilled() > 0, "churn mode must actually spill");
        assert!(r1.total_restored() > 0, "follow-up chunks must restore");
        assert_eq!(r0.completions.len(), r1.completions.len());
        for (a, b) in r0.completions.iter().zip(&r1.completions) {
            assert_eq!((a.model, a.session, a.tokens), (b.model, b.session, b.tokens));
            assert_eq!(a.nll_bits.to_bits(), b.nll_bits.to_bits());
        }
    }

    #[test]
    fn token_events_off_by_default_and_deterministic_when_on() {
        let lm = tiny_lm();
        let seqs: Vec<Vec<usize>> = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let oh: Vec<_> =
            seqs.iter().map(|s| crate::model::lm::one_hot_seq(s)).collect();
        let stats = lm.stack_weights.calibrate(&oh);
        let engine =
            lm.engine(StackEngine::Integer, Some(&stats), QuantizeOptions::default());
        let trace = RequestTrace::generate(8, 600.0, 10, VOCAB, 13);
        let run = |record: bool| {
            let cfg = ShardConfig {
                workers: 2,
                max_lanes: 4,
                record_tokens: record,
                ..ShardConfig::default()
            };
            let (_s, rep) = simulate_shard_trace(&engine, &trace, &cfg);
            rep
        };
        assert!(run(false).token_events.is_empty(), "tap must be off by default");
        let r1 = run(true);
        let r2 = run(true);
        let tokens: usize = trace.requests.iter().map(|r| r.tokens.len()).sum();
        assert_eq!(r1.token_events.len(), tokens, "one event per executed position");
        assert_eq!(r1.token_events, r2.token_events, "tap must be deterministic");
        // Per-stream positions are contiguous from 0 (chunk order).
        for req in &trace.requests {
            let positions: Vec<usize> = r1
                .token_events
                .iter()
                .filter(|e| e.session == req.id)
                .map(|e| e.pos)
                .collect();
            assert_eq!(positions, (0..req.tokens.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn trace_off_by_default_and_lifecycle_complete_when_full() {
        let lm = tiny_lm();
        // Integer engine: its batched steps run the int8 GEMMs, so the
        // folded kernel counters must be nonzero at Counters+.
        let seqs: Vec<Vec<usize>> = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let oh: Vec<_> =
            seqs.iter().map(|s| crate::model::lm::one_hot_seq(s)).collect();
        let stats = lm.stack_weights.calibrate(&oh);
        let engine =
            lm.engine(StackEngine::Integer, Some(&stats), QuantizeOptions::default());
        let trace = RequestTrace::generate(10, 600.0, 8, VOCAB, 17);
        let base = ShardConfig { workers: 2, max_lanes: 4, ..ShardConfig::default() };
        let (_s, off) = simulate_shard_trace(&engine, &trace, &base);
        assert!(off.trace_events.is_empty(), "trace must be off by default");
        assert!(off.stage.is_empty());
        assert!(off.worker_stats.iter().all(|s| s.kernels.is_empty()));

        let full = ShardConfig { trace: TraceConfig::full(), ..base.clone() };
        let (scheds, rep) = simulate_shard_trace(&engine, &trace, &full);
        assert!(scheds.iter().all(|s| s.trace_dropped() == 0));
        let count =
            |k: EventKind| rep.trace_events.iter().filter(|e| e.kind == k).count();
        // Every chunk admission pairs with exactly one Done.
        assert_eq!(count(EventKind::Admit), trace.requests.len());
        assert_eq!(count(EventKind::Done), trace.requests.len());
        assert!(count(EventKind::StepBatch) > 0);
        assert!(count(EventKind::FirstToken) > 0);
        // Counters flow at Full too, and the schedule is untouched.
        assert!(!rep.stage.is_empty());
        assert!(rep.worker_stats.iter().any(|s| !s.kernels.is_empty()));
        assert_eq!(rep.completions.len(), off.completions.len());
        for (a, b) in rep.completions.iter().zip(&off.completions) {
            assert_eq!((a.model, a.session, a.tokens), (b.model, b.session, b.tokens));
            assert_eq!(a.nll_bits.to_bits(), b.nll_bits.to_bits());
        }
        // The merged log is step-ordered.
        assert!(rep
            .trace_events
            .windows(2)
            .all(|w| w[0].step <= w[1].step));
    }
}
