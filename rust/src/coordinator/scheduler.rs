//! Continuous batching: the lane scheduler that keeps the batched
//! int8 path saturated under streaming arrivals.
//!
//! PR 1's coordinator packed *waves*: every lane of a batch started and
//! (modulo prefix truncation) ended together, so occupancy collapsed
//! whenever sessions arrived mid-wave or finished at different lengths.
//! This scheduler runs one *persistent* wave whose lanes turn over
//! independently:
//!
//! * between token positions, pending sessions are admitted into free
//!   lanes ([`ContinuousScheduler::admit_ready`] →
//!   [`CharLmEngine::admit_lane`]);
//! * every [`ContinuousScheduler::step`] advances all live lanes one
//!   token position with a single batched step;
//! * lanes whose items are exhausted are scattered back to their
//!   sessions and compacted out
//!   ([`CharLmEngine::compact_lanes`]), so live lanes stay a dense
//!   prefix and the GEMM never touches dead rows.
//!
//! Scheduling invariants (locked down by
//! `rust/tests/continuous_batching.rs` and
//! `rust/tests/sharded_serving.rs`):
//!
//! 1. at most one lane per session at any time (a stream's state must
//!    advance in arrival order);
//! 2. the batch width always equals the live lane count;
//! 3. every session's output is bit-exact with running it alone on the
//!    sequential `step` path — admission order, lane moves, and
//!    compaction never touch the numerics.
//!
//! The scheduler is deliberately free of threads and wall-clock
//! decisions: the serving worker drives it from a [`ShardRouter`],
//! [`simulate_trace`] drives one instance from a virtual clock, and
//! [`simulate_shard_trace`] drives a whole worker pool (with work
//! stealing) the same way — so tests and benches get deterministic,
//! replayable schedules.
//!
//! [`ShardRouter`]: super::router::ShardRouter
//! [`CharLmEngine::admit_lane`]: crate::model::lm::CharLmEngine::admit_lane
//! [`CharLmEngine::compact_lanes`]: crate::model::lm::CharLmEngine::compact_lanes

use std::collections::VecDeque;
use std::time::Instant;

use crate::model::lm::{nll_bits, CharLmEngine, LmBatchState};
use crate::workload::synth::RequestTrace;
use super::router::{ShardPoll, ShardRouter};
use super::session::{SessionId, SessionManager};

/// Which scheduling discipline the coordinator runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// PR 1 baseline: admit only into an empty batch — every wave is
    /// packed once and runs to completion.
    Wave,
    /// Admit into free lanes between token positions.
    Continuous,
}

impl SchedulerMode {
    /// Short name used in reports and bench JSON ("wave"/"continuous").
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerMode::Wave => "wave",
            SchedulerMode::Continuous => "continuous",
        }
    }
}

/// One unit of work: a request's token chunk for a session.
#[derive(Debug)]
pub struct StreamItem {
    /// The stream this chunk belongs to (scheduling is sticky per
    /// session: chunks apply to one evolving state, in order).
    pub session: SessionId,
    /// The token chunk to feed through the model.
    pub tokens: Vec<usize>,
    /// When the request entered the system (end-to-end latency base).
    pub submitted: Instant,
}

/// Completion record for one finished item.
#[derive(Debug, Clone)]
pub struct StreamDone {
    /// The stream the finished chunk belonged to.
    pub session: SessionId,
    /// Tokens executed for this item.
    pub tokens: usize,
    /// Total next-char negative log2-likelihood over the item.
    pub nll_bits: f64,
    /// Submission→completion latency in milliseconds.
    pub latency_ms: f64,
}

/// One live lane of the persistent wave.
struct Lane {
    session: SessionId,
    tokens: Vec<usize>,
    /// Next token position to feed.
    pos: usize,
    /// Accumulated nll over this item (token order, f64).
    nll: f64,
    submitted: Instant,
}

/// Counters the scheduler keeps about its own behaviour.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    /// Batched step invocations (one per token position of the wave).
    pub batched_steps: usize,
    /// Lane-steps executed (= tokens through the batched path).
    pub lane_steps: usize,
    /// Lane-slots executed including SIMD tile padding: the *physical*
    /// GEMM width summed per batched step (always `>= lane_steps`).
    /// The gap between this and `lane_steps` is the zero-lane work the
    /// padding contract trades for tail-free full-tile kernels — kept
    /// separate so `mean_occupancy` stays an honest live-lane metric.
    pub padded_lane_steps: usize,
    /// Widest live batch observed.
    pub peak_lanes: usize,
    /// Lane turnover: admissions into the wave.
    pub admissions: usize,
    /// Lane turnover: retirements out of the wave.
    pub retirements: usize,
    /// Total time items waited between submission and admission.
    pub admission_wait_ms: f64,
    /// Sessions evicted by [`ContinuousScheduler::enforce_session_budget`].
    pub evictions: usize,
}

impl SchedulerStats {
    /// Mean lanes per batched step — the occupancy this whole refactor
    /// exists to lift.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Mean wait between submission and lane admission.
    pub fn mean_admission_ms(&self) -> f64 {
        if self.admissions == 0 {
            0.0
        } else {
            self.admission_wait_ms / self.admissions as f64
        }
    }

    /// Mean *physical* lanes per batched step — what the GEMMs actually
    /// executed, pad lanes included (always `>=` [`Self::mean_occupancy`]).
    pub fn padded_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.padded_lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Fraction of executed lane-slots that carried a live stream
    /// (`lane_steps / padded_lane_steps`; 1.0 = no padding waste —
    /// every live width was already a tile multiple).
    pub fn padding_efficiency(&self) -> f64 {
        if self.padded_lane_steps == 0 {
            1.0
        } else {
            self.lane_steps as f64 / self.padded_lane_steps as f64
        }
    }
}

/// The continuous-batching lane scheduler for one worker.
pub struct ContinuousScheduler<'a> {
    engine: &'a CharLmEngine,
    sessions: SessionManager,
    bs: LmBatchState,
    lanes: Vec<Lane>,
    pending: VecDeque<StreamItem>,
    done: Vec<StreamDone>,
    toks: Vec<usize>,
    max_lanes: usize,
    mode: SchedulerMode,
    stats: SchedulerStats,
}

impl<'a> ContinuousScheduler<'a> {
    /// Continuous-mode scheduler with at most `max_lanes` live lanes.
    pub fn new(engine: &'a CharLmEngine, max_lanes: usize) -> Self {
        Self::with_mode(engine, max_lanes, SchedulerMode::Continuous)
    }

    /// A scheduler with an explicit [`SchedulerMode`] (the wave mode is
    /// the PR 1 baseline kept for A/B runs).
    pub fn with_mode(
        engine: &'a CharLmEngine,
        max_lanes: usize,
        mode: SchedulerMode,
    ) -> Self {
        assert!(max_lanes >= 1, "need at least one lane");
        ContinuousScheduler {
            engine,
            sessions: SessionManager::new(),
            bs: engine.new_batch_state(0),
            lanes: Vec::new(),
            pending: VecDeque::new(),
            done: Vec::new(),
            toks: Vec::new(),
            max_lanes,
            mode,
            stats: SchedulerStats::default(),
        }
    }

    /// Enqueue an item for admission (FIFO per session).
    pub fn offer(&mut self, item: StreamItem) {
        self.pending.push_back(item);
    }

    /// Move pending items into free lanes: at most `max_lanes` live
    /// lanes, at most one lane per session, earliest pending item per
    /// session first. In wave mode admission only happens into an empty
    /// batch. Returns how many lanes were admitted.
    pub fn admit_ready(&mut self) -> usize {
        if self.mode == SchedulerMode::Wave && !self.lanes.is_empty() {
            return 0;
        }
        let engine = self.engine;
        let mut admitted = 0;
        let mut i = 0;
        while self.lanes.len() < self.max_lanes && i < self.pending.len() {
            let sess = self.pending[i].session;
            if self.lanes.iter().any(|l| l.session == sess) {
                // A lane for this session is live; its next chunk must
                // wait so the stream's state advances in order.
                i += 1;
                continue;
            }
            let item = self.pending.remove(i).expect("index in bounds");
            if item.tokens.is_empty() {
                // Nothing to execute: complete immediately.
                self.done.push(StreamDone {
                    session: item.session,
                    tokens: 0,
                    nll_bits: 0.0,
                    latency_ms: item.submitted.elapsed().as_secs_f64() * 1e3,
                });
                continue;
            }
            self.stats.admissions += 1;
            self.stats.admission_wait_ms +=
                item.submitted.elapsed().as_secs_f64() * 1e3;
            let lane = {
                let state = &self.sessions.get_or_create(item.session, engine).state;
                engine.admit_lane(state, &mut self.bs)
            };
            debug_assert_eq!(lane, self.lanes.len());
            self.lanes.push(Lane {
                session: item.session,
                tokens: item.tokens,
                pos: 0,
                nll: 0.0,
                submitted: item.submitted,
            });
            admitted += 1;
        }
        self.stats.peak_lanes = self.stats.peak_lanes.max(self.lanes.len());
        admitted
    }

    /// Advance every live lane one token position with a single batched
    /// step, then scatter finished lanes back to their sessions and
    /// compact them out. No-op when no lane is live.
    pub fn step(&mut self) {
        if self.lanes.is_empty() {
            return;
        }
        debug_assert_eq!(self.bs.batch(), self.lanes.len());
        let engine = self.engine;
        self.toks.clear();
        self.toks.extend(self.lanes.iter().map(|l| l.tokens[l.pos]));
        engine.step_tokens(&self.toks, &mut self.bs);
        self.stats.batched_steps += 1;
        self.stats.lane_steps += self.lanes.len();
        self.stats.padded_lane_steps += self.bs.padded_batch();
        for (lane, l) in self.lanes.iter_mut().enumerate() {
            if let Some(&next) = l.tokens.get(l.pos + 1) {
                l.nll += nll_bits(self.bs.logits.row(lane), next);
            }
            l.pos += 1;
        }
        if self.lanes.iter().any(|l| l.pos >= l.tokens.len()) {
            let mut keep = Vec::with_capacity(self.lanes.len());
            for (lane, l) in self.lanes.iter().enumerate() {
                let finished = l.pos >= l.tokens.len();
                keep.push(!finished);
                if finished {
                    let session = self.sessions.get_or_create(l.session, engine);
                    engine.scatter_session(&self.bs, &mut session.state, lane);
                    session.tokens_seen += l.tokens.len();
                    session.nll_bits += l.nll;
                    self.stats.retirements += 1;
                    self.done.push(StreamDone {
                        session: l.session,
                        tokens: l.tokens.len(),
                        nll_bits: l.nll,
                        latency_ms: l.submitted.elapsed().as_secs_f64() * 1e3,
                    });
                }
            }
            engine.compact_lanes(&mut self.bs, &keep);
            let mut it = keep.into_iter();
            self.lanes.retain(|_| it.next().unwrap());
        }
    }

    /// Enforce a resident-session memory budget: evict the
    /// longest-seen *idle* sessions until at most `keep_at_most`
    /// remain. Sessions currently holding a lane, sessions with
    /// pending chunks, and the ids in `also_protected` are never
    /// evicted — callers pass the sessions whose next chunk is already
    /// queued at the ingest layer ([`ShardRouter::queued_sessions`]),
    /// so a stream with any in-flight work is never reset. The count
    /// can therefore stay above the budget while the wave is wide.
    ///
    /// Evicting a truly idle session *is* a stream reset: if a chunk
    /// for it arrives later, it restarts from zero state. Returns the
    /// evicted ids — a deterministic pure function of the session
    /// table and the protected sets (see
    /// [`SessionManager::evict_longest_protected`]).
    pub fn enforce_session_budget(
        &mut self,
        keep_at_most: usize,
        also_protected: &[SessionId],
    ) -> Vec<SessionId> {
        let mut protected: Vec<SessionId> =
            self.lanes.iter().map(|l| l.session).collect();
        protected.extend(self.pending.iter().map(|p| p.session));
        protected.extend_from_slice(also_protected);
        let evicted = self.sessions.evict_longest_protected(keep_at_most, &protected);
        self.stats.evictions += evicted.len();
        evicted
    }

    /// Drain the completion buffer.
    pub fn take_completed(&mut self) -> Vec<StreamDone> {
        std::mem::take(&mut self.done)
    }

    /// True while anything is live or waiting (including buffered
    /// completions not yet drained).
    pub fn has_live_work(&self) -> bool {
        !self.lanes.is_empty() || !self.pending.is_empty() || !self.done.is_empty()
    }

    /// Number of live lanes in the wave.
    pub fn live_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Number of items queued for admission.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Current width of the underlying batch state (must always equal
    /// [`Self::live_lanes`] — an invariant the test suite checks).
    pub fn batch_width(&self) -> usize {
        self.bs.batch()
    }

    /// Session ids of the live lanes, in lane order.
    pub fn lane_sessions(&self) -> Vec<SessionId> {
        self.lanes.iter().map(|l| l.session).collect()
    }

    /// The scheduling discipline this scheduler runs.
    pub fn mode(&self) -> SchedulerMode {
        self.mode
    }

    /// Snapshot of the scheduler's behaviour counters.
    pub fn stats(&self) -> SchedulerStats {
        self.stats
    }

    /// The worker's session table (persistent stream states).
    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }
}

/// Deterministic virtual-time replay of a [`RequestTrace`]: one batched
/// step consumes `tick_ms` of virtual time, requests are offered when
/// their arrival time is due, and idle gaps jump straight to the next
/// arrival. No threads, no wall clock — the same trace, mode, and tick
/// always produce the same schedule, so occupancy comparisons and
/// bit-exactness assertions are replayable.
///
/// Returns the scheduler (for stats and final session states) and all
/// completions in completion order.
pub fn simulate_trace<'a>(
    engine: &'a CharLmEngine,
    trace: &RequestTrace,
    max_lanes: usize,
    mode: SchedulerMode,
    tick_ms: f64,
) -> (ContinuousScheduler<'a>, Vec<StreamDone>) {
    assert!(tick_ms > 0.0);
    let mut sched = ContinuousScheduler::with_mode(engine, max_lanes, mode);
    let mut completed = Vec::new();
    let mut next = 0usize;
    let mut now_ms = 0f64;
    while next < trace.requests.len() || sched.has_live_work() {
        while next < trace.requests.len() && trace.requests[next].arrival_ms <= now_ms {
            let r = &trace.requests[next];
            sched.offer(StreamItem {
                session: r.id,
                tokens: r.tokens.clone(),
                submitted: Instant::now(),
            });
            next += 1;
        }
        sched.admit_ready();
        if sched.live_lanes() == 0 {
            completed.append(&mut sched.take_completed());
            if next < trace.requests.len() {
                // Idle: jump to the next arrival.
                now_ms = now_ms.max(trace.requests[next].arrival_ms);
                continue;
            }
            break;
        }
        sched.step();
        completed.append(&mut sched.take_completed());
        now_ms += tick_ms;
    }
    (sched, completed)
}

/// Configuration of one multi-worker shard pool (threaded server and
/// virtual-time simulator share this shape).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Worker (shard) count; each worker owns one persistent wave.
    pub workers: usize,
    /// Maximum live lanes per worker wave.
    pub max_lanes: usize,
    /// Scheduling discipline of every worker.
    pub mode: SchedulerMode,
    /// Whether idle workers steal unbound sessions from backlogged
    /// peers (see [`ShardRouter`]).
    pub steal: bool,
    /// Per-worker cap on resident sessions (`None` = unbounded); see
    /// [`ContinuousScheduler::enforce_session_budget`].
    pub session_budget: Option<usize>,
    /// Virtual milliseconds one batched step consumes in simulation.
    pub tick_ms: f64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            workers: 2,
            max_lanes: 8,
            mode: SchedulerMode::Continuous,
            steal: true,
            session_budget: None,
            tick_ms: 1.0,
        }
    }
}

/// What one [`simulate_shard_trace`] run reports.
#[derive(Debug, Clone)]
pub struct ShardSimReport {
    /// Worker count the pool ran with.
    pub workers: usize,
    /// All completions, in completion order (worker index order within
    /// one tick).
    pub completions: Vec<StreamDone>,
    /// Per-worker scheduler counters.
    pub worker_stats: Vec<SchedulerStats>,
    /// Steal invocations per worker (as thief).
    pub steal_events: Vec<usize>,
    /// Sessions stolen per worker (as thief).
    pub stolen_sessions: Vec<usize>,
    /// Virtual ticks in which at least one worker stepped — the
    /// makespan of the replay.
    pub ticks: usize,
    /// Sessions evicted per worker under the session budget, in
    /// eviction order.
    pub evicted: Vec<Vec<SessionId>>,
}

impl ShardSimReport {
    /// Total lane-steps (tokens) executed across the pool.
    pub fn lane_steps(&self) -> usize {
        self.worker_stats.iter().map(|s| s.lane_steps).sum()
    }

    /// Pool occupancy: lane-steps per worker-tick. 1.0 means every
    /// worker averaged one live lane per tick; `max_lanes` is the
    /// ceiling. This is the metric stealing exists to lift: with
    /// skewed routing and no stealing, idle workers burn ticks at zero
    /// lanes while the hot worker's queue backs up.
    pub fn pool_occupancy(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.lane_steps() as f64 / (self.workers * self.ticks) as f64
        }
    }

    /// Total sessions moved between workers by stealing.
    pub fn total_stolen(&self) -> usize {
        self.stolen_sessions.iter().sum()
    }

    /// Total sessions evicted under the session budget.
    pub fn total_evicted(&self) -> usize {
        self.evicted.iter().map(|e| e.len()).sum()
    }
}

/// Deterministic virtual-time replay of a [`RequestTrace`] through a
/// whole sharded worker pool: `cfg.workers` schedulers fed by one
/// [`ShardRouter`], all driven from a single thread on a virtual clock
/// (one batched step per worker per tick). Each tick, workers ingest in
/// index order — draining their own queue first, then stealing whole
/// unbound sessions from the most-backlogged peer — then every worker
/// with live lanes steps once. Identical inputs always produce
/// identical schedules, steal decisions, and completions, so the
/// sharded-serving suite can assert bit-exactness and occupancy wins
/// reproducibly.
///
/// Returns the schedulers (for final session states) and the report.
pub fn simulate_shard_trace<'a>(
    engine: &'a CharLmEngine,
    trace: &RequestTrace,
    cfg: &ShardConfig,
) -> (Vec<ContinuousScheduler<'a>>, ShardSimReport) {
    assert!(cfg.tick_ms > 0.0);
    assert!(cfg.workers > 0);
    let router = ShardRouter::new(cfg.workers, cfg.steal);
    let mut scheds: Vec<ContinuousScheduler<'a>> = (0..cfg.workers)
        .map(|_| ContinuousScheduler::with_mode(engine, cfg.max_lanes, cfg.mode))
        .collect();
    let mut completions = Vec::new();
    let mut evicted: Vec<Vec<SessionId>> = vec![Vec::new(); cfg.workers];
    let mut steal_storm_guard = 0usize;
    let mut next = 0usize;
    let mut now_ms = 0f64;
    let mut ticks = 0usize;
    let mut closed = false;
    loop {
        while next < trace.requests.len() && trace.requests[next].arrival_ms <= now_ms {
            let r = &trace.requests[next];
            router.submit(StreamItem {
                session: r.id,
                tokens: r.tokens.clone(),
                submitted: Instant::now(),
            });
            next += 1;
        }
        if next >= trace.requests.len() && !closed {
            router.close();
            closed = true;
        }
        // Ingest + admit, worker index order (deterministic).
        for (w, sched) in scheds.iter_mut().enumerate() {
            let capacity = cfg
                .max_lanes
                .saturating_sub(sched.live_lanes() + sched.pending_len());
            if capacity > 0 {
                match router.poll(w, capacity) {
                    ShardPoll::Items(new) | ShardPoll::Stolen { items: new, .. } => {
                        for item in new {
                            sched.offer(item);
                        }
                    }
                    ShardPoll::Empty | ShardPoll::Closed => {}
                }
            }
            sched.admit_ready();
        }
        // Step every live wave; drain completions and enforce budgets.
        let mut stepped = false;
        for (w, sched) in scheds.iter_mut().enumerate() {
            if sched.live_lanes() > 0 {
                sched.step();
                stepped = true;
            }
            if let Some(budget) = cfg.session_budget {
                evicted[w].extend(
                    sched.enforce_session_budget(budget, &router.queued_sessions(w)),
                );
            }
            completions.append(&mut sched.take_completed());
        }
        if stepped {
            ticks += 1;
            now_ms += cfg.tick_ms;
        } else {
            if next < trace.requests.len() {
                // Idle: jump to the next arrival.
                now_ms = now_ms.max(trace.requests[next].arrival_ms);
                continue;
            }
            if scheds.iter().all(|s| !s.has_live_work()) && router.is_drained() {
                break;
            }
            steal_storm_guard += 1;
            assert!(steal_storm_guard < 1_000_000, "shard simulation failed to drain");
        }
    }
    let report = ShardSimReport {
        workers: cfg.workers,
        completions,
        worker_stats: scheds.iter().map(|s| s.stats()).collect(),
        steal_events: router.steal_events(),
        stolen_sessions: router.stolen_sessions(),
        ticks,
        evicted,
    };
    (scheds, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, QuantizeOptions, StackEngine, StackWeights};
    use crate::model::lm::{CharLm, VOCAB};
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(41);
        let spec = LstmSpec::plain(VOCAB, 16);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 16);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 16, depth: 1 }
    }

    fn item(session: SessionId, tokens: Vec<usize>) -> StreamItem {
        StreamItem { session, tokens, submitted: Instant::now() }
    }

    #[test]
    fn continuous_admits_mid_flight_wave_does_not() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        for (mode, expect_mid_wave) in
            [(SchedulerMode::Continuous, true), (SchedulerMode::Wave, false)]
        {
            let mut sched = ContinuousScheduler::with_mode(&engine, 4, mode);
            sched.offer(item(1, vec![3; 6]));
            assert_eq!(sched.admit_ready(), 1);
            sched.step();
            // A second session arrives while lane 0 is mid-flight.
            sched.offer(item(2, vec![5; 4]));
            let admitted = sched.admit_ready();
            assert_eq!(admitted == 1, expect_mid_wave, "{mode:?}");
            while sched.has_live_work() {
                sched.admit_ready();
                sched.step();
                sched.take_completed();
            }
            assert_eq!(sched.stats().retirements, 2, "{mode:?}");
            assert_eq!(sched.stats().lane_steps, 10, "{mode:?}");
        }
    }

    #[test]
    fn same_session_chunks_never_coexist() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 4);
        sched.offer(item(9, vec![1; 5]));
        sched.offer(item(9, vec![2; 5]));
        sched.offer(item(7, vec![3; 3]));
        while sched.has_live_work() {
            sched.admit_ready();
            let ids = sched.lane_sessions();
            let unique: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(unique.len(), ids.len(), "session double-occupied: {ids:?}");
            assert_eq!(sched.batch_width(), ids.len());
            sched.step();
            sched.take_completed();
        }
        let s = sched.sessions().get(9).unwrap();
        assert_eq!(s.tokens_seen, 10);
    }

    #[test]
    fn empty_item_completes_immediately() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 2);
        sched.offer(item(5, Vec::new()));
        sched.admit_ready();
        let done = sched.take_completed();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, 0);
        assert_eq!(sched.live_lanes(), 0);
        assert!(!sched.has_live_work());
    }

    #[test]
    fn simulate_trace_completes_everything_deterministically() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let trace = RequestTrace::generate(12, 800.0, 10, VOCAB, 3);
        let (s1, d1) = simulate_trace(&engine, &trace, 4, SchedulerMode::Continuous, 1.0);
        let (s2, d2) = simulate_trace(&engine, &trace, 4, SchedulerMode::Continuous, 1.0);
        assert_eq!(d1.len(), 12);
        assert_eq!(d2.len(), 12);
        assert_eq!(s1.stats().batched_steps, s2.stats().batched_steps);
        assert_eq!(s1.stats().lane_steps, s2.stats().lane_steps);
        for (a, b) in d1.iter().zip(&d2) {
            assert_eq!(a.session, b.session);
            assert_eq!(a.nll_bits.to_bits(), b.nll_bits.to_bits());
        }
    }

    #[test]
    fn session_budget_never_evicts_live_or_pending_sessions() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 2);
        // Retire sessions 1 and 2 fully, then park 3 and 4 live with 5
        // pending behind them.
        sched.offer(item(1, vec![1; 2]));
        sched.offer(item(2, vec![2; 2]));
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        sched.offer(item(3, vec![3; 8]));
        sched.offer(item(4, vec![4; 8]));
        sched.offer(item(5, vec![5; 8]));
        sched.admit_ready();
        sched.step();
        assert_eq!(sched.lane_sessions(), vec![3, 4]);
        // Budget 0: only the idle sessions (1, 2) may go.
        let evicted = sched.enforce_session_budget(0, &[]);
        assert_eq!(evicted, vec![2, 1], "longest-first, ties by id desc");
        assert!(sched.sessions().get(3).is_some());
        assert!(sched.sessions().get(4).is_some());
        assert_eq!(sched.stats().evictions, 2);
        // Drain; the protected sessions completed untouched.
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        assert_eq!(sched.sessions().get(3).unwrap().tokens_seen, 8);
        assert_eq!(sched.sessions().get(5).unwrap().tokens_seen, 8);
    }

    #[test]
    fn session_budget_honours_externally_protected_sessions() {
        // A session whose next chunk is still queued at the ingest
        // layer is passed via `also_protected` and must survive even
        // when it is the longest idle stream.
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut sched = ContinuousScheduler::new(&engine, 2);
        sched.offer(item(1, vec![1; 6]));
        sched.offer(item(2, vec![2; 3]));
        while sched.has_live_work() {
            sched.admit_ready();
            sched.step();
            sched.take_completed();
        }
        // Session 1 is the longest idle stream but its next chunk is
        // "in flight" upstream: only 2 may be evicted.
        let evicted = sched.enforce_session_budget(0, &[1]);
        assert_eq!(evicted, vec![2]);
        assert!(sched.sessions().get(1).is_some());
    }
}
