//! The wall-clock TCP serving front: a `std::net` streaming server in
//! front of the sharded worker pool, with explicit backpressure and
//! graceful drain.
//!
//! Everything below the socket is the exact machinery trace replay
//! uses — the same [`ShardRouter`], the same worker loop
//! (`server::run_worker`), the same continuous batcher — so a
//! loopback client observes token streams *bit-identical* to
//! [`simulate_shard_trace`](super::scheduler::simulate_shard_trace)
//! on the same requests (locked down by `rust/tests/net_serving.rs`). No async runtime: an acceptor thread
//! polls a non-blocking listener, each connection gets one reader and
//! one writer thread, and a single dispatcher thread fans worker
//! events out to connection writers.
//!
//! ## Wire protocol
//!
//! Every frame is `[u32 len (LE)] [u8 kind] [payload]`, where `len`
//! counts the kind byte plus the payload. Integers are little-endian;
//! floats are IEEE-754 bit patterns. Kinds:
//!
//! | kind | name | payload | direction |
//! |------|------|---------|-----------|
//! | 0x01 | `Request` | model u32, session u64, n u32, n × token u32 | client → server |
//! | 0x02 | `Stats` | sub-kind u8 (`0` = Prometheus text snapshot) | client → server |
//! | 0x11 | `Token` | model u32, session u64, pos u32, pred u32 | server → client |
//! | 0x12 | `Done` | model u32, session u64, tokens u32, nll_bits f64, wall_ms f64, first_token_wall_ms f64 | server → client |
//! | 0x13 | `Busy` | model u32, session u64 | server → client |
//! | 0x14 | `Bye` | (empty) | server → client |
//! | 0x15 | `StatsText` | UTF-8 metrics text | server → client |
//!
//! A client streams `Request` frames (one per chunk), then half-closes
//! its write side; the server streams back one `Token` frame per
//! executed position and one `Done` per finished chunk, and terminates
//! every connection with `Bye`.
//!
//! ## Live metrics
//!
//! A `Stats` frame (sub-kind 0) may arrive on any connection at any
//! time — including a dedicated polling connection that never submits
//! work — and is answered with one `StatsText` frame carrying a
//! Prometheus-style text snapshot of the live counters (per-model
//! tokens, completed requests, in-flight sessions; busy rejections,
//! connections, uptime). Unknown sub-kinds are a decode error, not a
//! silent default (`unknown_stats_subkind_is_rejected_not_defaulted`).
//! Stats polling stays answerable during drain; it never touches
//! admission.
//!
//! ## Backpressure
//!
//! Admission is bounded, never queued unboundedly: each model has a
//! budget of distinct in-flight sessions
//! ([`NetConfig::max_inflight_per_model`], default `workers ×
//! max_batch` — the pool's whole lane capacity). A `Request` that
//! would exceed the budget, reuse a session already in flight, name an
//! unregistered model, or arrive during drain is answered with an
//! explicit `Busy` frame and **not** enqueued; nothing is silently
//! dropped. Admitted requests are registered (route + in-flight count)
//! *before* they are submitted to the router, so no token event can
//! outrun its route and drain can never observe a half-admitted
//! session.
//!
//! ## Graceful drain
//!
//! On shutdown ([`NetShutdown::shutdown`] or
//! [`NetConfig::drain_after`]) the server stops admitting (`Busy` for
//! in-flight connections, immediate `Bye` for late connects), waits
//! for every in-flight session to finish, closes the router, joins the
//! workers, and closes every stream with a terminal `Bye`.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::ServingReport;
use super::router::ShardRouter;
use super::scheduler::StreamItem;
use super::server::{run_worker, CompletionAgg, Server, WorkerCfg, WorkerEvent};
use super::session::SessionKey;
use super::trace::{merge_events, EventKind, TraceConfig, TraceEvent, TraceLevel};

/// Hard cap on one frame's `len` field (kind byte + payload): a
/// defensive bound so a corrupt or hostile length prefix cannot ask
/// the server to allocate gigabytes.
pub const MAX_FRAME_LEN: u32 = 16 * 1024 * 1024;

const KIND_REQUEST: u8 = 0x01;
const KIND_STATS: u8 = 0x02;
const KIND_TOKEN: u8 = 0x11;
const KIND_DONE: u8 = 0x12;
const KIND_BUSY: u8 = 0x13;
const KIND_BYE: u8 = 0x14;
const KIND_STATS_TEXT: u8 = 0x15;

/// The only `Stats` sub-kind defined so far: a Prometheus text
/// snapshot. Any other sub-kind byte is a decode error.
const STATS_PROMETHEUS: u8 = 0;

/// One protocol frame (see the module docs for the wire layout).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server: one chunk of a stream.
    Request {
        /// Registry id of the model this stream runs on.
        model: u32,
        /// Client-chosen stream id (sticky state key together with
        /// `model`).
        session: u64,
        /// The chunk's token ids.
        tokens: Vec<u32>,
    },
    /// Server → client: one executed token position of a live stream.
    Token {
        /// Registry id of the stream's model.
        model: u32,
        /// The stream id.
        session: u64,
        /// Position within the chunk (0-based, contiguous).
        pos: u32,
        /// Deterministic argmax over the logits row at this position
        /// (first maximum wins) — the field the loopback tests compare
        /// bit-for-bit against the simulator's token tap.
        pred: u32,
    },
    /// Server → client: one finished chunk.
    Done {
        /// Registry id of the stream's model.
        model: u32,
        /// The stream id.
        session: u64,
        /// Tokens the chunk executed.
        tokens: u32,
        /// Total negative log-likelihood of the chunk, in bits.
        nll_bits: f64,
        /// Submission → completion wall-clock latency (ms).
        wall_ms: f64,
        /// Submission → first executed token wall-clock latency (ms).
        first_token_wall_ms: f64,
    },
    /// Server → client: the request was refused by backpressure (model
    /// budget exhausted, session already in flight, unknown model, or
    /// the server is draining). Nothing was enqueued; retry later.
    Busy {
        /// Registry id the refused request named.
        model: u32,
        /// The refused stream id.
        session: u64,
    },
    /// Client → server: poll the live metrics (sub-kind 0, the only
    /// one defined — a Prometheus text snapshot). Answered with one
    /// [`Frame::StatsText`]; never touches admission.
    Stats,
    /// Server → client: the answer to a [`Frame::Stats`] poll — a
    /// Prometheus-style text snapshot of the live serving counters.
    StatsText {
        /// The metrics exposition text (UTF-8).
        text: String,
    },
    /// Server → client: terminal frame; the server closes the
    /// connection after sending it.
    Bye,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn get_u32(buf: &[u8], at: usize) -> io::Result<u32> {
    let b: [u8; 4] = buf
        .get(at..at + 4)
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "short frame"))?
        .try_into()
        .unwrap();
    Ok(u32::from_le_bytes(b))
}

fn get_u64(buf: &[u8], at: usize) -> io::Result<u64> {
    let b: [u8; 8] = buf
        .get(at..at + 8)
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "short frame"))?
        .try_into()
        .unwrap();
    Ok(u64::from_le_bytes(b))
}

fn get_f64(buf: &[u8], at: usize) -> io::Result<f64> {
    Ok(f64::from_bits(get_u64(buf, at)?))
}

impl Frame {
    /// Encode the whole wire frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            Frame::Request { model, session, tokens } => {
                body.push(KIND_REQUEST);
                put_u32(&mut body, *model);
                put_u64(&mut body, *session);
                put_u32(&mut body, tokens.len() as u32);
                for &t in tokens {
                    put_u32(&mut body, t);
                }
            }
            Frame::Token { model, session, pos, pred } => {
                body.push(KIND_TOKEN);
                put_u32(&mut body, *model);
                put_u64(&mut body, *session);
                put_u32(&mut body, *pos);
                put_u32(&mut body, *pred);
            }
            Frame::Done { model, session, tokens, nll_bits, wall_ms, first_token_wall_ms } => {
                body.push(KIND_DONE);
                put_u32(&mut body, *model);
                put_u64(&mut body, *session);
                put_u32(&mut body, *tokens);
                put_f64(&mut body, *nll_bits);
                put_f64(&mut body, *wall_ms);
                put_f64(&mut body, *first_token_wall_ms);
            }
            Frame::Busy { model, session } => {
                body.push(KIND_BUSY);
                put_u32(&mut body, *model);
                put_u64(&mut body, *session);
            }
            Frame::Stats => {
                body.push(KIND_STATS);
                body.push(STATS_PROMETHEUS);
            }
            Frame::StatsText { text } => {
                body.push(KIND_STATS_TEXT);
                body.extend_from_slice(text.as_bytes());
            }
            Frame::Bye => body.push(KIND_BYE),
        }
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Decode one frame body (`kind` byte already split off).
    fn decode(kind: u8, p: &[u8]) -> io::Result<Frame> {
        match kind {
            KIND_REQUEST => {
                let model = get_u32(p, 0)?;
                let session = get_u64(p, 4)?;
                let n = get_u32(p, 12)? as usize;
                if p.len() != 16 + 4 * n {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "request frame length mismatch",
                    ));
                }
                let mut tokens = Vec::with_capacity(n);
                for i in 0..n {
                    tokens.push(get_u32(p, 16 + 4 * i)?);
                }
                Ok(Frame::Request { model, session, tokens })
            }
            KIND_TOKEN => Ok(Frame::Token {
                model: get_u32(p, 0)?,
                session: get_u64(p, 4)?,
                pos: get_u32(p, 12)?,
                pred: get_u32(p, 16)?,
            }),
            KIND_DONE => Ok(Frame::Done {
                model: get_u32(p, 0)?,
                session: get_u64(p, 4)?,
                tokens: get_u32(p, 12)?,
                nll_bits: get_f64(p, 16)?,
                wall_ms: get_f64(p, 24)?,
                first_token_wall_ms: get_f64(p, 32)?,
            }),
            KIND_BUSY => {
                Ok(Frame::Busy { model: get_u32(p, 0)?, session: get_u64(p, 4)? })
            }
            KIND_STATS => match p {
                [STATS_PROMETHEUS] => Ok(Frame::Stats),
                [other] => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("unknown stats sub-kind {other}"),
                )),
                _ => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "stats frame length mismatch",
                )),
            },
            KIND_STATS_TEXT => match String::from_utf8(p.to_vec()) {
                Ok(text) => Ok(Frame::StatsText { text }),
                Err(_) => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "stats text is not utf-8",
                )),
            },
            KIND_BYE => Ok(Frame::Bye),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unknown frame kind 0x{other:02x}"),
            )),
        }
    }
}

/// Write one frame to `w` (no flush policy beyond the write itself;
/// `TcpStream` writes are unbuffered).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    w.write_all(&frame.encode())
}

/// Read one frame from `r`, blocking. Returns `Ok(None)` on a clean
/// EOF at a frame boundary; EOF inside a frame is an error.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Frame>> {
    let mut len_buf = [0u8; 4];
    match r.read(&mut len_buf[..1]) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => return Err(e),
    }
    r.read_exact(&mut len_buf[1..])?;
    let len = u32::from_le_bytes(len_buf);
    read_body(r, len)
}

fn read_body(r: &mut impl Read, len: u32) -> io::Result<Option<Frame>> {
    if len == 0 || len > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} out of range"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    Frame::decode(body[0], &body[1..]).map(Some)
}

/// Read one frame from a stream whose read timeout is set, surviving
/// timeouts mid-frame: a `WouldBlock`/`TimedOut` polls `closing` and
/// resumes the partial read, so timeout polling can never tear a
/// frame. Returns `Ok(None)` on clean EOF **or** when `closing` was
/// raised.
fn read_frame_interruptible(
    stream: &mut TcpStream,
    closing: &AtomicBool,
) -> io::Result<Option<Frame>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match stream.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside frame header",
                ));
            }
            Ok(n) => got += n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if closing.load(Ordering::Relaxed) {
                    return Ok(None);
                }
            }
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} out of range"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    let mut got = 0usize;
    while got < body.len() {
        match stream.read(&mut body[got..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside frame body",
                ))
            }
            Ok(n) => got += n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if closing.load(Ordering::Relaxed) {
                    return Ok(None);
                }
            }
            Err(e) => return Err(e),
        }
    }
    Frame::decode(body[0], &body[1..]).map(Some)
}

/// Network front configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Address to bind (`"127.0.0.1:0"` by default — loopback, OS-
    /// assigned port; read the bound port back with
    /// [`NetServer::local_addr`]).
    pub listen: String,
    /// Per-model cap on distinct in-flight sessions; a `Request`
    /// beyond it gets [`Frame::Busy`]. `None` defaults to `workers ×
    /// max_batch` — the pool's whole lane capacity, so admitted work
    /// never queues more than one wave deep per worker.
    pub max_inflight_per_model: Option<usize>,
    /// Begin graceful drain after this long, even without a
    /// [`NetShutdown::shutdown`] call (`None` = serve until told).
    pub drain_after: Option<Duration>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            listen: "127.0.0.1:0".into(),
            max_inflight_per_model: None,
            drain_after: None,
        }
    }
}

/// Shutdown handle for [`NetServer::serve`]: cloneable, raisable from
/// any thread (a ctrl-c handler, a test, a timer).
#[derive(Debug, Clone, Default)]
pub struct NetShutdown(Arc<AtomicBool>);

impl NetShutdown {
    /// A fresh, un-raised handle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request graceful drain.
    pub fn shutdown(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// Whether drain has been requested.
    pub fn is_shutdown(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// What one serving run of the network front reports.
#[derive(Debug)]
pub struct NetReport {
    /// The pool's serving report — same shape and clocks as trace
    /// replay, assembled from the same worker summaries.
    pub serving: ServingReport,
    /// Connections accepted and served.
    pub connections: usize,
    /// Late connects answered with an immediate [`Frame::Bye`] during
    /// drain.
    pub refused_connects: usize,
    /// Requests answered with [`Frame::Busy`] by backpressure.
    pub busy_rejections: usize,
}

/// Per-session route: which connection's writer gets this stream's
/// frames.
struct RouteEntry {
    tx: Sender<Frame>,
}

/// Everything the reader threads and the dispatcher share, under one
/// lock.
struct NetState {
    /// `(model, session)` → the owning connection's writer. Present
    /// exactly while the session is in flight (registered before
    /// submit, removed at `Done`).
    routes: HashMap<SessionKey, RouteEntry>,
    /// Distinct in-flight sessions per model (indexed by `ModelId`).
    inflight: Vec<usize>,
    /// Raised at drain start: no further admissions.
    draining: bool,
    /// Requests refused with `Busy`.
    busy_rejections: usize,
    /// Executed token positions per model (dispatcher-updated at each
    /// `Token` event) — the `iqrnn_tokens_total` counter.
    tokens_by_model: Vec<usize>,
    /// Completed requests per model (dispatcher-updated at `Done`).
    requests_by_model: Vec<usize>,
    /// Connections accepted and served so far.
    connections: usize,
    /// `Busy` lifecycle events recorded at trace level `full`, bounded
    /// by the trace ring capacity. The front has no virtual step, so
    /// these carry `step == 0` and `worker == u32::MAX` and are merged
    /// into the report's event log after the pool drains.
    busy_events: Vec<TraceEvent>,
}

/// Render the Prometheus-style text snapshot a [`Frame::Stats`] poll
/// is answered with. Counters are monotone within one serve run;
/// gauges are instantaneous.
fn prometheus_text(st: &NetState, names: &[String], uptime_secs: f64) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    out.push_str("# TYPE iqrnn_tokens_total counter\n");
    for (m, name) in names.iter().enumerate() {
        let _ = writeln!(out, "iqrnn_tokens_total{{model=\"{name}\"}} {}", st.tokens_by_model[m]);
    }
    out.push_str("# TYPE iqrnn_requests_completed_total counter\n");
    for (m, name) in names.iter().enumerate() {
        let _ = writeln!(
            out,
            "iqrnn_requests_completed_total{{model=\"{name}\"}} {}",
            st.requests_by_model[m]
        );
    }
    out.push_str("# TYPE iqrnn_inflight_sessions gauge\n");
    for (m, name) in names.iter().enumerate() {
        let _ = writeln!(out, "iqrnn_inflight_sessions{{model=\"{name}\"}} {}", st.inflight[m]);
    }
    out.push_str("# TYPE iqrnn_busy_rejections_total counter\n");
    let _ = writeln!(out, "iqrnn_busy_rejections_total {}", st.busy_rejections);
    out.push_str("# TYPE iqrnn_connections_total counter\n");
    let _ = writeln!(out, "iqrnn_connections_total {}", st.connections);
    out.push_str("# TYPE iqrnn_uptime_seconds gauge\n");
    let _ = writeln!(out, "iqrnn_uptime_seconds {uptime_secs:.3}");
    out
}

/// The TCP front bound to a [`Server`]'s pool.
pub struct NetServer<'s, 'a> {
    server: &'s Server<'a>,
    cfg: NetConfig,
    listener: TcpListener,
}

impl<'s, 'a> NetServer<'s, 'a> {
    /// Bind the listener (no serving yet).
    pub fn bind(server: &'s Server<'a>, cfg: NetConfig) -> Result<Self> {
        let listener = TcpListener::bind(&cfg.listen)?;
        Ok(NetServer { server, cfg, listener })
    }

    /// The bound address (with the OS-assigned port resolved).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until `stop` is raised (or [`NetConfig::drain_after`]
    /// elapses), then drain gracefully and return the report. Blocks
    /// the calling thread; workers, per-connection readers/writers,
    /// and the dispatcher run on scoped threads.
    pub fn serve(&self, stop: &NetShutdown) -> Result<NetReport> {
        let server = self.server;
        let workers = server.config.workers;
        let n_models = server.registry().len();
        let residency = server.registry().residency(workers);
        let router =
            ShardRouter::with_residency(workers, server.config.steal, residency.clone());
        let budget = self
            .cfg
            .max_inflight_per_model
            .unwrap_or(workers * server.config.batch.max_batch)
            .max(1);
        let state = Mutex::new(NetState {
            routes: HashMap::new(),
            inflight: vec![0; n_models],
            draining: false,
            busy_rejections: 0,
            tokens_by_model: vec![0; n_models],
            requests_by_model: vec![0; n_models],
            connections: 0,
            busy_events: Vec::new(),
        });
        let model_names = server.registry().names();
        // Raised after the pool has fully drained: readers on still-
        // open connections exit, which lets their writers send `Bye`.
        let closing = AtomicBool::new(false);
        let (ev_tx, ev_rx) = channel::<WorkerEvent>();
        let wcfg = WorkerCfg {
            max_lanes: server.config.batch.max_batch,
            mode: server.config.mode,
            session_budget: server.config.session_budget,
            evict_idle_after: server.config.evict_idle_after,
            state_budget: server.config.state_budget,
            spill_quantized: server.config.spill_quantized,
            // The token tap is what the front streams to clients.
            record_tokens: true,
            trace: server.config.trace,
        };
        self.listener.set_nonblocking(true)?;

        let wall_start = Instant::now();
        let mut connections = 0usize;
        let mut refused_connects = 0usize;
        let (summaries, agg) = std::thread::scope(|scope| -> Result<_> {
            let router = &router;
            let state = &state;
            let closing = &closing;
            let registry = server.registry();
            let wcfg = &wcfg;
            let model_names = &model_names;
            let mut worker_handles = Vec::new();
            for w in 0..workers {
                let events = ev_tx.clone();
                worker_handles.push(scope.spawn(move || {
                    run_worker(registry, router, w, workers, wcfg, &events)
                }));
            }
            drop(ev_tx);

            // Dispatcher: the single consumer of worker events. Routes
            // token/done frames to the owning connection's writer and
            // aggregates wall-clock completion latencies. Exits when
            // every worker has exited (channel disconnects).
            let dispatcher = scope.spawn(move || {
                let mut agg = CompletionAgg::new();
                for ev in ev_rx.iter() {
                    match ev {
                        WorkerEvent::Token(t) => {
                            let mut st = state.lock().expect("net state lock");
                            st.tokens_by_model[t.model as usize] += 1;
                            if let Some(route) = st.routes.get(&(t.model, t.session)) {
                                let _ = route.tx.send(Frame::Token {
                                    model: t.model,
                                    session: t.session,
                                    pos: t.pos as u32,
                                    pred: t.pred as u32,
                                });
                            }
                        }
                        WorkerEvent::Done(d) => {
                            agg.record(&d);
                            let mut st = state.lock().expect("net state lock");
                            st.requests_by_model[d.model as usize] += 1;
                            if let Some(route) =
                                st.routes.remove(&(d.model, d.session))
                            {
                                st.inflight[d.model as usize] -= 1;
                                let _ = route.tx.send(Frame::Done {
                                    model: d.model,
                                    session: d.session,
                                    tokens: d.tokens as u32,
                                    nll_bits: d.nll_bits,
                                    wall_ms: d.wall_ms,
                                    first_token_wall_ms: d.first_token_wall_ms,
                                });
                            }
                        }
                    }
                }
                agg
            });

            // Accept loop: non-blocking accept + 1 ms sleep poll, until
            // shutdown is requested. A fatal accept error must NOT
            // return here — the workers are parked on the router and
            // the scope would block forever joining them; it breaks
            // into the normal drain instead and surfaces after
            // teardown.
            let deadline = self.cfg.drain_after.map(|d| wall_start + d);
            let mut accept_err: Option<io::Error> = None;
            loop {
                if stop.is_shutdown()
                    || deadline.map_or(false, |t| Instant::now() >= t)
                {
                    break;
                }
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        connections += 1;
                        state.lock().expect("net state lock").connections += 1;
                        spawn_connection(
                            scope, stream, router, state, closing, n_models, budget,
                            model_names, wall_start, server.config.trace,
                        );
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(e) => {
                        accept_err = Some(e);
                        break;
                    }
                }
            }

            // Graceful drain: stop admitting, answer late connects with
            // an immediate Bye, and wait for every in-flight session to
            // finish. Admission increments `inflight` before the state
            // lock drops and submits after, so `inflight == 0` here
            // really means no admitted work remains anywhere — closing
            // the router below can never race a submit.
            state.lock().expect("net state lock").draining = true;
            loop {
                let idle = {
                    let st = state.lock().expect("net state lock");
                    st.inflight.iter().sum::<usize>() == 0
                };
                if idle {
                    break;
                }
                if let Ok((mut s, _peer)) = self.listener.accept() {
                    refused_connects += 1;
                    let _ = write_frame(&mut s, &Frame::Bye);
                    let _ = s.shutdown(Shutdown::Both);
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            router.close();
            let summaries: Vec<_> = worker_handles
                .into_iter()
                .map(|h| h.join().expect("worker panicked"))
                .collect();
            // Workers gone → all event senders dropped → dispatcher
            // drains the channel and exits.
            let agg = dispatcher.join().expect("dispatcher panicked");
            // Tell the per-connection readers to wind down; their
            // writers then emit the terminal Bye and close the socket.
            closing.store(true, Ordering::Relaxed);
            if let Some(e) = accept_err {
                return Err(e.into());
            }
            Ok((summaries, agg))
        })?;
        let wall_secs = wall_start.elapsed().as_secs_f64();

        let (busy_rejections, busy_events) = {
            let mut st = state.lock().expect("net state lock");
            (st.busy_rejections, std::mem::take(&mut st.busy_events))
        };
        let mut serving =
            server.assemble_report(&summaries, &router, &residency, wall_secs, agg);
        if !busy_events.is_empty() {
            // Fold the front's Busy events (worker `u32::MAX`, step 0)
            // into the workers' merged event log.
            let worker_events = std::mem::take(&mut serving.trace_events);
            serving.trace_events = merge_events(vec![worker_events, busy_events]);
        }
        Ok(NetReport { serving, connections, refused_connects, busy_rejections })
    }
}

/// Spawn the reader + writer pair for one accepted connection.
#[allow(clippy::too_many_arguments)]
fn spawn_connection<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    stream: TcpStream,
    router: &'scope ShardRouter,
    state: &'scope Mutex<NetState>,
    closing: &'scope AtomicBool,
    n_models: usize,
    budget: usize,
    model_names: &'scope [String],
    wall_start: Instant,
    trace: TraceConfig,
) {
    let (tx, rx) = channel::<Frame>();
    let write_half = stream.try_clone();

    // Writer: drains the connection's frame queue; when every sender
    // is gone (reader exited and all of the connection's sessions
    // completed), sends the terminal Bye and closes the socket — which
    // also unblocks a reader still parked in a blocking read.
    if let Ok(mut ws) = write_half {
        scope.spawn(move || {
            for frame in rx.iter() {
                if write_frame(&mut ws, &frame).is_err() {
                    break; // client went away; drain silently
                }
            }
            let _ = write_frame(&mut ws, &Frame::Bye);
            let _ = ws.shutdown(Shutdown::Both);
        });
    }

    // Reader: parses Request frames and runs admission. A short read
    // timeout keeps it responsive to `closing` without tearing frames
    // (the interruptible reader resumes partial reads across
    // timeouts).
    scope.spawn(move || {
        let mut stream = stream;
        let _ = stream.set_read_timeout(Some(Duration::from_millis(25)));
        loop {
            match read_frame_interruptible(&mut stream, closing) {
                Ok(Some(Frame::Request { model, session, tokens })) => {
                    let admitted = {
                        let mut st = state.lock().expect("net state lock");
                        let key: SessionKey = (model, session);
                        let ok = !st.draining
                            && (model as usize) < n_models
                            && !st.routes.contains_key(&key)
                            && st.inflight[model as usize] < budget;
                        if ok {
                            // Route + count registered before the lock
                            // drops and before submit: the dispatcher
                            // can immediately route this stream's
                            // tokens, and drain sees the session the
                            // instant it is admitted.
                            st.routes
                                .insert(key, RouteEntry { tx: tx.clone() });
                            st.inflight[model as usize] += 1;
                        } else {
                            st.busy_rejections += 1;
                            if trace.level >= TraceLevel::Full
                                && st.busy_events.len() < trace.capacity
                            {
                                st.busy_events.push(TraceEvent {
                                    step: 0,
                                    wall_us: wall_start.elapsed().as_micros() as u64,
                                    dur_us: 0,
                                    worker: u32::MAX,
                                    model,
                                    session,
                                    arg: 0,
                                    kind: EventKind::Busy,
                                });
                            }
                        }
                        ok
                    };
                    if admitted {
                        router.submit(StreamItem {
                            model,
                            session,
                            tokens: tokens.into_iter().map(|t| t as usize).collect(),
                            submitted: Instant::now(),
                        });
                    } else {
                        let _ = tx.send(Frame::Busy { model, session });
                    }
                }
                Ok(Some(Frame::Stats)) => {
                    // Metrics poll: snapshot under the state lock,
                    // answer through the connection's writer. Stays
                    // answerable during drain.
                    let text = {
                        let st = state.lock().expect("net state lock");
                        prometheus_text(
                            &st,
                            model_names,
                            wall_start.elapsed().as_secs_f64(),
                        )
                    };
                    let _ = tx.send(Frame::StatsText { text });
                }
                // A client sending server-side frames is a protocol
                // violation; clean EOF and raised `closing` both end
                // the read loop normally.
                Ok(Some(_)) | Ok(None) | Err(_) => break,
            }
        }
        // Dropping `tx` lets the writer finish once the connection's
        // in-flight sessions (which hold their own clones) complete.
        drop(tx);
    });
}

/// A minimal blocking client for the frame protocol — what the
/// loopback tests, the e2e example, and the bench sweep drive.
pub struct NetClient {
    stream: TcpStream,
}

impl NetClient {
    /// Connect to a listening [`NetServer`].
    pub fn connect(addr: SocketAddr) -> io::Result<Self> {
        Ok(NetClient { stream: TcpStream::connect(addr)? })
    }

    /// Send one `Request` chunk.
    pub fn send(&mut self, model: u32, session: u64, tokens: &[usize]) -> io::Result<()> {
        let frame = Frame::Request {
            model,
            session,
            tokens: tokens.iter().map(|&t| t as u32).collect(),
        };
        write_frame(&mut self.stream, &frame)
    }

    /// Half-close the write side: no more requests, keep reading the
    /// response stream until `Bye`/EOF.
    pub fn finish(&mut self) -> io::Result<()> {
        self.stream.shutdown(Shutdown::Write)
    }

    /// Read the next server frame (`None` on EOF).
    pub fn read_frame(&mut self) -> io::Result<Option<Frame>> {
        read_frame(&mut self.stream)
    }

    /// Poll the server's live metrics: send one [`Frame::Stats`] and
    /// block for the [`Frame::StatsText`] answer. Other frames
    /// arriving on this connection in the meantime (tokens of live
    /// streams) are skipped, so prefer a dedicated polling connection
    /// when the full stream matters.
    pub fn stats(&mut self) -> io::Result<String> {
        write_frame(&mut self.stream, &Frame::Stats)?;
        while let Some(f) = self.read_frame()? {
            if let Frame::StatsText { text } = f {
                return Ok(text);
            }
        }
        Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "connection closed before StatsText",
        ))
    }

    /// Read frames until `Bye` or EOF, returning everything before the
    /// terminal frame.
    pub fn read_to_bye(&mut self) -> io::Result<Vec<Frame>> {
        let mut out = Vec::new();
        while let Some(f) = self.read_frame()? {
            if f == Frame::Bye {
                break;
            }
            out.push(f);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_through_encode_decode() {
        let frames = vec![
            Frame::Request { model: 3, session: 0xDEAD_BEEF_u64, tokens: vec![0, 7, 41] },
            Frame::Request { model: 0, session: 1, tokens: vec![] },
            Frame::Token { model: 1, session: 9, pos: 4, pred: 17 },
            Frame::Done {
                model: 2,
                session: 5,
                tokens: 12,
                nll_bits: 34.5,
                wall_ms: 1.25,
                first_token_wall_ms: 0.5,
            },
            Frame::Busy { model: 1, session: 2 },
            Frame::Stats,
            Frame::StatsText { text: "iqrnn_connections_total 1\n".into() },
            Frame::Bye,
        ];
        for f in &frames {
            let wire = f.encode();
            let mut cursor = io::Cursor::new(&wire);
            let back = read_frame(&mut cursor).unwrap().expect("frame");
            assert_eq!(&back, f, "round trip changed the frame");
            // And the stream position consumed exactly one frame.
            assert_eq!(cursor.position() as usize, wire.len());
        }
        // Frames survive concatenation on one stream.
        let wire: Vec<u8> = frames.iter().flat_map(|f| f.encode()).collect();
        let mut cursor = io::Cursor::new(&wire);
        for f in &frames {
            assert_eq!(read_frame(&mut cursor).unwrap().as_ref(), Some(f));
        }
        assert!(read_frame(&mut cursor).unwrap().is_none(), "clean EOF after frames");
    }

    #[test]
    fn oversized_and_zero_length_frames_are_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        wire.push(KIND_BYE);
        assert!(read_frame(&mut io::Cursor::new(&wire)).is_err());
        let wire = 0u32.to_le_bytes().to_vec();
        assert!(read_frame(&mut io::Cursor::new(&wire)).is_err());
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let wire = Frame::Busy { model: 1, session: 2 }.encode();
        let cut = &wire[..wire.len() - 3];
        assert!(read_frame(&mut io::Cursor::new(cut)).is_err());
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&1u32.to_le_bytes());
        wire.push(0x7F);
        assert!(read_frame(&mut io::Cursor::new(&wire)).is_err());
    }

    #[test]
    fn unknown_stats_subkind_is_rejected_not_defaulted() {
        // A sub-kind the server does not know must be a decode error,
        // never silently treated as "Prometheus".
        let mut wire = Vec::new();
        wire.extend_from_slice(&2u32.to_le_bytes());
        wire.push(KIND_STATS);
        wire.push(9);
        let err = read_frame(&mut io::Cursor::new(&wire)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("sub-kind"), "got: {err}");
        // A stats frame with no sub-kind byte at all is also an error.
        let mut wire = Vec::new();
        wire.extend_from_slice(&1u32.to_le_bytes());
        wire.push(KIND_STATS);
        assert!(read_frame(&mut io::Cursor::new(&wire)).is_err());
    }
}
