//! Session-state hibernation: the compact cold tier idle streams spill
//! into when a worker's resident-state **byte** budget is exceeded.
//!
//! LSTM serving state is tiny and self-contained — h/c per layer plus
//! the last hidden/logits scratch, no KV-cache growth — so a hibernated
//! stream is just a few hundred bytes. The scheduler spills
//! coldest-first (by the session table's logical activity clock) and
//! restores transparently before lane admission, so the lane machinery
//! never sees a hibernated session.
//!
//! Two codecs ([`SpillCodec`]):
//!
//! * **Exact** (default) — a little-endian byte image of the state.
//!   `f32::to_le_bytes`/`from_le_bytes` round-trip every bit pattern,
//!   so spill → restore is bit-exact by construction and a
//!   spilled-and-restored stream produces the identical token stream
//!   to one that never spilled (pinned across all three engines by
//!   `rust/tests/hibernation.rs`).
//! * **Int8** (`--spill-quantized`) — every f32 vector stored as int8
//!   with one per-vector symmetric scale, the paper's affine
//!   activation scheme applied to hibernated h/c. Roughly 4× smaller
//!   and *lossy* for float vectors; the loss is measured honestly
//!   (per-vector error bounds and a bits/char delta in
//!   `rust/tests/numerics_edge.rs`), never silent. Integer-engine
//!   layer states are already ≤16-bit and stay exact, so the integer
//!   engine remains bit-exact even under this codec.

use std::collections::HashMap;

use crate::lstm::{FloatState, IntegerState, LayerState, StackEngine};
use crate::model::lm::{CharLmEngine, LmState, VOCAB};
use super::registry::ModelId;
use super::session::{Session, SessionKey};

/// How hibernated state is encoded in the cold tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillCodec {
    /// Exact little-endian byte image — restore is bit-exact.
    Exact,
    /// Per-vector symmetric int8 for every f32 vector (lossy for the
    /// float/hybrid engines, exact for integer layer states).
    Int8,
}

impl SpillCodec {
    /// Human-readable codec name for reports.
    pub fn label(&self) -> &'static str {
        match self {
            SpillCodec::Exact => "exact",
            SpillCodec::Int8 => "int8",
        }
    }
}

/// Per-vector symmetric int8 quantization: `scale = max|v| / 127`,
/// `q = round(v / scale)` clamped to ±127 (an all-zero vector gets
/// scale 0 and quantizes exactly). The worst-case per-element
/// reconstruction error is `scale / 2` plus f32 rounding — the bound
/// `numerics_edge.rs` pins on adversarial h/c vectors.
pub fn quantize_vec_i8(v: &[f32]) -> (f32, Vec<i8>) {
    let max_abs = v.iter().fold(0f32, |m, &x| m.max(x.abs()));
    if max_abs == 0.0 {
        return (0.0, vec![0; v.len()]);
    }
    let scale = max_abs / 127.0;
    let q = v
        .iter()
        .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (scale, q)
}

/// Inverse of [`quantize_vec_i8`]: `v ≈ q * scale`.
pub fn dequantize_vec_i8(scale: f32, q: &[i8]) -> Vec<f32> {
    q.iter().map(|&x| f32::from(x) * scale).collect()
}

fn push_f32s(out: &mut Vec<u8>, v: &[f32]) {
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(bytes: &[u8], off: &mut usize, n: usize) -> Vec<f32> {
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(f32::from_le_bytes([
            bytes[*off],
            bytes[*off + 1],
            bytes[*off + 2],
            bytes[*off + 3],
        ]));
        *off += 4;
    }
    v
}

fn push_quantized(out: &mut Vec<u8>, v: &[f32]) {
    let (scale, q) = quantize_vec_i8(v);
    out.extend_from_slice(&scale.to_le_bytes());
    for x in q {
        out.push(x as u8);
    }
}

fn read_quantized(bytes: &[u8], off: &mut usize, n: usize) -> Vec<f32> {
    let scale = f32::from_le_bytes([
        bytes[*off],
        bytes[*off + 1],
        bytes[*off + 2],
        bytes[*off + 3],
    ]);
    *off += 4;
    let q: Vec<i8> = bytes[*off..*off + n].iter().map(|&b| b as i8).collect();
    *off += n;
    dequantize_vec_i8(scale, &q)
}

fn push_integer_layer(out: &mut Vec<u8>, st: &IntegerState) {
    for v in &st.c {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for v in &st.h {
        out.push(*v as u8);
    }
}

fn read_integer_layer(bytes: &[u8], off: &mut usize, n_cell: usize, n_output: usize) -> IntegerState {
    let mut c = Vec::with_capacity(n_cell);
    for _ in 0..n_cell {
        c.push(i16::from_le_bytes([bytes[*off], bytes[*off + 1]]));
        *off += 2;
    }
    let mut h = Vec::with_capacity(n_output);
    for _ in 0..n_output {
        h.push(bytes[*off] as i8);
        *off += 1;
    }
    IntegerState { c, h }
}

/// Serialize a session's full [`LmState`] under `codec`. The exact
/// codec delegates the recurrent layers to
/// [`crate::lstm::LstmStack::export_lane`] and appends the
/// hidden/logits scratch as raw f32 bytes; the int8 codec stores every
/// f32 vector as `[scale f32][int8 …]` and integer layer states
/// verbatim.
pub fn encode_state(engine: &CharLmEngine, state: &LmState, codec: SpillCodec) -> Vec<u8> {
    let mut out = Vec::new();
    match codec {
        SpillCodec::Exact => {
            engine.stack.export_lane(&state.layers, &mut out);
            push_f32s(&mut out, &state.h);
            push_f32s(&mut out, &state.logits);
        }
        SpillCodec::Int8 => {
            for st in &state.layers {
                match st {
                    LayerState::Float(st) => {
                        push_quantized(&mut out, &st.c);
                        push_quantized(&mut out, &st.h);
                    }
                    LayerState::Integer(st) => push_integer_layer(&mut out, st),
                }
            }
            push_quantized(&mut out, &state.h);
            push_quantized(&mut out, &state.logits);
        }
    }
    out
}

/// Rebuild an [`LmState`] from bytes produced by [`encode_state`] with
/// the same engine and codec. Exact-codec bytes reproduce the original
/// state bit for bit.
pub fn decode_state(engine: &CharLmEngine, bytes: &[u8], codec: SpillCodec) -> LmState {
    let n_output = engine.stack.n_output();
    match codec {
        SpillCodec::Exact => {
            let sb = engine.stack.state_bytes();
            let layers = engine.stack.import_lane(&bytes[..sb]);
            let mut off = sb;
            let h = read_f32s(bytes, &mut off, n_output);
            let logits = read_f32s(bytes, &mut off, VOCAB);
            assert_eq!(off, bytes.len(), "trailing hibernated bytes");
            LmState { layers, h, logits }
        }
        SpillCodec::Int8 => {
            let integer = engine.stack.engine() == StackEngine::Integer;
            let mut off = 0usize;
            let mut layers = Vec::with_capacity(engine.stack.depth());
            for spec in engine.stack.specs() {
                if integer {
                    layers.push(LayerState::Integer(read_integer_layer(
                        bytes,
                        &mut off,
                        spec.n_cell,
                        spec.n_output,
                    )));
                } else {
                    let c = read_quantized(bytes, &mut off, spec.n_cell);
                    let h = read_quantized(bytes, &mut off, spec.n_output);
                    layers.push(LayerState::Float(FloatState { c, h }));
                }
            }
            let h = read_quantized(bytes, &mut off, n_output);
            let logits = read_quantized(bytes, &mut off, VOCAB);
            assert_eq!(off, bytes.len(), "trailing hibernated bytes");
            LmState { layers, h, logits }
        }
    }
}

/// One hibernated stream: its encoded state plus the scalar session
/// metadata, which always survives exactly (only the state vectors are
/// subject to the codec).
struct HibernatedSession {
    bytes: Vec<u8>,
    tokens_seen: usize,
    nll_bits: f64,
    last_active: u64,
}

/// One worker's cold tier: hibernated sessions keyed like the hot
/// session table, with byte accounting and spill/restore counters.
pub struct ColdTier {
    store: HashMap<SessionKey, HibernatedSession>,
    codec: SpillCodec,
    bytes: usize,
    spills: u64,
    restores: u64,
}

impl ColdTier {
    /// An empty cold tier using `codec` for every spill.
    pub fn new(codec: SpillCodec) -> Self {
        ColdTier {
            store: HashMap::new(),
            codec,
            bytes: 0,
            spills: 0,
            restores: 0,
        }
    }

    /// The codec this tier encodes with.
    pub fn codec(&self) -> SpillCodec {
        self.codec
    }

    /// Hibernate one session: encode its state and take ownership. The
    /// caller must have removed it from the hot table (via
    /// `SessionManager::take`) first. Returns the encoded byte size
    /// (what the tier now holds for this session — the `arg` of the
    /// trace subsystem's `Spill` events).
    pub fn spill(&mut self, engine: &CharLmEngine, session: Session) -> usize {
        let key = session.key();
        debug_assert!(!self.store.contains_key(&key), "double spill of {key:?}");
        let bytes = encode_state(engine, &session.state, self.codec);
        let n = bytes.len();
        self.bytes += n;
        self.spills += 1;
        self.store.insert(
            key,
            HibernatedSession {
                bytes,
                tokens_seen: session.tokens_seen,
                nll_bits: session.nll_bits,
                last_active: session.last_active,
            },
        );
        n
    }

    /// Wake one session: decode its state and remove it from the tier.
    /// Returns `None` when the key is not hibernated (the common case —
    /// most arriving chunks belong to hot sessions).
    pub fn restore(&mut self, key: SessionKey, engine: &CharLmEngine) -> Option<Session> {
        let h = self.store.remove(&key)?;
        self.bytes -= h.bytes.len();
        self.restores += 1;
        Some(Session {
            model: key.0,
            id: key.1,
            state: decode_state(engine, &h.bytes, self.codec),
            tokens_seen: h.tokens_seen,
            nll_bits: h.nll_bits,
            last_active: h.last_active,
        })
    }

    /// True when `key` is hibernated here.
    pub fn contains(&self, key: SessionKey) -> bool {
        self.store.contains_key(&key)
    }

    /// Hibernated session count.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// True when nothing is hibernated.
    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Total encoded bytes held in the tier.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Hibernated session count for one model.
    pub fn len_model(&self, model: ModelId) -> usize {
        self.store.keys().filter(|(m, _)| *m == model).count()
    }

    /// Encoded bytes held for one model.
    pub fn bytes_model(&self, model: ModelId) -> usize {
        self.store
            .iter()
            .filter(|((m, _), _)| *m == model)
            .map(|(_, h)| h.bytes.len())
            .sum()
    }

    /// All hibernated keys, sorted — deterministic drain order for
    /// `restore_all`-style sweeps.
    pub fn keys(&self) -> Vec<SessionKey> {
        let mut keys: Vec<SessionKey> = self.store.keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Spill events since construction.
    pub fn spills(&self) -> u64 {
        self.spills
    }

    /// Restore events since construction.
    pub fn restores(&self) -> u64 {
        self.restores
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, QuantizeOptions, StackWeights};
    use crate::model::lm::CharLm;
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm(depth: usize) -> CharLm {
        let mut rng = Pcg32::seeded(71);
        let spec = LstmSpec::plain(VOCAB, 12);
        let stack_weights = StackWeights::random(VOCAB, spec, depth, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 12);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm {
            stack_weights,
            out_w,
            out_b: vec![0.0; VOCAB],
            hidden: 12,
            depth,
        }
    }

    fn calib(lm: &CharLm) -> Vec<crate::lstm::CalibrationStats> {
        let mut rng = Pcg32::seeded(72);
        let seqs: Vec<Vec<usize>> = (0..4)
            .map(|_| (0..24).map(|_| rng.below(VOCAB as u32) as usize).collect())
            .collect();
        lm.calibrate(&seqs)
    }

    fn assert_states_bit_eq(a: &LmState, b: &LmState) {
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            match (la, lb) {
                (LayerState::Float(x), LayerState::Float(y)) => {
                    for (u, v) in x.c.iter().zip(&y.c) {
                        assert_eq!(u.to_bits(), v.to_bits());
                    }
                    for (u, v) in x.h.iter().zip(&y.h) {
                        assert_eq!(u.to_bits(), v.to_bits());
                    }
                }
                (LayerState::Integer(x), LayerState::Integer(y)) => {
                    assert_eq!(x.c, y.c);
                    assert_eq!(x.h, y.h);
                }
                _ => panic!("layer variant mismatch"),
            }
        }
        for (u, v) in a.h.iter().zip(&b.h) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        for (u, v) in a.logits.iter().zip(&b.logits) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn exact_codec_roundtrips_bit_exact_across_engines() {
        let lm = tiny_lm(2);
        let stats = calib(&lm);
        for kind in StackEngine::ALL {
            let engine = lm.engine(kind, Some(&stats), QuantizeOptions::default());
            let mut state = engine.new_state();
            for t in [3usize, 40, 7, 90, 1] {
                engine.step_token(t, &mut state);
            }
            let bytes = encode_state(&engine, &state, SpillCodec::Exact);
            assert_eq!(bytes.len(), engine.state_bytes(), "{}", kind.label());
            let restored = decode_state(&engine, &bytes, SpillCodec::Exact);
            assert_states_bit_eq(&state, &restored);
        }
    }

    #[test]
    fn int8_codec_is_exact_for_integer_engine_layers() {
        let lm = tiny_lm(2);
        let stats = calib(&lm);
        let engine = lm.engine(StackEngine::Integer, Some(&stats), QuantizeOptions::default());
        let mut state = engine.new_state();
        for t in [5usize, 61, 13, 88] {
            engine.step_token(t, &mut state);
        }
        let bytes = encode_state(&engine, &state, SpillCodec::Int8);
        let restored = decode_state(&engine, &bytes, SpillCodec::Int8);
        // The recurrent layers are already integer and survive exactly
        // — future steps are bit-identical even under the lossy codec.
        for (la, lb) in state.layers.iter().zip(&restored.layers) {
            match (la, lb) {
                (LayerState::Integer(x), LayerState::Integer(y)) => {
                    assert_eq!(x.c, y.c);
                    assert_eq!(x.h, y.h);
                }
                _ => panic!("expected integer layers"),
            }
        }
        // And the int8 image is smaller than the exact one.
        assert!(bytes.len() < encode_state(&engine, &state, SpillCodec::Exact).len());
    }

    #[test]
    fn cold_tier_accounts_bytes_and_counters() {
        let lm = tiny_lm(1);
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut tier = ColdTier::new(SpillCodec::Exact);
        assert!(tier.is_empty());
        for id in 0..3u64 {
            let mut s = Session::new(0, id, &engine);
            engine.step_token(id as usize + 1, &mut s.state);
            s.tokens_seen = 1;
            tier.spill(&engine, s);
        }
        assert_eq!(tier.len(), 3);
        assert_eq!(tier.len_model(0), 3);
        assert_eq!(tier.bytes(), 3 * engine.state_bytes());
        assert_eq!(tier.bytes_model(0), tier.bytes());
        assert_eq!(tier.spills(), 3);
        assert_eq!(tier.keys(), vec![(0, 0), (0, 1), (0, 2)]);
        assert!(tier.contains((0, 1)));
        let s = tier.restore((0, 1), &engine).expect("hibernated");
        assert_eq!(s.tokens_seen, 1);
        assert_eq!(tier.restores(), 1);
        assert_eq!(tier.len(), 2);
        assert_eq!(tier.bytes(), 2 * engine.state_bytes());
        assert!(tier.restore((0, 1), &engine).is_none());
        assert_eq!(tier.restores(), 1, "missed restore does not count");
    }

    #[test]
    fn quantized_roundtrip_error_is_bounded() {
        let mut rng = Pcg32::seeded(9);
        let mut v = vec![0f32; 64];
        rng.fill_uniform_f32(&mut v, -0.9, 0.9);
        let (scale, q) = quantize_vec_i8(&v);
        let back = dequantize_vec_i8(scale, &q);
        for (a, b) in v.iter().zip(&back) {
            assert!((a - b).abs() <= 0.5 * scale + 1e-6, "{a} vs {b} (scale {scale})");
        }
    }
}
