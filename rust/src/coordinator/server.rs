//! The streaming serving loop: sticky-routed workers, each owning an
//! engine instance and its sessions, fed by bounded micro-batching;
//! open-loop trace replay with end-to-end latency accounting.
//!
//! Execution is batch-major and *continuously batched*: each worker
//! runs one persistent wave through a [`ContinuousScheduler`] — newly
//! arrived sessions are admitted into free lanes between token
//! positions (non-blocking [`Batcher::poll_batch`] ingest), every step
//! advances all live lanes through a single batched stack step (one
//! int8 GEMM per gate instead of per-session matvecs), and lanes whose
//! items finish are scattered back to their sessions and compacted out
//! so the GEMM only ever touches live rows. The PR 1 wave-at-a-time
//! discipline is kept as [`SchedulerMode::Wave`] for A/B comparison.

use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use anyhow::Result;

use crate::eval::metrics::LatencyStats;
use crate::lstm::{CalibrationStats, QuantizeOptions, StackEngine};
use crate::model::lm::CharLm;
use crate::workload::synth::RequestTrace;
use super::batcher::{BatchPolicy, Batcher, Poll};
use super::metrics::ServingReport;
use super::router::Router;
use super::scheduler::{ContinuousScheduler, SchedulerMode, StreamItem};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub engine: StackEngine,
    pub opts: QuantizeOptions,
    /// Scheduling discipline (continuous batching by default).
    pub mode: SchedulerMode,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            engine: StackEngine::Integer,
            opts: QuantizeOptions::default(),
            mode: SchedulerMode::Continuous,
        }
    }
}

/// Completion record sent back to the driver.
struct Completion {
    latency_ms: f64,
    tokens: usize,
    nll_bits_total: f64,
}

/// Per-worker execution summary.
struct WorkerSummary {
    compute_secs: f64,
    batches: usize,
    items: usize,
    /// Batched step invocations (one per token position of the wave).
    batched_steps: usize,
    /// Lane-steps executed (= tokens); `lane_steps / batched_steps` is
    /// the mean batch occupancy of the GEMM path.
    lane_steps: usize,
    /// Widest batch observed.
    peak_lanes: usize,
    /// Lane turnover: admissions into / retirements out of the wave.
    admissions: usize,
    retirements: usize,
    /// Total submission→admission wait across admitted items.
    admission_wait_ms: f64,
}

/// The server: binds a model + engine choice to a worker pool.
pub struct Server<'a> {
    lm: &'a CharLm,
    stats: Option<&'a [CalibrationStats]>,
    pub config: ServerConfig,
}

impl<'a> Server<'a> {
    pub fn new(
        lm: &'a CharLm,
        stats: Option<&'a [CalibrationStats]>,
        config: ServerConfig,
    ) -> Self {
        if config.engine == StackEngine::Integer {
            assert!(stats.is_some(), "integer engine needs calibration stats");
        }
        Server { lm, stats, config }
    }

    /// Replay a trace open-loop (arrival times compressed by
    /// `speedup`), return the serving report.
    pub fn run_trace(&self, trace: &RequestTrace, speedup: f64) -> Result<ServingReport> {
        let router = Router::new(self.config.workers);
        let (done_tx, done_rx) = channel::<Completion>();
        let engine_label = self.config.engine.label();

        let wall_start = Instant::now();
        let summaries: Vec<WorkerSummary> = std::thread::scope(|scope| {
            let mut senders: Vec<Sender<StreamItem>> = Vec::new();
            let mut handles = Vec::new();
            for _ in 0..self.config.workers {
                let (tx, rx) = channel::<StreamItem>();
                senders.push(tx);
                let batcher = Batcher::new(rx, self.config.batch);
                let done = done_tx.clone();
                let lm = self.lm;
                let stats = self.stats;
                let engine_kind = self.config.engine;
                let opts = self.config.opts;
                let mode = self.config.mode;
                let max_lanes = self.config.batch.max_batch;
                handles.push(scope.spawn(move || {
                    let engine = lm.engine(engine_kind, stats, opts);
                    let mut sched =
                        ContinuousScheduler::with_mode(&engine, max_lanes, mode);
                    let mut compute_secs = 0f64;
                    let mut batches = 0usize;
                    let mut items = 0usize;
                    let mut open = true;
                    loop {
                        // Ingest: block only when idle; between token
                        // positions only drain what is already queued.
                        if open {
                            if sched.has_live_work() {
                                match batcher.poll_batch() {
                                    Poll::Items(new) => {
                                        batches += 1;
                                        for item in new {
                                            items += 1;
                                            sched.offer(item);
                                        }
                                    }
                                    Poll::Empty => {}
                                    Poll::Closed => open = false,
                                }
                            } else {
                                match batcher.next_batch() {
                                    Some(new) => {
                                        batches += 1;
                                        for item in new {
                                            items += 1;
                                            sched.offer(item);
                                        }
                                    }
                                    None => open = false,
                                }
                            }
                        }
                        if !sched.has_live_work() {
                            if !open {
                                break;
                            }
                            continue;
                        }
                        let t0 = Instant::now();
                        sched.admit_ready();
                        sched.step();
                        compute_secs += t0.elapsed().as_secs_f64();
                        for c in sched.take_completed() {
                            let _ = done.send(Completion {
                                latency_ms: c.latency_ms,
                                tokens: c.tokens,
                                nll_bits_total: c.nll_bits,
                            });
                        }
                    }
                    let st = sched.stats();
                    WorkerSummary {
                        compute_secs,
                        batches,
                        items,
                        batched_steps: st.batched_steps,
                        lane_steps: st.lane_steps,
                        peak_lanes: st.peak_lanes,
                        admissions: st.admissions,
                        retirements: st.retirements,
                        admission_wait_ms: st.admission_wait_ms,
                    }
                }));
            }
            drop(done_tx);

            // Open-loop submission on the driver thread.
            let t0 = Instant::now();
            for req in &trace.requests {
                let target =
                    std::time::Duration::from_secs_f64(req.arrival_ms / 1000.0 / speedup);
                let now = t0.elapsed();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let worker = router.route(req.id);
                senders[worker]
                    .send(StreamItem {
                        session: req.id,
                        tokens: req.tokens.clone(),
                        submitted: Instant::now(),
                    })
                    .expect("worker died");
            }
            drop(senders);
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        let wall_secs = wall_start.elapsed().as_secs_f64();

        let mut latency = LatencyStats::new();
        let mut tokens = 0usize;
        let mut requests = 0usize;
        let mut _total_nll = 0f64;
        for c in done_rx.iter() {
            latency.record(c.latency_ms);
            tokens += c.tokens;
            requests += 1;
            _total_nll += c.nll_bits_total;
        }
        let compute_secs: f64 = summaries.iter().map(|s| s.compute_secs).sum();
        let batches: usize = summaries.iter().map(|s| s.batches).sum();
        let items: usize = summaries.iter().map(|s| s.items).sum();
        let batched_steps: usize = summaries.iter().map(|s| s.batched_steps).sum();
        let lane_steps: usize = summaries.iter().map(|s| s.lane_steps).sum();
        let peak_lanes: usize = summaries.iter().map(|s| s.peak_lanes).max().unwrap_or(0);
        let lane_admissions: usize = summaries.iter().map(|s| s.admissions).sum();
        let lane_retirements: usize = summaries.iter().map(|s| s.retirements).sum();
        let admission_wait_ms: f64 = summaries.iter().map(|s| s.admission_wait_ms).sum();

        Ok(ServingReport {
            engine: engine_label,
            mode: self.config.mode.label(),
            requests,
            tokens,
            wall_secs,
            compute_secs,
            latency,
            workers: self.config.workers,
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            batched_steps,
            lane_steps,
            peak_lanes,
            lane_admissions,
            lane_retirements,
            mean_admission_ms: if lane_admissions == 0 {
                0.0
            } else {
                admission_wait_ms / lane_admissions as f64
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, StackWeights};
    use crate::model::lm::{one_hot_seq, VOCAB};
    use crate::tensor::Matrix;
    use crate::util::Pcg32;
    use std::time::Duration;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(31);
        let spec = LstmSpec::plain(VOCAB, 24);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 24);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 24, depth: 1 }
    }

    fn calib(lm: &CharLm) -> Vec<crate::lstm::CalibrationStats> {
        let mut rng = Pcg32::seeded(32);
        let seqs: Vec<Vec<usize>> = (0..4)
            .map(|_| (0..24).map(|_| rng.below(VOCAB as u32) as usize).collect())
            .collect();
        let oh: Vec<_> = seqs.iter().map(|s| one_hot_seq(s)).collect();
        lm.stack_weights.calibrate(&oh)
    }

    #[test]
    fn serves_trace_on_all_engines_and_modes() {
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(24, 1000.0, 12, VOCAB, 3);
        for mode in [SchedulerMode::Continuous, SchedulerMode::Wave] {
            for engine in StackEngine::ALL {
                let config = ServerConfig {
                    workers: 2,
                    batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                    engine,
                    opts: QuantizeOptions::default(),
                    mode,
                };
                let server = Server::new(&lm, Some(&stats), config);
                let report = server.run_trace(&trace, 1000.0).unwrap();
                assert_eq!(report.requests, 24, "{engine:?} {mode:?}");
                assert_eq!(report.tokens, trace.total_tokens());
                assert_eq!(report.lane_retirements, report.lane_admissions);
                assert!(report.latency.percentile(50.0) >= 0.0);
                assert!(report.throughput() > 0.0);
                assert!(report.compute_secs > 0.0);
            }
        }
    }

    #[test]
    fn sticky_sessions_accumulate_state() {
        // Two requests with the same session id must be processed by
        // the same worker against the same recurrent state.
        let lm = tiny_lm();
        let stats = calib(&lm);
        let mut trace = RequestTrace::generate(2, 10_000.0, 8, VOCAB, 4);
        trace.requests[1].id = trace.requests[0].id; // same session
        let server = Server::new(&lm, Some(&stats), ServerConfig::default());
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.requests, 2);
    }

    #[test]
    #[should_panic(expected = "integer engine needs calibration stats")]
    fn integer_without_stats_panics() {
        let lm = tiny_lm();
        let _ = Server::new(&lm, None, ServerConfig::default());
    }
}
