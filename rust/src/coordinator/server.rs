//! The streaming serving loop: sticky-routed workers, each owning an
//! engine instance and its sessions, fed by bounded micro-batching;
//! open-loop trace replay with end-to-end latency accounting.
//!
//! Execution is batch-major end to end: each worker drains its
//! [`Batcher`] into a cross-session batch, packs the touched sessions'
//! recurrent states into one [`LmBatchState`], runs a *single* batched
//! step per token position through the whole stack (one int8 GEMM per
//! gate instead of per-session matvecs), and scatters the advanced
//! lanes back into the session table.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::eval::metrics::LatencyStats;
use crate::lstm::{CalibrationStats, QuantizeOptions, StackEngine};
use crate::model::lm::{nll_bits, CharLm, CharLmEngine, LmBatchState};
use crate::workload::synth::RequestTrace;
use super::batcher::{BatchPolicy, Batcher};
use super::metrics::ServingReport;
use super::router::Router;
use super::session::{SessionId, SessionManager};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub engine: StackEngine,
    pub opts: QuantizeOptions,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            engine: StackEngine::Integer,
            opts: QuantizeOptions::default(),
        }
    }
}

/// One unit of work: a request's token chunk for a session.
struct WorkItem {
    session: SessionId,
    tokens: Vec<usize>,
    submitted: Instant,
}

/// Completion record sent back to the driver.
struct Completion {
    latency_ms: f64,
    tokens: usize,
    nll_bits_total: f64,
}

/// Per-worker execution summary.
struct WorkerSummary {
    compute_secs: f64,
    batches: usize,
    items: usize,
    /// Batched step invocations (one per token position per wave).
    batched_steps: usize,
    /// Lane-steps executed (= tokens); `lane_steps / batched_steps` is
    /// the mean batch occupancy of the GEMM path.
    lane_steps: usize,
    /// Widest batch observed.
    peak_lanes: usize,
}

/// Execute one wave: distinct sessions, one work item per lane, all
/// lanes stepped together batch-major. Lanes are packed longest-first,
/// so the active set is always a prefix — when the shortest lanes run
/// out of tokens they are scattered back and the batch state simply
/// truncates, keeping the GEMM working only on live lanes.
fn run_wave(
    engine: &CharLmEngine,
    sessions: &mut SessionManager,
    mut wave: Vec<WorkItem>,
    state_cache: &mut Option<LmBatchState>,
    done: &Sender<Completion>,
    summary: &mut WorkerSummary,
) {
    wave.sort_by(|a, b| b.tokens.len().cmp(&a.tokens.len()));
    let lanes = wave.len();
    if lanes == 0 {
        return;
    }
    summary.peak_lanes = summary.peak_lanes.max(lanes);
    let max_len = wave[0].tokens.len();
    // One batch state per worker, resized (allocation-reusing) per
    // wave; every lane is gathered below, so stale contents are fine.
    let bs = state_cache.get_or_insert_with(|| engine.new_batch_state(lanes));
    engine.resize_batch_state(bs, lanes);
    for (lane, item) in wave.iter().enumerate() {
        let session = sessions.get_or_create(item.session, engine);
        engine.gather_session(&session.state, bs, lane);
    }
    let mut nll = vec![0f64; lanes];
    let mut toks: Vec<usize> = Vec::with_capacity(lanes);
    let mut active = lanes;
    for t in 0..max_len {
        // Lanes whose items are exhausted form a suffix: finish them.
        let still = wave.iter().take_while(|it| it.tokens.len() > t).count();
        if still < active {
            for lane in still..active {
                finish_lane(engine, sessions, bs, &wave[lane], lane, nll[lane], done);
            }
            engine.truncate_batch(bs, still);
            active = still;
        }
        toks.clear();
        toks.extend(wave[..active].iter().map(|it| it.tokens[t]));
        engine.step_tokens(&toks, bs);
        summary.batched_steps += 1;
        summary.lane_steps += active;
        for lane in 0..active {
            if let Some(&next) = wave[lane].tokens.get(t + 1) {
                nll[lane] += nll_bits(bs.logits.row(lane), next);
            }
        }
    }
    for lane in 0..active {
        finish_lane(engine, sessions, bs, &wave[lane], lane, nll[lane], done);
    }
}

/// Scatter a finished lane back into its session and report completion.
fn finish_lane(
    engine: &CharLmEngine,
    sessions: &mut SessionManager,
    bs: &LmBatchState,
    item: &WorkItem,
    lane: usize,
    nll: f64,
    done: &Sender<Completion>,
) {
    let session = sessions.get_or_create(item.session, engine);
    if !item.tokens.is_empty() {
        engine.scatter_session(bs, &mut session.state, lane);
    }
    session.tokens_seen += item.tokens.len();
    session.nll_bits += nll;
    let _ = done.send(Completion {
        latency_ms: item.submitted.elapsed().as_secs_f64() * 1e3,
        tokens: item.tokens.len(),
        nll_bits_total: nll,
    });
}

/// The server: binds a model + engine choice to a worker pool.
pub struct Server<'a> {
    lm: &'a CharLm,
    stats: Option<&'a [CalibrationStats]>,
    pub config: ServerConfig,
}

impl<'a> Server<'a> {
    pub fn new(
        lm: &'a CharLm,
        stats: Option<&'a [CalibrationStats]>,
        config: ServerConfig,
    ) -> Self {
        if config.engine == StackEngine::Integer {
            assert!(stats.is_some(), "integer engine needs calibration stats");
        }
        Server { lm, stats, config }
    }

    /// Replay a trace open-loop (arrival times compressed by
    /// `speedup`), return the serving report.
    pub fn run_trace(&self, trace: &RequestTrace, speedup: f64) -> Result<ServingReport> {
        let router = Router::new(self.config.workers);
        let (done_tx, done_rx) = channel::<Completion>();
        let engine_label = self.config.engine.label();

        let wall_start = Instant::now();
        let summaries: Vec<WorkerSummary> = std::thread::scope(|scope| {
            let mut senders: Vec<Sender<WorkItem>> = Vec::new();
            let mut handles = Vec::new();
            for _ in 0..self.config.workers {
                let (tx, rx) = channel::<WorkItem>();
                senders.push(tx);
                let batcher = Batcher::new(rx, self.config.batch);
                let done = done_tx.clone();
                let lm = self.lm;
                let stats = self.stats;
                let engine_kind = self.config.engine;
                let opts = self.config.opts;
                handles.push(scope.spawn(move || {
                    let engine = lm.engine(engine_kind, stats, opts);
                    let mut sessions = SessionManager::new();
                    let mut state_cache: Option<LmBatchState> = None;
                    let mut summary = WorkerSummary {
                        compute_secs: 0.0,
                        batches: 0,
                        items: 0,
                        batched_steps: 0,
                        lane_steps: 0,
                        peak_lanes: 0,
                    };
                    while let Some(batch) = batcher.next_batch() {
                        summary.batches += 1;
                        let t0 = Instant::now();
                        // Split same-session items into consecutive
                        // waves so each wave holds at most one item per
                        // session (a stream's state must advance in
                        // arrival order).
                        let mut waves: Vec<Vec<WorkItem>> = Vec::new();
                        let mut seen: HashMap<SessionId, usize> = HashMap::new();
                        for item in batch {
                            summary.items += 1;
                            let slot = seen.entry(item.session).or_insert(0);
                            let w = *slot;
                            *slot += 1;
                            if waves.len() <= w {
                                waves.push(Vec::new());
                            }
                            waves[w].push(item);
                        }
                        for wave in waves {
                            run_wave(
                                &engine,
                                &mut sessions,
                                wave,
                                &mut state_cache,
                                &done,
                                &mut summary,
                            );
                        }
                        summary.compute_secs += t0.elapsed().as_secs_f64();
                    }
                    summary
                }));
            }
            drop(done_tx);

            // Open-loop submission on the driver thread.
            let t0 = Instant::now();
            for req in &trace.requests {
                let target = Duration::from_secs_f64(req.arrival_ms / 1000.0 / speedup);
                let now = t0.elapsed();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let worker = router.route(req.id);
                senders[worker]
                    .send(WorkItem {
                        session: req.id,
                        tokens: req.tokens.clone(),
                        submitted: Instant::now(),
                    })
                    .expect("worker died");
            }
            drop(senders);
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        let wall_secs = wall_start.elapsed().as_secs_f64();

        let mut latency = LatencyStats::new();
        let mut tokens = 0usize;
        let mut requests = 0usize;
        let mut _total_nll = 0f64;
        for c in done_rx.iter() {
            latency.record(c.latency_ms);
            tokens += c.tokens;
            requests += 1;
            _total_nll += c.nll_bits_total;
        }
        let compute_secs: f64 = summaries.iter().map(|s| s.compute_secs).sum();
        let batches: usize = summaries.iter().map(|s| s.batches).sum();
        let items: usize = summaries.iter().map(|s| s.items).sum();
        let batched_steps: usize = summaries.iter().map(|s| s.batched_steps).sum();
        let lane_steps: usize = summaries.iter().map(|s| s.lane_steps).sum();
        let peak_lanes: usize = summaries.iter().map(|s| s.peak_lanes).max().unwrap_or(0);

        Ok(ServingReport {
            engine: engine_label,
            requests,
            tokens,
            wall_secs,
            compute_secs,
            latency,
            workers: self.config.workers,
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            batched_steps,
            lane_steps,
            peak_lanes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, StackWeights};
    use crate::model::lm::{one_hot_seq, VOCAB};
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(31);
        let spec = LstmSpec::plain(VOCAB, 24);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 24);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 24, depth: 1 }
    }

    fn calib(lm: &CharLm) -> Vec<CalibrationStats> {
        let mut rng = Pcg32::seeded(32);
        let seqs: Vec<Vec<usize>> = (0..4)
            .map(|_| (0..24).map(|_| rng.below(VOCAB as u32) as usize).collect())
            .collect();
        let oh: Vec<_> = seqs.iter().map(|s| one_hot_seq(s)).collect();
        lm.stack_weights.calibrate(&oh)
    }

    #[test]
    fn serves_trace_on_all_engines() {
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(24, 1000.0, 12, VOCAB, 3);
        for engine in StackEngine::ALL {
            let config = ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                engine,
                opts: QuantizeOptions::default(),
            };
            let server = Server::new(&lm, Some(&stats), config);
            let report = server.run_trace(&trace, 1000.0).unwrap();
            assert_eq!(report.requests, 24, "{engine:?}");
            assert_eq!(report.tokens, trace.total_tokens());
            assert!(report.latency.percentile(50.0) >= 0.0);
            assert!(report.throughput() > 0.0);
            assert!(report.compute_secs > 0.0);
        }
    }

    #[test]
    fn sticky_sessions_accumulate_state() {
        // Two requests with the same session id must be processed by
        // the same worker against the same recurrent state.
        let lm = tiny_lm();
        let stats = calib(&lm);
        let mut trace = RequestTrace::generate(2, 10_000.0, 8, VOCAB, 4);
        trace.requests[1].id = trace.requests[0].id; // same session
        let server = Server::new(&lm, Some(&stats), ServerConfig::default());
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.requests, 2);
    }

    #[test]
    #[should_panic(expected = "integer engine needs calibration stats")]
    fn integer_without_stats_panics() {
        let lm = tiny_lm();
        let _ = Server::new(&lm, None, ServerConfig::default());
    }
}
