//! The streaming serving loop: a sharded worker pool with work
//! stealing, each worker owning engine instances for its resident
//! models, its sessions, and one persistent continuously-batched wave
//! per model; open-loop trace replay with end-to-end latency
//! accounting.
//!
//! Execution is batch-major and *continuously batched*: each worker
//! runs its persistent waves through a [`ContinuousScheduler`] — newly
//! arrived sessions are admitted into free lanes between token
//! positions, every step advances all live lanes of a model through a
//! single batched stack step (one int8 GEMM per gate instead of
//! per-session matvecs), and lanes whose items finish are scattered
//! back to their sessions and compacted out so the GEMM only ever
//! touches live rows. Lanes never mix models; the per-worker lane
//! budget is shared across resident models by backlog.
//!
//! Ingest is sharded: the driver hash-routes each request's
//! `(model, session)` stream to a home queue on the shared
//! [`ShardRouter`] (among the model's resident workers); workers drain
//! their own queue between token positions, and a worker that runs dry
//! *steals* whole unbound sessions of models it hosts from the most
//! backlogged peer, so occupancy survives skewed session routing. A
//! worker only ingests up to its free lane capacity, which deliberately
//! leaves overload in the shared queue where peers can take it. The
//! PR 1 wave-at-a-time discipline is kept as [`SchedulerMode::Wave`]
//! for A/B comparison, and `steal: false` reproduces static sticky
//! routing.
//!
//! A [`Server`] binds either one model ([`Server::new`]) or a whole
//! [`ModelRegistry`] ([`Server::with_registry`]) to the pool; the
//! single-model constructor is just a one-entry registry.

use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use anyhow::Result;

use crate::eval::metrics::LatencyStats;
use crate::lstm::{CalibrationStats, QuantizeOptions, StackEngine};
use crate::model::lm::{CharLm, CharLmEngine};
use crate::tensor::qmatmul::kernel_counters::KernelCounters;
use crate::workload::synth::RequestTrace;
use super::batcher::BatchPolicy;
use super::hibernate::SpillCodec;
use super::metrics::{ModelLoad, ServingReport, WorkerLoad};
use super::registry::{ModelId, ModelRegistry, ModelSpec, Residency};
use super::router::{ShardPoll, ShardRouter};
use super::scheduler::{
    ContinuousScheduler, SchedulerMode, SchedulerStats, StreamDone, StreamItem,
    TokenEvent,
};
use super::session::SessionKey;
use super::trace::{merge_events, EventKind, StageLatencies, TraceConfig, TraceEvent};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Worker (shard) count; each worker owns one persistent wave per
    /// resident model.
    pub workers: usize,
    /// Batch policy. Only `max_batch` is consulted by the server: it
    /// bounds the live lanes per worker (shared across that worker's
    /// model waves, and how many items one ingest pull may take).
    /// `max_wait` is a [`Batcher`] dial with no effect on this path —
    /// sharded ingest is non-blocking between token positions.
    ///
    /// [`Batcher`]: super::batcher::Batcher
    pub batch: BatchPolicy,
    /// Execution engine for the single-model constructor
    /// ([`Server::new`]); registry deployments carry an engine per
    /// model instead.
    pub engine: StackEngine,
    /// Quantization options for the single-model constructor.
    pub opts: QuantizeOptions,
    /// Scheduling discipline (continuous batching by default).
    pub mode: SchedulerMode,
    /// Work stealing between workers (on by default; off reproduces
    /// static sticky routing).
    pub steal: bool,
    /// Per-worker cap on resident sessions (`None` = unbounded). The
    /// longest-seen idle sessions are evicted between token positions;
    /// sessions holding or awaiting a lane are never evicted.
    pub session_budget: Option<usize>,
    /// Evict sessions idle for more than this many batched token
    /// positions (`None` = never) — the idle-age twin of
    /// `session_budget`, matching real memory pressure for stream
    /// state.
    pub evict_idle_after: Option<u64>,
    /// Per-worker **byte** budget on resident session state (`None` =
    /// unbounded) — the `--session-budget` CLI flag. When resident
    /// state exceeds it, the coldest idle sessions hibernate into the
    /// worker's cold tier (lossless, restored transparently before
    /// their next lane admission); sessions holding or awaiting a lane
    /// never spill, so the budget must cover
    /// `max_lanes × state_bytes` of the largest resident model.
    pub state_budget: Option<usize>,
    /// Serialize hibernated state int8-quantized (per-vector scales,
    /// ~4x smaller) instead of exact — the `--spill-quantized` flag.
    /// Exact spills are bit-exact on restore; quantized spills trade a
    /// measured accuracy delta (see `rust/tests/numerics_edge.rs`) for
    /// the smaller cold tier.
    pub spill_quantized: bool,
    /// Observability level and per-worker ring capacity (the `--trace`
    /// flag; off by default). Tracing never changes token values or
    /// schedules at any level.
    pub trace: TraceConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            engine: StackEngine::Integer,
            opts: QuantizeOptions::default(),
            mode: SchedulerMode::Continuous,
            steal: true,
            session_budget: None,
            evict_idle_after: None,
            state_budget: None,
            spill_quantized: false,
            trace: TraceConfig::default(),
        }
    }
}

/// What a serving worker emits while running: per-token events (only
/// when the token tap is on — the network front streams them to
/// clients) and item completions. The old `Completion` record with its
/// ambiguous `latency_ms` is gone: completions travel as the
/// scheduler's own [`StreamDone`], whose `wall_ms` /
/// `first_token_wall_ms` names make the clock explicit.
pub(crate) enum WorkerEvent {
    /// One executed token position of a live stream.
    Token(TokenEvent),
    /// One finished item.
    Done(StreamDone),
}

/// The per-worker knobs [`run_worker`] needs — the scheduler-facing
/// subset of [`ServerConfig`] plus the token tap.
pub(crate) struct WorkerCfg {
    pub(crate) max_lanes: usize,
    pub(crate) mode: SchedulerMode,
    pub(crate) session_budget: Option<usize>,
    pub(crate) evict_idle_after: Option<u64>,
    pub(crate) state_budget: Option<usize>,
    pub(crate) spill_quantized: bool,
    pub(crate) record_tokens: bool,
    pub(crate) trace: TraceConfig,
}

/// Per-worker execution summary.
pub(crate) struct WorkerSummary {
    pub(crate) compute_secs: f64,
    pub(crate) batches: usize,
    pub(crate) items: usize,
    pub(crate) stats: SchedulerStats,
    pub(crate) model_stats: Vec<SchedulerStats>,
    /// Resident (hot) sessions per model at worker exit.
    pub(crate) model_sessions: Vec<usize>,
    /// Hibernated sessions per model at worker exit.
    pub(crate) model_hibernated: Vec<usize>,
    /// Serialized cold-tier bytes per model at worker exit.
    pub(crate) model_hibernated_bytes: Vec<usize>,
    /// Per-stage duration histograms (empty below trace `counters`).
    pub(crate) stage: StageLatencies,
    /// This worker's lifecycle events (empty below trace `full`). The
    /// `step` field is the worker's own loop iteration counter — a
    /// worker-local clock, unlike the simulators' shared tick.
    pub(crate) trace_events: Vec<TraceEvent>,
}

/// Wall-clock completion aggregation shared by trace replay and the
/// network front-end: the end-to-end, first-token, and per-token
/// latency histograms plus the token/request/nll totals.
pub(crate) struct CompletionAgg {
    pub(crate) latency: LatencyStats,
    pub(crate) first_token: LatencyStats,
    pub(crate) per_token: LatencyStats,
    pub(crate) tokens: usize,
    pub(crate) requests: usize,
}

impl CompletionAgg {
    pub(crate) fn new() -> Self {
        CompletionAgg {
            latency: LatencyStats::new(),
            first_token: LatencyStats::new(),
            per_token: LatencyStats::new(),
            tokens: 0,
            requests: 0,
        }
    }

    pub(crate) fn record(&mut self, d: &StreamDone) {
        self.latency.record(d.wall_ms);
        self.first_token.record(d.first_token_wall_ms);
        if d.tokens > 1 {
            // Steady-state cadence after the first token landed.
            self.per_token
                .record((d.wall_ms - d.first_token_wall_ms) / (d.tokens - 1) as f64);
        }
        self.tokens += d.tokens;
        self.requests += 1;
    }
}

/// The worker loop shared by trace replay ([`Server::run_trace`]) and
/// the network front-end ([`super::net`]): poll the router up to the
/// free lane capacity, admit, step, enforce budgets, and emit events
/// until the router is closed and drained.
pub(crate) fn run_worker(
    registry: &ModelRegistry<'_>,
    router: &ShardRouter,
    w: usize,
    workers: usize,
    cfg: &WorkerCfg,
    events: &Sender<WorkerEvent>,
) -> WorkerSummary {
    let engines: Vec<Option<CharLmEngine>> = registry.instantiate(w, workers);
    let engine_refs: Vec<Option<&CharLmEngine>> =
        engines.iter().map(|e| e.as_ref()).collect();
    let mut sched = ContinuousScheduler::multi(engine_refs, cfg.max_lanes, cfg.mode);
    sched.set_record_tokens(cfg.record_tokens);
    sched.set_trace(cfg.trace, w as u32);
    if cfg.spill_quantized {
        sched.set_spill_codec(SpillCodec::Int8);
    }
    let mut compute_secs = 0f64;
    let mut batches = 0usize;
    let mut items = 0usize;
    // The worker's virtual clock for trace events: its own loop
    // iteration counter. Unlike the simulators there is no shared tick,
    // so cross-worker event order within a step is only meaningful
    // per-worker.
    let mut tstep = 0u64;
    // Sticky shutdown flag. A worker whose lanes are full at close time
    // has `capacity == 0` and skips the poll entirely, so `Closed`
    // cannot be observed that iteration; when the flag was re-armed to
    // `false` every iteration, shutdown additionally required
    // re-observing the router in the *same* iteration the last lane
    // drained. That never hangs (full lanes imply live work, every
    // step retires work, and an emptied scheduler polls again next
    // iteration) — but exit correctness shouldn't lean on that
    // re-observation; once `Closed` is seen it stays seen. Pinned by
    // `close_with_full_lanes_drains_cleanly`.
    let mut closed = false;
    loop {
        sched.set_trace_step(tstep);
        tstep += 1;
        // Ingest up to the free lane capacity: backlog beyond it stays
        // in the shared queue, where an idle peer can steal it.
        let capacity =
            cfg.max_lanes.saturating_sub(sched.live_lanes() + sched.pending_len());
        if capacity > 0 {
            match router.poll(w, capacity) {
                ShardPoll::Items(new) => {
                    batches += 1;
                    for item in new {
                        items += 1;
                        sched.offer(item);
                    }
                }
                ShardPoll::Stolen { items: new, victim } => {
                    batches += 1;
                    // One Steal event per distinct stolen session, not
                    // per item, mirroring the simulators.
                    let mut stolen: Vec<SessionKey> = Vec::new();
                    for item in new {
                        items += 1;
                        let key = (item.model, item.session);
                        if !stolen.contains(&key) {
                            stolen.push(key);
                            sched.trace_event(
                                EventKind::Steal,
                                key.0,
                                key.1,
                                victim as u64,
                            );
                        }
                        sched.offer(item);
                    }
                }
                ShardPoll::Empty => {
                    if !sched.has_live_work() {
                        // Fully idle: block until there is something to
                        // drain, steal, or shut down for.
                        router.wait_for_work(w);
                        continue;
                    }
                }
                ShardPoll::Closed => closed = true,
            }
        }
        if !sched.has_live_work() {
            if closed {
                break;
            }
            continue;
        }
        let t0 = Instant::now();
        sched.admit_ready();
        sched.step();
        compute_secs += t0.elapsed().as_secs_f64();
        if cfg.session_budget.is_some() || cfg.evict_idle_after.is_some() {
            // One router-lock acquisition serves both eviction
            // policies.
            let queued = router.queued_sessions(w);
            if let Some(budget) = cfg.session_budget {
                sched.enforce_session_budget(budget, &queued);
            }
            if let Some(max_idle) = cfg.evict_idle_after {
                sched.enforce_idle_budget(max_idle, &queued);
            }
        }
        if let Some(budget) = cfg.state_budget {
            sched.enforce_state_budget(budget);
        }
        sched.sample_resident_peak();
        // Tokens before completions: a stream's Done must never
        // overtake its own token events at the receiver.
        for t in sched.take_token_events() {
            let _ = events.send(WorkerEvent::Token(t));
        }
        for c in sched.take_completed() {
            let _ = events.send(WorkerEvent::Done(c));
        }
    }
    let model_sessions = (0..registry.len())
        .map(|m| sched.sessions().len_model(m as ModelId))
        .collect();
    let model_hibernated = (0..registry.len())
        .map(|m| sched.cold().len_model(m as ModelId))
        .collect();
    let model_hibernated_bytes = (0..registry.len())
        .map(|m| sched.cold().bytes_model(m as ModelId))
        .collect();
    let stage = sched.take_stage_latencies();
    let trace_events = sched.take_trace_events();
    WorkerSummary {
        compute_secs,
        batches,
        items,
        stats: sched.stats(),
        model_stats: sched.model_stats().to_vec(),
        model_sessions,
        model_hibernated,
        model_hibernated_bytes,
        stage,
        trace_events,
    }
}

/// The server: binds a model registry to a worker pool. The
/// single-model constructor wraps the model into a one-entry registry,
/// so both deployments run the identical pool.
pub struct Server<'a> {
    registry: ModelRegistry<'a>,
    /// The pool configuration the server runs with.
    pub config: ServerConfig,
}

impl<'a> Server<'a> {
    /// Bind one model (and, for the integer engine, its calibration
    /// stats) to a pool configuration. Panics with "integer engine
    /// needs calibration stats" when they are missing.
    pub fn new(
        lm: &'a CharLm,
        stats: Option<&'a [CalibrationStats]>,
        config: ServerConfig,
    ) -> Self {
        let mut registry = ModelRegistry::new();
        registry.register(ModelSpec {
            name: "default".into(),
            lm,
            engine: config.engine,
            stats,
            opts: config.opts,
            residency: Residency::All,
        });
        Server { registry, config }
    }

    /// Bind a whole model registry to a pool configuration. Requests
    /// are tagged with [`ModelId`]s; each worker instantiates engines
    /// for the models resident on it and runs one wave per model.
    pub fn with_registry(registry: ModelRegistry<'a>, config: ServerConfig) -> Self {
        assert!(!registry.is_empty(), "registry must hold at least one model");
        Server { registry, config }
    }

    /// The registry this server serves.
    pub fn registry(&self) -> &ModelRegistry<'a> {
        &self.registry
    }

    /// Replay a trace open-loop (arrival times compressed by
    /// `speedup`), return the serving report. Fails cleanly if the
    /// trace names a model the registry does not hold (submitting such
    /// a request mid-replay would otherwise panic the driver thread
    /// while workers wait for close).
    pub fn run_trace(&self, trace: &RequestTrace, speedup: f64) -> Result<ServingReport> {
        let workers = self.config.workers;
        let n_models = self.registry.len();
        for req in &trace.requests {
            anyhow::ensure!(
                (req.model as usize) < n_models,
                "request for session {} names model {} but only {} model(s) are registered",
                req.id,
                req.model,
                n_models
            );
        }
        let residency = self.registry.residency(workers);
        let router = ShardRouter::with_residency(workers, self.config.steal, residency.clone());
        let (ev_tx, ev_rx) = channel::<WorkerEvent>();
        let wcfg = WorkerCfg {
            max_lanes: self.config.batch.max_batch,
            mode: self.config.mode,
            session_budget: self.config.session_budget,
            evict_idle_after: self.config.evict_idle_after,
            state_budget: self.config.state_budget,
            spill_quantized: self.config.spill_quantized,
            record_tokens: false,
            trace: self.config.trace,
        };

        let wall_start = Instant::now();
        let summaries: Vec<WorkerSummary> = std::thread::scope(|scope| {
            let router = &router;
            let registry = &self.registry;
            let wcfg = &wcfg;
            let mut handles = Vec::new();
            for w in 0..workers {
                let events: Sender<WorkerEvent> = ev_tx.clone();
                handles.push(scope.spawn(move || {
                    run_worker(registry, router, w, workers, wcfg, &events)
                }));
            }
            drop(ev_tx);

            // Open-loop submission on the driver thread.
            let t0 = Instant::now();
            for req in &trace.requests {
                let target =
                    std::time::Duration::from_secs_f64(req.arrival_ms / 1000.0 / speedup);
                let now = t0.elapsed();
                if target > now {
                    std::thread::sleep(target - now);
                }
                router.submit(StreamItem {
                    model: req.model,
                    session: req.id,
                    tokens: req.tokens.clone(),
                    submitted: Instant::now(),
                });
            }
            router.close();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        let wall_secs = wall_start.elapsed().as_secs_f64();

        let mut agg = CompletionAgg::new();
        for ev in ev_rx.iter() {
            if let WorkerEvent::Done(d) = ev {
                agg.record(&d);
            }
        }
        Ok(self.assemble_report(&summaries, &router, &residency, wall_secs, agg))
    }

    /// Assemble the [`ServingReport`] out of the worker summaries, the
    /// router's steal counters, and the wall-clock completion
    /// aggregation — shared by [`Self::run_trace`] and the network
    /// front-end ([`super::net`]).
    pub(crate) fn assemble_report(
        &self,
        summaries: &[WorkerSummary],
        router: &ShardRouter,
        residency: &[Vec<usize>],
        wall_secs: f64,
        agg: CompletionAgg,
    ) -> ServingReport {
        let workers = self.config.workers;
        let n_models = self.registry.len();
        let engine_label = if n_models == 1 {
            self.registry.engine_kind(0).label()
        } else {
            "multi"
        };
        let steal_events = router.steal_events();
        let stolen_sessions = router.stolen_sessions();
        let stolen_by_model = router.stolen_by_model(n_models);
        let per_worker: Vec<WorkerLoad> = summaries
            .iter()
            .enumerate()
            .map(|(i, s)| WorkerLoad {
                worker: i,
                batched_steps: s.stats.batched_steps,
                lane_steps: s.stats.lane_steps,
                padded_lane_steps: s.stats.padded_lane_steps,
                peak_lanes: s.stats.peak_lanes,
                admissions: s.stats.admissions,
                retirements: s.stats.retirements,
                steal_events: steal_events[i],
                stolen_sessions: stolen_sessions[i],
                evictions: s.stats.evictions,
                idle_evictions: s.stats.idle_evictions,
                spills: s.stats.spills,
                restores: s.stats.restores,
                peak_resident_state_bytes: s.stats.peak_resident_state_bytes,
            })
            .collect();
        let per_model: Vec<ModelLoad> = (0..n_models)
            .map(|m| {
                let mid = m as ModelId;
                let mut agg = SchedulerStats::default();
                let mut resident_sessions = 0usize;
                let mut hibernated_sessions = 0usize;
                let mut hibernated_state_bytes = 0usize;
                for s in summaries {
                    agg.batched_steps += s.model_stats[m].batched_steps;
                    agg.lane_steps += s.model_stats[m].lane_steps;
                    agg.padded_lane_steps += s.model_stats[m].padded_lane_steps;
                    agg.peak_lanes = agg.peak_lanes.max(s.model_stats[m].peak_lanes);
                    agg.admissions += s.model_stats[m].admissions;
                    agg.retirements += s.model_stats[m].retirements;
                    agg.evictions += s.model_stats[m].evictions;
                    agg.idle_evictions += s.model_stats[m].idle_evictions;
                    agg.spills += s.model_stats[m].spills;
                    agg.restores += s.model_stats[m].restores;
                    agg.kernels.add(&s.model_stats[m].kernels);
                    resident_sessions += s.model_sessions[m];
                    hibernated_sessions += s.model_hibernated[m];
                    hibernated_state_bytes += s.model_hibernated_bytes[m];
                }
                let resident_workers = residency[m].len();
                let weight_bytes = self.registry.weight_bytes(mid);
                ModelLoad {
                    model: mid,
                    name: self.registry.name(mid).to_string(),
                    engine: self.registry.engine_kind(mid).label(),
                    weight_bits: self.registry.weight_bits(mid).label(),
                    resident_workers,
                    weight_bytes,
                    resident_weight_bytes: weight_bytes * resident_workers,
                    resident_sessions,
                    resident_state_bytes: resident_sessions
                        * self.registry.state_bytes(mid),
                    hibernated_sessions,
                    hibernated_state_bytes,
                    batched_steps: agg.batched_steps,
                    lane_steps: agg.lane_steps,
                    padded_lane_steps: agg.padded_lane_steps,
                    peak_lanes: agg.peak_lanes,
                    admissions: agg.admissions,
                    retirements: agg.retirements,
                    steals: stolen_by_model[m],
                    evictions: agg.evictions,
                    idle_evictions: agg.idle_evictions,
                    spills: agg.spills,
                    restores: agg.restores,
                    kernels: agg.kernels,
                }
            })
            .collect();
        let compute_secs: f64 = summaries.iter().map(|s| s.compute_secs).sum();
        let batches: usize = summaries.iter().map(|s| s.batches).sum();
        let items: usize = summaries.iter().map(|s| s.items).sum();
        let batched_steps: usize = summaries.iter().map(|s| s.stats.batched_steps).sum();
        let lane_steps: usize = summaries.iter().map(|s| s.stats.lane_steps).sum();
        let padded_lane_steps: usize =
            summaries.iter().map(|s| s.stats.padded_lane_steps).sum();
        let peak_lanes: usize =
            summaries.iter().map(|s| s.stats.peak_lanes).max().unwrap_or(0);
        let lane_admissions: usize = summaries.iter().map(|s| s.stats.admissions).sum();
        let lane_retirements: usize =
            summaries.iter().map(|s| s.stats.retirements).sum();
        let admission_wait_ms: f64 =
            summaries.iter().map(|s| s.stats.admission_wait_ms).sum();
        let evictions: usize = summaries.iter().map(|s| s.stats.evictions).sum();
        let idle_evictions: usize =
            summaries.iter().map(|s| s.stats.idle_evictions).sum();
        let spills: usize = summaries.iter().map(|s| s.stats.spills).sum();
        let restores: usize = summaries.iter().map(|s| s.stats.restores).sum();
        let peak_resident_state_bytes: usize = summaries
            .iter()
            .map(|s| s.stats.peak_resident_state_bytes)
            .max()
            .unwrap_or(0);
        let resident_state_bytes: usize =
            per_model.iter().map(|m| m.resident_state_bytes).sum();
        let hibernated_state_bytes: usize =
            per_model.iter().map(|m| m.hibernated_state_bytes).sum();
        let mut stage = StageLatencies::default();
        let mut kernels = KernelCounters::default();
        for s in summaries {
            stage.merge(&s.stage);
            kernels.add(&s.stats.kernels);
        }
        let trace_events =
            merge_events(summaries.iter().map(|s| s.trace_events.clone()).collect());

        ServingReport {
            engine: engine_label,
            mode: self.config.mode.label(),
            models: n_models,
            requests: agg.requests,
            tokens: agg.tokens,
            wall_secs,
            compute_secs,
            latency: agg.latency,
            first_token_latency: agg.first_token,
            per_token_latency: agg.per_token,
            workers,
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            batched_steps,
            lane_steps,
            padded_lane_steps,
            peak_lanes,
            lane_admissions,
            lane_retirements,
            mean_admission_ms: if lane_admissions == 0 {
                0.0
            } else {
                admission_wait_ms / lane_admissions as f64
            },
            steals: stolen_sessions.iter().sum(),
            evictions,
            idle_evictions,
            spills,
            restores,
            resident_state_bytes,
            hibernated_state_bytes,
            peak_resident_state_bytes,
            resident_weight_bytes: self.registry.total_resident_weight_bytes(workers),
            per_worker,
            per_model,
            stage,
            kernels,
            trace_events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, StackWeights};
    use crate::model::lm::{one_hot_seq, VOCAB};
    use crate::tensor::Matrix;
    use crate::util::Pcg32;
    use std::time::Duration;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(31);
        let spec = LstmSpec::plain(VOCAB, 24);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 24);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 24, depth: 1 }
    }

    fn calib(lm: &CharLm) -> Vec<crate::lstm::CalibrationStats> {
        let mut rng = Pcg32::seeded(32);
        let seqs: Vec<Vec<usize>> = (0..4)
            .map(|_| (0..24).map(|_| rng.below(VOCAB as u32) as usize).collect())
            .collect();
        let oh: Vec<_> = seqs.iter().map(|s| one_hot_seq(s)).collect();
        lm.stack_weights.calibrate(&oh)
    }

    #[test]
    fn serves_trace_on_all_engines_and_modes() {
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(24, 1000.0, 12, VOCAB, 3);
        for mode in [SchedulerMode::Continuous, SchedulerMode::Wave] {
            for engine in StackEngine::ALL {
                let config = ServerConfig {
                    workers: 2,
                    batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                    engine,
                    mode,
                    ..ServerConfig::default()
                };
                let server = Server::new(&lm, Some(&stats), config);
                let report = server.run_trace(&trace, 1000.0).unwrap();
                assert_eq!(report.requests, 24, "{engine:?} {mode:?}");
                assert_eq!(report.tokens, trace.total_tokens());
                assert_eq!(report.lane_retirements, report.lane_admissions);
                assert!(
                    report.padded_lane_steps >= report.lane_steps,
                    "physical width below live width"
                );
                assert_eq!(report.per_worker.len(), 2);
                assert_eq!(report.models, 1);
                assert_eq!(report.per_model.len(), 1);
                assert_eq!(report.per_model[0].lane_steps, report.lane_steps);
                assert!(report.resident_weight_bytes > 0);
                assert!(report.latency.percentile(50.0) >= 0.0);
                assert!(report.throughput() > 0.0);
                assert!(report.compute_secs > 0.0);
            }
        }
    }

    #[test]
    fn sticky_sessions_accumulate_state() {
        // Two requests with the same session id must be processed by
        // the same worker against the same recurrent state.
        let lm = tiny_lm();
        let stats = calib(&lm);
        let mut trace = RequestTrace::generate(2, 10_000.0, 8, VOCAB, 4);
        trace.requests[1].id = trace.requests[0].id; // same session
        let server = Server::new(&lm, Some(&stats), ServerConfig::default());
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.requests, 2);
    }

    #[test]
    fn single_worker_reports_no_steals() {
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(12, 2000.0, 8, VOCAB, 6);
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig { workers: 1, ..ServerConfig::default() },
        );
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.requests, 12);
        assert_eq!(report.steals, 0);
        assert_eq!(report.per_worker.len(), 1);
        assert_eq!(report.per_worker[0].lane_steps, report.lane_steps);
    }

    #[test]
    fn registry_server_serves_mixed_models() {
        let lm_a = tiny_lm();
        let lm_b = {
            let mut rng = Pcg32::seeded(77);
            let spec = LstmSpec::plain(VOCAB, 16);
            let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
            let mut out_w = Matrix::<f32>::zeros(VOCAB, 16);
            rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
            CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 16, depth: 1 }
        };
        let mut registry = ModelRegistry::new();
        registry.register(ModelSpec {
            name: "a".into(),
            lm: &lm_a,
            engine: StackEngine::Float,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        registry.register(ModelSpec {
            name: "b".into(),
            lm: &lm_b,
            engine: StackEngine::Hybrid,
            stats: None,
            opts: QuantizeOptions::default(),
            residency: Residency::All,
        });
        let mut trace = RequestTrace::generate(20, 2000.0, 8, VOCAB, 9);
        trace.assign_models(|id| (id % 2) as ModelId);
        let server = Server::with_registry(
            registry,
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                ..ServerConfig::default()
            },
        );
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.requests, 20);
        assert_eq!(report.tokens, trace.total_tokens());
        assert_eq!(report.models, 2);
        assert_eq!(report.engine, "multi");
        assert_eq!(report.per_model.len(), 2);
        // Per-model lane-steps partition the total.
        assert_eq!(
            report.per_model.iter().map(|m| m.lane_steps).sum::<usize>(),
            report.lane_steps
        );
        for m in &report.per_model {
            assert!(m.lane_steps > 0, "model {} never executed", m.model);
            assert!(m.resident_weight_bytes >= m.weight_bytes);
            assert_eq!(m.resident_workers, 2);
        }
    }

    #[test]
    fn close_with_full_lanes_drains_cleanly() {
        // Satellite-3 regression: submit everything at once at an
        // extreme speedup so `router.close()` lands while every lane is
        // occupied (`max_batch` is far below the backlog). Workers then
        // have `capacity == 0` and skip the poll on the very iteration
        // the router closes; with a non-sticky `closed` flag, exit
        // correctness leaned on re-observing `Closed` in the same
        // iteration the last lane drained. The run must still complete
        // every request and terminate.
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(32, 1_000_000.0, 10, VOCAB, 13);
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
                ..ServerConfig::default()
            },
        );
        let report = server.run_trace(&trace, 1e9).unwrap();
        assert_eq!(report.requests, 32);
        assert_eq!(report.tokens, trace.total_tokens());
        assert_eq!(report.lane_retirements, report.lane_admissions);
    }

    #[test]
    fn report_populates_wall_clock_histograms() {
        // The two-clock split: every completed request lands in the
        // end-to-end and first-token histograms, and multi-token
        // requests land in the per-token cadence histogram.
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(8, 2000.0, 6, VOCAB, 21);
        let server = Server::new(&lm, Some(&stats), ServerConfig::default());
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.latency.count(), 8);
        assert_eq!(report.first_token_latency.count(), 8);
        assert!(report.per_token_latency.count() > 0);
        for p in [50.0, 95.0, 99.0] {
            assert!(report.first_token_latency.percentile(p) >= 0.0);
            assert!(report.per_token_latency.percentile(p) >= 0.0);
            // First token cannot land after the end of the stream.
            assert!(
                report.first_token_latency.percentile(p)
                    <= report.latency.percentile(p) + 1e-9
            );
        }
    }

    #[test]
    fn state_budget_hibernates_and_report_accounts_bytes() {
        let lm = tiny_lm();
        let stats = calib(&lm);
        // 24 distinct sessions against a budget of 4 sessions' state:
        // hibernation must engage on both workers.
        let trace = RequestTrace::generate(24, 1_000_000.0, 8, VOCAB, 17);
        let server_probe = Server::new(&lm, Some(&stats), ServerConfig::default());
        let sb = server_probe.registry().state_bytes(0);
        let budget = 4 * sb;
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig {
                workers: 2,
                batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                state_budget: Some(budget),
                ..ServerConfig::default()
            },
        );
        let report = server.run_trace(&trace, 1e9).unwrap();
        assert_eq!(report.requests, 24);
        assert!(report.spills > 0, "budget pressure must spill");
        assert!(report.peak_resident_state_bytes <= budget);
        for w in &report.per_worker {
            assert!(w.peak_resident_state_bytes <= budget, "worker {}", w.worker);
        }
        // Cold-tier population is exactly the unrestored spills, and
        // the byte totals are live: hot + cold partition the sessions.
        let m = &report.per_model[0];
        assert_eq!(m.hibernated_sessions, report.spills - report.restores);
        assert_eq!(m.resident_sessions + m.hibernated_sessions, 24);
        assert_eq!(report.resident_state_bytes, m.resident_sessions * sb);
        // Exact codec: each cold image is exactly the hot state size.
        assert_eq!(report.hibernated_state_bytes, m.hibernated_sessions * sb);
        assert!(report.evictions == 0, "spills must not count as evictions");
    }

    #[test]
    #[should_panic(expected = "integer engine needs calibration stats")]
    fn integer_without_stats_panics() {
        let lm = tiny_lm();
        let _ = Server::new(&lm, None, ServerConfig::default());
    }
}
