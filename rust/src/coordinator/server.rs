//! The streaming serving loop: a sharded worker pool with work
//! stealing, each worker owning an engine instance, its sessions, and
//! one persistent continuously-batched wave; open-loop trace replay
//! with end-to-end latency accounting.
//!
//! Execution is batch-major and *continuously batched*: each worker
//! runs one persistent wave through a [`ContinuousScheduler`] — newly
//! arrived sessions are admitted into free lanes between token
//! positions, every step advances all live lanes through a single
//! batched stack step (one int8 GEMM per gate instead of per-session
//! matvecs), and lanes whose items finish are scattered back to their
//! sessions and compacted out so the GEMM only ever touches live rows.
//!
//! Ingest is sharded: the driver hash-routes each request's session to
//! a home queue on the shared [`ShardRouter`]; workers drain their own
//! queue between token positions, and a worker that runs dry *steals*
//! whole unbound sessions from the most-backlogged peer, so occupancy
//! survives skewed session routing. A worker only ingests up to its
//! free lane capacity, which deliberately leaves overload in the shared
//! queue where peers can take it. The PR 1 wave-at-a-time discipline is
//! kept as [`SchedulerMode::Wave`] for A/B comparison, and
//! `steal: false` reproduces static sticky routing.

use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use anyhow::Result;

use crate::eval::metrics::LatencyStats;
use crate::lstm::{CalibrationStats, QuantizeOptions, StackEngine};
use crate::model::lm::CharLm;
use crate::workload::synth::RequestTrace;
use super::batcher::BatchPolicy;
use super::metrics::{ServingReport, WorkerLoad};
use super::router::{ShardPoll, ShardRouter};
use super::scheduler::{ContinuousScheduler, SchedulerMode, SchedulerStats, StreamItem};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Worker (shard) count; each worker owns one persistent wave.
    pub workers: usize,
    /// Batch policy. Only `max_batch` is consulted by the server: it
    /// bounds the live lanes per worker wave (and how many items one
    /// ingest pull may take). `max_wait` is a [`Batcher`] dial with no
    /// effect on this path — sharded ingest is non-blocking between
    /// token positions.
    ///
    /// [`Batcher`]: super::batcher::Batcher
    pub batch: BatchPolicy,
    /// Execution engine for every worker.
    pub engine: StackEngine,
    /// Quantization options used to build the engine.
    pub opts: QuantizeOptions,
    /// Scheduling discipline (continuous batching by default).
    pub mode: SchedulerMode,
    /// Work stealing between workers (on by default; off reproduces
    /// static sticky routing).
    pub steal: bool,
    /// Per-worker cap on resident sessions (`None` = unbounded). The
    /// longest-seen idle sessions are evicted between token positions;
    /// sessions holding or awaiting a lane are never evicted.
    pub session_budget: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            engine: StackEngine::Integer,
            opts: QuantizeOptions::default(),
            mode: SchedulerMode::Continuous,
            steal: true,
            session_budget: None,
        }
    }
}

/// Completion record sent back to the driver.
struct Completion {
    latency_ms: f64,
    tokens: usize,
    nll_bits_total: f64,
}

/// Per-worker execution summary.
struct WorkerSummary {
    compute_secs: f64,
    batches: usize,
    items: usize,
    stats: SchedulerStats,
}

/// The server: binds a model + engine choice to a worker pool.
pub struct Server<'a> {
    lm: &'a CharLm,
    stats: Option<&'a [CalibrationStats]>,
    /// The pool configuration the server runs with.
    pub config: ServerConfig,
}

impl<'a> Server<'a> {
    /// Bind a model (and, for the integer engine, its calibration
    /// stats) to a pool configuration.
    pub fn new(
        lm: &'a CharLm,
        stats: Option<&'a [CalibrationStats]>,
        config: ServerConfig,
    ) -> Self {
        if config.engine == StackEngine::Integer {
            assert!(stats.is_some(), "integer engine needs calibration stats");
        }
        Server { lm, stats, config }
    }

    /// Replay a trace open-loop (arrival times compressed by
    /// `speedup`), return the serving report.
    pub fn run_trace(&self, trace: &RequestTrace, speedup: f64) -> Result<ServingReport> {
        let router = ShardRouter::new(self.config.workers, self.config.steal);
        let (done_tx, done_rx) = channel::<Completion>();
        let engine_label = self.config.engine.label();

        let wall_start = Instant::now();
        let summaries: Vec<WorkerSummary> = std::thread::scope(|scope| {
            let router = &router;
            let mut handles = Vec::new();
            for w in 0..self.config.workers {
                let done: Sender<Completion> = done_tx.clone();
                let lm = self.lm;
                let stats = self.stats;
                let engine_kind = self.config.engine;
                let opts = self.config.opts;
                let mode = self.config.mode;
                let max_lanes = self.config.batch.max_batch;
                let session_budget = self.config.session_budget;
                handles.push(scope.spawn(move || {
                    let engine = lm.engine(engine_kind, stats, opts);
                    let mut sched =
                        ContinuousScheduler::with_mode(&engine, max_lanes, mode);
                    let mut compute_secs = 0f64;
                    let mut batches = 0usize;
                    let mut items = 0usize;
                    loop {
                        // Ingest up to the free lane capacity: backlog
                        // beyond it stays in the shared queue, where an
                        // idle peer can steal it.
                        let capacity = max_lanes
                            .saturating_sub(sched.live_lanes() + sched.pending_len());
                        let mut closed = false;
                        if capacity > 0 {
                            match router.poll(w, capacity) {
                                ShardPoll::Items(new)
                                | ShardPoll::Stolen { items: new, .. } => {
                                    batches += 1;
                                    for item in new {
                                        items += 1;
                                        sched.offer(item);
                                    }
                                }
                                ShardPoll::Empty => {
                                    if !sched.has_live_work() {
                                        // Fully idle: block until there
                                        // is something to drain, steal,
                                        // or shut down for.
                                        router.wait_for_work(w);
                                        continue;
                                    }
                                }
                                ShardPoll::Closed => closed = true,
                            }
                        }
                        if !sched.has_live_work() {
                            if closed {
                                break;
                            }
                            continue;
                        }
                        let t0 = Instant::now();
                        sched.admit_ready();
                        sched.step();
                        compute_secs += t0.elapsed().as_secs_f64();
                        if let Some(budget) = session_budget {
                            sched.enforce_session_budget(
                                budget,
                                &router.queued_sessions(w),
                            );
                        }
                        for c in sched.take_completed() {
                            let _ = done.send(Completion {
                                latency_ms: c.latency_ms,
                                tokens: c.tokens,
                                nll_bits_total: c.nll_bits,
                            });
                        }
                    }
                    WorkerSummary {
                        compute_secs,
                        batches,
                        items,
                        stats: sched.stats(),
                    }
                }));
            }
            drop(done_tx);

            // Open-loop submission on the driver thread.
            let t0 = Instant::now();
            for req in &trace.requests {
                let target =
                    std::time::Duration::from_secs_f64(req.arrival_ms / 1000.0 / speedup);
                let now = t0.elapsed();
                if target > now {
                    std::thread::sleep(target - now);
                }
                router.submit(StreamItem {
                    session: req.id,
                    tokens: req.tokens.clone(),
                    submitted: Instant::now(),
                });
            }
            router.close();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        let wall_secs = wall_start.elapsed().as_secs_f64();

        let mut latency = LatencyStats::new();
        let mut tokens = 0usize;
        let mut requests = 0usize;
        let mut _total_nll = 0f64;
        for c in done_rx.iter() {
            latency.record(c.latency_ms);
            tokens += c.tokens;
            requests += 1;
            _total_nll += c.nll_bits_total;
        }
        let steal_events = router.steal_events();
        let stolen_sessions = router.stolen_sessions();
        let per_worker: Vec<WorkerLoad> = summaries
            .iter()
            .enumerate()
            .map(|(i, s)| WorkerLoad {
                worker: i,
                batched_steps: s.stats.batched_steps,
                lane_steps: s.stats.lane_steps,
                padded_lane_steps: s.stats.padded_lane_steps,
                peak_lanes: s.stats.peak_lanes,
                admissions: s.stats.admissions,
                retirements: s.stats.retirements,
                steal_events: steal_events[i],
                stolen_sessions: stolen_sessions[i],
                evictions: s.stats.evictions,
            })
            .collect();
        let compute_secs: f64 = summaries.iter().map(|s| s.compute_secs).sum();
        let batches: usize = summaries.iter().map(|s| s.batches).sum();
        let items: usize = summaries.iter().map(|s| s.items).sum();
        let batched_steps: usize = summaries.iter().map(|s| s.stats.batched_steps).sum();
        let lane_steps: usize = summaries.iter().map(|s| s.stats.lane_steps).sum();
        let padded_lane_steps: usize =
            summaries.iter().map(|s| s.stats.padded_lane_steps).sum();
        let peak_lanes: usize =
            summaries.iter().map(|s| s.stats.peak_lanes).max().unwrap_or(0);
        let lane_admissions: usize = summaries.iter().map(|s| s.stats.admissions).sum();
        let lane_retirements: usize =
            summaries.iter().map(|s| s.stats.retirements).sum();
        let admission_wait_ms: f64 =
            summaries.iter().map(|s| s.stats.admission_wait_ms).sum();
        let evictions: usize = summaries.iter().map(|s| s.stats.evictions).sum();

        Ok(ServingReport {
            engine: engine_label,
            mode: self.config.mode.label(),
            requests,
            tokens,
            wall_secs,
            compute_secs,
            latency,
            workers: self.config.workers,
            mean_batch: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            batched_steps,
            lane_steps,
            padded_lane_steps,
            peak_lanes,
            lane_admissions,
            lane_retirements,
            mean_admission_ms: if lane_admissions == 0 {
                0.0
            } else {
                admission_wait_ms / lane_admissions as f64
            },
            steals: stolen_sessions.iter().sum(),
            evictions,
            per_worker,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{LstmSpec, StackWeights};
    use crate::model::lm::{one_hot_seq, VOCAB};
    use crate::tensor::Matrix;
    use crate::util::Pcg32;
    use std::time::Duration;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(31);
        let spec = LstmSpec::plain(VOCAB, 24);
        let stack_weights = StackWeights::random(VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(VOCAB, 24);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm { stack_weights, out_w, out_b: vec![0.0; VOCAB], hidden: 24, depth: 1 }
    }

    fn calib(lm: &CharLm) -> Vec<crate::lstm::CalibrationStats> {
        let mut rng = Pcg32::seeded(32);
        let seqs: Vec<Vec<usize>> = (0..4)
            .map(|_| (0..24).map(|_| rng.below(VOCAB as u32) as usize).collect())
            .collect();
        let oh: Vec<_> = seqs.iter().map(|s| one_hot_seq(s)).collect();
        lm.stack_weights.calibrate(&oh)
    }

    #[test]
    fn serves_trace_on_all_engines_and_modes() {
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(24, 1000.0, 12, VOCAB, 3);
        for mode in [SchedulerMode::Continuous, SchedulerMode::Wave] {
            for engine in StackEngine::ALL {
                let config = ServerConfig {
                    workers: 2,
                    batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                    engine,
                    opts: QuantizeOptions::default(),
                    mode,
                    steal: true,
                    session_budget: None,
                };
                let server = Server::new(&lm, Some(&stats), config);
                let report = server.run_trace(&trace, 1000.0).unwrap();
                assert_eq!(report.requests, 24, "{engine:?} {mode:?}");
                assert_eq!(report.tokens, trace.total_tokens());
                assert_eq!(report.lane_retirements, report.lane_admissions);
                assert!(
                    report.padded_lane_steps >= report.lane_steps,
                    "physical width below live width"
                );
                assert_eq!(report.per_worker.len(), 2);
                assert!(report.latency.percentile(50.0) >= 0.0);
                assert!(report.throughput() > 0.0);
                assert!(report.compute_secs > 0.0);
            }
        }
    }

    #[test]
    fn sticky_sessions_accumulate_state() {
        // Two requests with the same session id must be processed by
        // the same worker against the same recurrent state.
        let lm = tiny_lm();
        let stats = calib(&lm);
        let mut trace = RequestTrace::generate(2, 10_000.0, 8, VOCAB, 4);
        trace.requests[1].id = trace.requests[0].id; // same session
        let server = Server::new(&lm, Some(&stats), ServerConfig::default());
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.requests, 2);
    }

    #[test]
    fn single_worker_reports_no_steals() {
        let lm = tiny_lm();
        let stats = calib(&lm);
        let trace = RequestTrace::generate(12, 2000.0, 8, VOCAB, 6);
        let server = Server::new(
            &lm,
            Some(&stats),
            ServerConfig { workers: 1, ..ServerConfig::default() },
        );
        let report = server.run_trace(&trace, 1000.0).unwrap();
        assert_eq!(report.requests, 12);
        assert_eq!(report.steals, 0);
        assert_eq!(report.per_worker.len(), 1);
        assert_eq!(report.per_worker[0].lane_steps, report.lane_steps);
    }

    #[test]
    #[should_panic(expected = "integer engine needs calibration stats")]
    fn integer_without_stats_panics() {
        let lm = tiny_lm();
        let _ = Server::new(&lm, None, ServerConfig::default());
    }
}
