//! Bounded micro-batching with a latency deadline.
//!
//! Workers drain their queue into batches of at most `max_batch` items,
//! waiting at most `max_wait` for stragglers once the first item is in
//! hand — the standard throughput/latency dial of serving systems.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pull-side batcher over an mpsc receiver.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy }
    }

    /// Block until at least one item, then gather up to `max_batch`
    /// within the deadline. Returns `None` when the channel closed and
    /// is drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let first = match self.rx.recv() {
            Ok(item) => item,
            Err(_) => return None,
        };
        let mut batch = Vec::with_capacity(self.policy.max_batch);
        batch.push(first);
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            // Fast path: drain without waiting.
            match self.rx.try_recv() {
                Ok(item) => {
                    batch.push(item);
                    continue;
                }
                Err(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        assert_eq!(b.next_batch().unwrap().len(), 8);
        assert_eq!(b.next_batch().unwrap().len(), 8);
        assert_eq!(b.next_batch().unwrap().len(), 4);
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn waits_for_stragglers_within_deadline() {
        let (tx, rx) = channel();
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
        );
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
            // Hold the channel open past the deadline.
            std::thread::sleep(Duration::from_millis(200));
            drop(tx);
        });
        let batch = b.next_batch().unwrap();
        assert!(batch.len() >= 3, "got {batch:?}");
        sender.join().unwrap();
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }
}
