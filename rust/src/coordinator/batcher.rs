//! Bounded micro-batching with a latency deadline.
//!
//! Workers drain their queue into batches of at most `max_batch` items,
//! waiting at most `max_wait` for stragglers once the first item is in
//! hand — the standard throughput/latency dial of serving systems.
//!
//! **Not on the serving path.** The sharded server ingests through
//! [`ShardRouter`](super::router::ShardRouter) (whose polls are
//! capacity-bounded by the same [`BatchPolicy::max_batch`]) and never
//! constructs a `Batcher`; in particular `max_wait` has **no effect**
//! on [`Server`](super::server::Server) runs — continuous ingest is
//! deliberately non-blocking between token positions. `Batcher` stays
//! as a tested, standalone single-queue ingest primitive (blocking
//! deadline batching over an mpsc channel) for embedders that drive a
//! [`ContinuousScheduler`](super::scheduler::ContinuousScheduler)
//! directly without the sharded router.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Result of a non-blocking [`Batcher::poll_batch`].
#[derive(Debug, PartialEq, Eq)]
pub enum Poll<T> {
    /// One or more items were waiting (at most `max_batch`).
    Items(Vec<T>),
    /// Nothing queued right now; the channel is still open.
    Empty,
    /// The channel is closed and fully drained.
    Closed,
}

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum items per batch — in the serving loop this also bounds
    /// the live lanes of each worker's wave.
    pub max_batch: usize,
    /// How long [`Batcher::next_batch`] waits for stragglers once the
    /// first item is in hand (ignored by the non-blocking paths).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pull-side batcher over an mpsc receiver.
pub struct Batcher<T> {
    rx: Receiver<T>,
    /// The batch formation policy this batcher drains under.
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    /// A batcher draining `rx` under `policy`.
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy }
    }

    /// Block until at least one item, then gather up to `max_batch`
    /// within the deadline. Returns `None` when the channel closed and
    /// is drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let first = match self.rx.recv() {
            Ok(item) => item,
            Err(_) => return None,
        };
        let mut batch = Vec::with_capacity(self.policy.max_batch);
        batch.push(first);
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            // Fast path: drain without waiting.
            match self.rx.try_recv() {
                Ok(item) => {
                    batch.push(item);
                    continue;
                }
                Err(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }

    /// Non-blocking drain: gather whatever is queued right now, up to
    /// `max_batch`, without waiting for stragglers. This is the
    /// continuous-batching ingest path — the worker calls it *between
    /// token positions* so newly-arrived sessions can join live waves
    /// instead of queueing behind a whole wave.
    pub fn poll_batch(&self) -> Poll<T> {
        let mut items = Vec::new();
        while items.len() < self.policy.max_batch {
            match self.rx.try_recv() {
                Ok(item) => items.push(item),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    return if items.is_empty() { Poll::Closed } else { Poll::Items(items) };
                }
            }
        }
        if items.is_empty() {
            Poll::Empty
        } else {
            Poll::Items(items)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        assert_eq!(b.next_batch().unwrap().len(), 8);
        assert_eq!(b.next_batch().unwrap().len(), 8);
        assert_eq!(b.next_batch().unwrap().len(), 4);
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn waits_for_stragglers_within_deadline() {
        let (tx, rx) = channel();
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) },
        );
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
            // Hold the channel open past the deadline.
            std::thread::sleep(Duration::from_millis(200));
            drop(tx);
        });
        let batch = b.next_batch().unwrap();
        assert!(batch.len() >= 3, "got {batch:?}");
        sender.join().unwrap();
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn next_batch_flushes_at_max_without_waiting_deadline() {
        // The max-batch flush trigger must fire immediately even under
        // an absurd deadline — if it waited, this test would hang.
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) },
        );
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2]);
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn poll_batch_is_non_blocking_on_empty_channel() {
        let (tx, rx) = channel::<u32>();
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(60) },
        );
        let t0 = Instant::now();
        assert_eq!(b.poll_batch(), Poll::Empty);
        // Never waits for the straggler deadline.
        assert!(t0.elapsed() < Duration::from_secs(5));
        drop(tx);
        assert_eq!(b.poll_batch(), Poll::Closed);
    }

    #[test]
    fn poll_batch_drains_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b.poll_batch(), Poll::Items(vec![0, 1, 2, 3]));
        assert_eq!(b.poll_batch(), Poll::Items(vec![4, 5, 6, 7]));
        assert_eq!(b.poll_batch(), Poll::Items(vec![8, 9]));
        assert_eq!(b.poll_batch(), Poll::Empty);
    }

    #[test]
    fn poll_batch_yields_remainder_then_closed() {
        let (tx, rx) = channel();
        tx.send(7u32).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert_eq!(b.poll_batch(), Poll::Items(vec![7, 8]));
        assert_eq!(b.poll_batch(), Poll::Closed);
        assert_eq!(b.poll_batch(), Poll::Closed);
    }
}
