//! Sticky session routing: a session's persistent LSTM state lives on
//! exactly one worker, so the router must map a given session id to the
//! same worker every time (consistent hashing over a fixed worker set).

use super::session::SessionId;

/// Maps sessions to workers.
#[derive(Debug, Clone)]
pub struct Router {
    workers: usize,
}

impl Router {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The worker owning `session` (SplitMix64 finalizer — uniform and
    /// stable across calls).
    pub fn route(&self, session: SessionId) -> usize {
        let mut z = session.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        (z % self.workers as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_sticky() {
        let r = Router::new(4);
        for id in 0..1000u64 {
            assert_eq!(r.route(id), r.route(id));
            assert!(r.route(id) < 4);
        }
    }

    #[test]
    fn routing_is_balanced() {
        let r = Router::new(4);
        let mut counts = [0usize; 4];
        for id in 0..10_000u64 {
            counts[r.route(id)] += 1;
        }
        for &c in &counts {
            assert!((2000..3000).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn single_worker_takes_all() {
        let r = Router::new(1);
        assert_eq!(r.route(123), 0);
    }
}
