//! Session routing: sticky hashing plus the sharded ingest queues with
//! work stealing that keep every worker's wave occupied.
//!
//! A stream's persistent LSTM state must live on exactly one worker
//! (streams are stateful), so routing must be *sticky* — and with the
//! model registry a stream is a `(model, session)` pair, so the sticky
//! unit is that key. Static hashing alone ([`Router`]) leaves occupancy
//! on the floor under skewed id distributions: one worker's queue backs
//! up while its peers idle. [`ShardRouter`] keeps the stickiness but
//! makes the *initial placement* negotiable: a session is hash-routed
//! to a **home** queue among the workers its model is resident on, and
//! only becomes **bound** to a worker when that worker first drains one
//! of its chunks — or when an idle worker *steals* it. Stealing moves
//! whole sessions (every queued chunk at once), only ever sessions no
//! worker has touched, only to thieves **where the session's model is
//! resident** (a worker without the weights cannot execute the work),
//! and binds them to the thief; from then on every future chunk of that
//! session follows the binding. The result: work moves, state never
//! does, and every session still executes its chunks in arrival order
//! on exactly one worker — which is what keeps the sharded path
//! bit-exact with the sequential one (locked down by
//! `rust/tests/sharded_serving.rs` and `rust/tests/multi_model.rs`).

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};

use super::registry::ModelId;
use super::scheduler::StreamItem;
use super::session::{SessionId, SessionKey};

/// The home worker a model-0 session id hashes to among `workers`
/// shards (kept as the stable single-model hash so traces can be
/// constructed to target a shard; see [`shard_home_model`]).
pub fn shard_home(session: SessionId, workers: usize) -> usize {
    shard_home_model(0, session, workers)
}

/// The home index a `(model, session)` key hashes to among `n` slots
/// (SplitMix64 finalizer over the model-mixed key — uniform and stable
/// across calls and processes). For model 0 this equals the historical
/// [`shard_home`] hash, so single-model traces keep their placement.
pub fn shard_home_model(model: ModelId, session: SessionId, n: usize) -> usize {
    let key = session ^ (model as u64).wrapping_mul(0xA24B_AED4_963E_E407);
    let mut z = key.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % n as u64) as usize
}

/// Static sticky routing: maps a session id to the same worker every
/// time, with no queues and no stealing. Kept as the baseline placement
/// function; the serving path proper uses [`ShardRouter`].
#[derive(Debug, Clone)]
pub struct Router {
    workers: usize,
}

impl Router {
    /// A router over a fixed worker set (`workers >= 1`).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Router { workers }
    }

    /// The worker count routed over.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The worker owning `session` (see [`shard_home`]).
    pub fn route(&self, session: SessionId) -> usize {
        shard_home(session, self.workers)
    }
}

/// Result of one non-blocking [`ShardRouter::poll`].
#[derive(Debug)]
pub enum ShardPoll {
    /// Items drained from the worker's own ingest queue, in arrival
    /// order. Their sessions are now bound to this worker.
    Items(Vec<StreamItem>),
    /// Whole sessions stolen from a backlogged peer's queue (every
    /// queued chunk of each stolen session, in their original order).
    /// The stolen sessions are now bound to the thief.
    Stolen {
        /// The stolen items.
        items: Vec<StreamItem>,
        /// The worker the items were stolen from.
        victim: usize,
    },
    /// Nothing available for this worker right now; ingest is open or
    /// peers still hold bound work of their own.
    Empty,
    /// Ingest is closed, this worker's queue is drained, and nothing
    /// anywhere is stealable: the worker may exit once its scheduler
    /// drains.
    Closed,
}

/// Everything mutable, under one lock: the per-worker queues, the
/// `(model, session)`→worker binding map, and the steal accounting.
struct ShardState {
    queues: Vec<VecDeque<StreamItem>>,
    /// A session appears here from the moment any worker drains or
    /// steals one of its chunks; bindings never change afterwards, so a
    /// session's chunks execute on exactly one worker, in order.
    bound: HashMap<SessionKey, usize>,
    closed: bool,
    /// Steal invocations per thief worker.
    steal_events: Vec<usize>,
    /// Sessions stolen per thief worker.
    stolen_sessions: Vec<usize>,
    /// Sessions stolen per model (indexed by [`ModelId`], grown on
    /// demand).
    stolen_by_model: Vec<usize>,
    /// Items re-queued because their binding changed while queued
    /// (defensive path; cannot occur under the submit/steal protocol).
    forwards: usize,
}

/// The sharded ingest front of the multi-worker server: one queue per
/// worker, hash-homed submission over each model's resident worker
/// set, and a work-stealing drain path.
///
/// Invariants the router maintains (the basis of the sharded path's
/// bit-exactness):
///
/// 1. all queued chunks of an *unbound* session sit in its home queue,
///    in submission order;
/// 2. once bound, every chunk of a session is delivered to its bound
///    worker, in submission order;
/// 3. stealing only takes unbound sessions, only onto workers where
///    the session's model is resident, and takes every queued chunk of
///    a stolen session in one atomic move.
///
/// All operations are safe to call from any thread; the deterministic
/// shard simulators drive the same type single-threaded.
pub struct ShardRouter {
    workers: usize,
    steal: bool,
    /// Per-model sorted resident worker sets; `None` means every model
    /// is resident everywhere (the single-model configuration).
    residency: Option<Vec<Vec<usize>>>,
    state: Mutex<ShardState>,
    work: Condvar,
}

impl ShardRouter {
    /// A router over `workers` ingest queues with every model resident
    /// on every worker; `steal` enables the work-stealing drain path
    /// (off reproduces static sticky routing).
    pub fn new(workers: usize, steal: bool) -> Self {
        Self::build(workers, steal, None)
    }

    /// A router with an explicit per-model residency map (index =
    /// [`ModelId`]; each entry the sorted worker set holding that
    /// model's weights, as produced by
    /// [`ModelRegistry::residency`]). Sessions home only onto resident
    /// workers and steal only toward them.
    ///
    /// [`ModelRegistry::residency`]:
    ///     super::registry::ModelRegistry::residency
    pub fn with_residency(workers: usize, steal: bool, residency: Vec<Vec<usize>>) -> Self {
        for (m, ws) in residency.iter().enumerate() {
            assert!(!ws.is_empty(), "model {m} resident nowhere");
            assert!(
                ws.iter().all(|&w| w < workers),
                "model {m} residency names worker outside the pool"
            );
        }
        Self::build(workers, steal, Some(residency))
    }

    fn build(workers: usize, steal: bool, residency: Option<Vec<Vec<usize>>>) -> Self {
        assert!(workers > 0);
        ShardRouter {
            workers,
            steal,
            residency,
            state: Mutex::new(ShardState {
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                bound: HashMap::new(),
                closed: false,
                steal_events: vec![0; workers],
                stolen_sessions: vec![0; workers],
                stolen_by_model: Vec::new(),
                forwards: 0,
            }),
            work: Condvar::new(),
        }
    }

    /// The worker count routed over.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether the stealing drain path is enabled.
    pub fn steal_enabled(&self) -> bool {
        self.steal
    }

    /// Whether `model` is resident on `worker` under this router's
    /// residency map (always true without one).
    ///
    /// `model` must be registered in the residency map: asking about an
    /// unregistered model is a wiring bug (the caller is holding a
    /// [`ModelId`] the registry never issued), not a "not resident"
    /// answer, so it panics rather than silently reporting `false`.
    pub fn resident_on(&self, model: ModelId, worker: usize) -> bool {
        match &self.residency {
            None => true,
            Some(res) => {
                debug_assert!(
                    (model as usize) < res.len(),
                    "model {model} out of range: residency map covers {} model(s)",
                    res.len()
                );
                res[model as usize].contains(&worker)
            }
        }
    }

    /// The home queue a model-0 `session` hashes to (single-model
    /// convenience for [`Self::home_of`]).
    pub fn home(&self, session: SessionId) -> usize {
        self.home_of(0, session)
    }

    /// The home queue a `(model, session)` stream hashes to: a
    /// [`shard_home_model`] pick among the model's resident workers
    /// (its initial placement; the binding may move it once, at steal
    /// time).
    pub fn home_of(&self, model: ModelId, session: SessionId) -> usize {
        match &self.residency {
            None => shard_home_model(model, session, self.workers),
            Some(res) => {
                let ws = res
                    .get(model as usize)
                    .unwrap_or_else(|| panic!("model {model} not registered"));
                ws[shard_home_model(model, session, ws.len())]
            }
        }
    }

    /// Submit one item: appended to its stream's bound worker's queue
    /// if the stream is bound, else to its home queue. Panics after
    /// [`Self::close`].
    pub fn submit(&self, item: StreamItem) {
        let mut st = self.state.lock().expect("router lock");
        assert!(!st.closed, "submit after close");
        let target = st
            .bound
            .get(&(item.model, item.session))
            .copied()
            .unwrap_or_else(|| self.home_of(item.model, item.session));
        st.queues[target].push_back(item);
        drop(st);
        self.work.notify_all();
    }

    /// Close ingest: no further [`Self::submit`] calls may happen, and
    /// workers start observing [`ShardPoll::Closed`] once drained.
    pub fn close(&self) {
        self.state.lock().expect("router lock").closed = true;
        self.work.notify_all();
    }

    /// Non-blocking drain-or-steal for `worker`. `max_items` is the
    /// caller's free lane capacity: the own-queue drain takes at most
    /// that many items (backlog beyond it stays in the shared queue,
    /// where peers can steal it), and the steal path takes at most
    /// that many *sessions* — but a stolen session comes with **every**
    /// queued chunk it has, so a steal may return more items than
    /// `max_items` (the extra chunks could not have run elsewhere
    /// anyway; they queue behind the session's lane).
    ///
    /// Own queue first: drained items' streams are bound to `worker`.
    /// If the own queue yields nothing and stealing is enabled, whole
    /// unbound sessions **whose model is resident on this worker** are
    /// taken from the deepest peer queue holding any. With nothing to
    /// do, returns [`ShardPoll::Closed`] after [`Self::close`] (the
    /// worker may exit) or [`ShardPoll::Empty`] before it.
    pub fn poll(&self, worker: usize, max_items: usize) -> ShardPoll {
        assert!(worker < self.workers, "worker index");
        if max_items == 0 {
            return ShardPoll::Empty;
        }
        let mut guard = self.state.lock().expect("router lock");
        let st = &mut *guard;

        // Drain the worker's own queue, binding what it takes.
        let mut taken = Vec::new();
        while taken.len() < max_items {
            let Some(item) = st.queues[worker].pop_front() else { break };
            match st.bound.get(&(item.model, item.session)).copied() {
                Some(owner) if owner != worker => {
                    // Binding changed while queued (defensive; the
                    // submit/steal protocol never produces this).
                    st.forwards += 1;
                    st.queues[owner].push_back(item);
                }
                _ => {
                    st.bound.insert((item.model, item.session), worker);
                    taken.push(item);
                }
            }
        }
        if !taken.is_empty() {
            return ShardPoll::Items(taken);
        }

        // Own queue dry: steal whole unbound, resident-here sessions
        // from the deepest peer queue that holds any (queue depth
        // descending, ties by lowest index — deterministic for the
        // single-threaded simulator). Scanning one candidate victim at
        // a time keeps the common case O(one queue) instead of
        // pre-counting every peer's stealable items under the lock.
        if self.steal {
            let mut order: Vec<usize> =
                (0..self.workers).filter(|&w| w != worker).collect();
            order.sort_by_key(|&w| std::cmp::Reverse(st.queues[w].len()));
            for v in order {
                if st.queues[v].is_empty() {
                    break;
                }
                let mut chosen: Vec<SessionKey> = Vec::new();
                for it in st.queues[v].iter() {
                    let key = (it.model, it.session);
                    if st.bound.contains_key(&key)
                        || !self.resident_on(it.model, worker)
                        || chosen.contains(&key)
                    {
                        continue;
                    }
                    chosen.push(key);
                    if chosen.len() >= max_items {
                        break;
                    }
                }
                if chosen.is_empty() {
                    continue;
                }
                let mut items = Vec::new();
                let mut keep = VecDeque::with_capacity(st.queues[v].len());
                for it in st.queues[v].drain(..) {
                    if chosen.contains(&(it.model, it.session)) {
                        items.push(it);
                    } else {
                        keep.push_back(it);
                    }
                }
                st.queues[v] = keep;
                for &key in &chosen {
                    st.bound.insert(key, worker);
                    let m = key.0 as usize;
                    if st.stolen_by_model.len() <= m {
                        st.stolen_by_model.resize(m + 1, 0);
                    }
                    st.stolen_by_model[m] += 1;
                }
                st.steal_events[worker] += 1;
                st.stolen_sessions[worker] += chosen.len();
                return ShardPoll::Stolen { items, victim: v };
            }
        }

        if st.closed {
            ShardPoll::Closed
        } else {
            ShardPoll::Empty
        }
    }

    /// Block until `worker` plausibly has something to do: its own
    /// queue is non-empty, a peer holds a stealable resident-here
    /// session (when stealing is enabled), or ingest closed. May wake
    /// spuriously — callers re-[`Self::poll`] in a loop.
    pub fn wait_for_work(&self, worker: usize) {
        assert!(worker < self.workers, "worker index");
        let mut st = self.state.lock().expect("router lock");
        loop {
            if st.closed || !st.queues[worker].is_empty() {
                return;
            }
            if self.steal {
                let stealable = st.queues.iter().enumerate().any(|(w, q)| {
                    w != worker
                        && q.iter().any(|it| {
                            !st.bound.contains_key(&(it.model, it.session))
                                && self.resident_on(it.model, worker)
                        })
                });
                if stealable {
                    return;
                }
            }
            st = self.work.wait(st).expect("router lock");
        }
    }

    /// `(model, session)` keys with items currently queued for
    /// `worker`, deduplicated. The budget-eviction path protects
    /// these: their next chunk is already in flight, so dropping their
    /// state would reset the stream mid-flight (see
    /// [`ContinuousScheduler::enforce_session_budget`]).
    ///
    /// [`ContinuousScheduler::enforce_session_budget`]:
    ///     super::scheduler::ContinuousScheduler::enforce_session_budget
    pub fn queued_sessions(&self, worker: usize) -> Vec<SessionKey> {
        let st = self.state.lock().expect("router lock");
        let mut keys: Vec<SessionKey> =
            st.queues[worker].iter().map(|it| (it.model, it.session)).collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    /// Current depth of every ingest queue (backlog snapshot).
    pub fn backlogs(&self) -> Vec<usize> {
        let st = self.state.lock().expect("router lock");
        st.queues.iter().map(|q| q.len()).collect()
    }

    /// True when every ingest queue is empty.
    pub fn is_drained(&self) -> bool {
        let st = self.state.lock().expect("router lock");
        st.queues.iter().all(|q| q.is_empty())
    }

    /// The worker a `(model, session)` stream is bound to, if any
    /// worker has drained or stolen one of its chunks yet.
    pub fn owner(&self, model: ModelId, session: SessionId) -> Option<usize> {
        self.state
            .lock()
            .expect("router lock")
            .bound
            .get(&(model, session))
            .copied()
    }

    /// Steal invocations per worker (as thief).
    pub fn steal_events(&self) -> Vec<usize> {
        self.state.lock().expect("router lock").steal_events.clone()
    }

    /// Sessions stolen per worker (as thief).
    pub fn stolen_sessions(&self) -> Vec<usize> {
        self.state.lock().expect("router lock").stolen_sessions.clone()
    }

    /// Sessions stolen per model. Returns at least `n_models` entries
    /// (models with no steals report 0).
    pub fn stolen_by_model(&self, n_models: usize) -> Vec<usize> {
        let mut v = self.state.lock().expect("router lock").stolen_by_model.clone();
        if v.len() < n_models {
            v.resize(n_models, 0);
        }
        v
    }

    /// Items re-queued because their binding changed while queued
    /// (always 0 under the submit/steal protocol; exposed so tests can
    /// assert the defensive path never fires).
    pub fn forwards(&self) -> usize {
        self.state.lock().expect("router lock").forwards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn item(session: SessionId, tok: usize) -> StreamItem {
        StreamItem { model: 0, session, tokens: vec![tok], submitted: Instant::now() }
    }

    fn item_m(model: ModelId, session: SessionId, tok: usize) -> StreamItem {
        StreamItem { model, session, tokens: vec![tok], submitted: Instant::now() }
    }

    #[test]
    fn routing_is_sticky() {
        let r = Router::new(4);
        for id in 0..1000u64 {
            assert_eq!(r.route(id), r.route(id));
            assert!(r.route(id) < 4);
        }
    }

    #[test]
    fn routing_is_balanced() {
        let r = Router::new(4);
        let mut counts = [0usize; 4];
        for id in 0..10_000u64 {
            counts[r.route(id)] += 1;
        }
        for &c in &counts {
            assert!((2000..3000).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn model_mixing_moves_homes_but_preserves_model_zero() {
        // Model 0 keeps the historical single-model hash; other models
        // land elsewhere often enough to spread load.
        let mut moved = 0;
        for id in 0..1000u64 {
            assert_eq!(shard_home_model(0, id, 4), shard_home(id, 4));
            if shard_home_model(1, id, 4) != shard_home(id, 4) {
                moved += 1;
            }
        }
        assert!(moved > 500, "model mixing too weak: {moved}/1000");
    }

    #[test]
    fn single_worker_takes_all() {
        let r = Router::new(1);
        assert_eq!(r.route(123), 0);
    }

    #[test]
    fn submit_goes_home_then_follows_binding() {
        let router = ShardRouter::new(4, true);
        // Find an id homed on worker 2.
        let id = (0..).find(|&i| shard_home(i, 4) == 2).unwrap();
        router.submit(item(id, 1));
        assert_eq!(router.backlogs()[2], 1);
        assert_eq!(router.owner(0, id), None);
        // Worker 2 drains it and becomes the binding.
        match router.poll(2, 8) {
            ShardPoll::Items(v) => assert_eq!(v.len(), 1),
            other => panic!("expected Items, got {other:?}"),
        }
        assert_eq!(router.owner(0, id), Some(2));
        // The next chunk follows the binding, not the hash.
        router.submit(item(id, 2));
        assert_eq!(router.backlogs()[2], 1);
    }

    #[test]
    fn steal_takes_whole_unbound_sessions_and_rebinds() {
        let router = ShardRouter::new(2, true);
        let hot: Vec<u64> = (0..).filter(|&i| shard_home(i, 2) == 0).take(3).collect();
        // Session hot[0] gets two chunks; hot[1], hot[2] one each. All
        // land on worker 0's queue.
        router.submit(item(hot[0], 1));
        router.submit(item(hot[1], 1));
        router.submit(item(hot[0], 2));
        router.submit(item(hot[2], 1));
        assert_eq!(router.backlogs(), vec![4, 0]);

        // Worker 1 is idle: it steals up to 2 sessions — the two
        // earliest-queued unbound ones, hot[0] (both chunks) and hot[1].
        match router.poll(1, 2) {
            ShardPoll::Stolen { items, victim } => {
                assert_eq!(victim, 0);
                let ids: Vec<u64> = items.iter().map(|i| i.session).collect();
                assert_eq!(ids, vec![hot[0], hot[1], hot[0]]);
                // Chunk order within the stolen session is preserved.
                assert_eq!(items[0].tokens, vec![1]);
                assert_eq!(items[2].tokens, vec![2]);
            }
            other => panic!("expected Stolen, got {other:?}"),
        }
        assert_eq!(router.owner(0, hot[0]), Some(1));
        assert_eq!(router.owner(0, hot[1]), Some(1));
        assert_eq!(router.owner(0, hot[2]), None);
        assert_eq!(router.backlogs(), vec![1, 0]);
        assert_eq!(router.stolen_sessions(), vec![0, 2]);
        assert_eq!(router.steal_events(), vec![0, 1]);
        assert_eq!(router.stolen_by_model(1), vec![2]);

        // Future chunks of a stolen session follow the thief.
        router.submit(item(hot[0], 3));
        assert_eq!(router.backlogs(), vec![1, 1]);
        assert_eq!(router.forwards(), 0);
    }

    #[test]
    fn bound_sessions_are_never_stolen() {
        let router = ShardRouter::new(2, true);
        let id = (0..).find(|&i| shard_home(i, 2) == 0).unwrap();
        router.submit(item(id, 1));
        // Worker 0 drains (binds) the first chunk, then a second chunk
        // arrives while worker 0 is busy.
        match router.poll(0, 8) {
            ShardPoll::Items(v) => assert_eq!(v.len(), 1),
            other => panic!("expected Items, got {other:?}"),
        }
        router.submit(item(id, 2));
        // Worker 1 finds nothing stealable.
        match router.poll(1, 8) {
            ShardPoll::Empty => {}
            other => panic!("expected Empty, got {other:?}"),
        }
        assert_eq!(router.backlogs(), vec![1, 0]);
    }

    #[test]
    fn steal_disabled_reproduces_static_routing() {
        let router = ShardRouter::new(2, false);
        let id = (0..).find(|&i| shard_home(i, 2) == 0).unwrap();
        router.submit(item(id, 1));
        match router.poll(1, 8) {
            ShardPoll::Empty => {}
            other => panic!("expected Empty, got {other:?}"),
        }
        router.close();
        // Worker 1 may exit even though worker 0 still has a backlog.
        match router.poll(1, 8) {
            ShardPoll::Closed => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        // Worker 0 still drains its own queue after close.
        match router.poll(0, 8) {
            ShardPoll::Items(v) => assert_eq!(v.len(), 1),
            other => panic!("expected Items, got {other:?}"),
        }
        match router.poll(0, 8) {
            ShardPoll::Closed => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn residency_restricts_homes_and_steals() {
        // Model 0 lives only on worker 0, model 1 on workers 1 and 2.
        let router =
            ShardRouter::with_residency(3, true, vec![vec![0], vec![1, 2]]);
        for id in 0..20u64 {
            router.submit(item_m(0, id, 1));
            assert!(router.resident_on(0, 0));
            assert!(!router.resident_on(0, 1));
            assert_eq!(router.home_of(0, id), 0, "model 0 must home on worker 0");
            let h1 = router.home_of(1, id);
            assert!(h1 == 1 || h1 == 2, "model 1 must home on worker 1 or 2");
        }
        assert_eq!(router.backlogs()[0], 20);
        // Workers 1 and 2 are idle but must not steal model 0: its
        // weights are not resident there.
        for thief in [1usize, 2] {
            match router.poll(thief, 8) {
                ShardPoll::Empty => {}
                other => panic!("worker {thief}: expected Empty, got {other:?}"),
            }
        }
        assert_eq!(router.stolen_by_model(2), vec![0, 0]);
        // Model-1 backlog on worker 1 *is* stealable by worker 2.
        let id1 = (0..).find(|&i| router.home_of(1, i) == 1).unwrap();
        router.submit(item_m(1, id1, 1));
        match router.poll(2, 8) {
            ShardPoll::Stolen { items, victim } => {
                assert_eq!(victim, 1);
                assert_eq!(items.len(), 1);
                assert_eq!(items[0].model, 1);
            }
            other => panic!("expected Stolen, got {other:?}"),
        }
        assert_eq!(router.owner(1, id1), Some(2));
        assert_eq!(router.stolen_by_model(2), vec![0, 1]);
    }

    #[test]
    #[should_panic]
    fn resident_on_panics_for_unregistered_model() {
        // Two registered models: asking about model 5 is a wiring bug,
        // not a "not resident" answer.
        let router =
            ShardRouter::with_residency(3, true, vec![vec![0], vec![1, 2]]);
        let _ = router.resident_on(5, 0);
    }

    #[test]
    fn resident_on_in_range_false_is_a_legitimate_answer() {
        let router =
            ShardRouter::with_residency(3, true, vec![vec![0], vec![1, 2]]);
        assert!(router.resident_on(1, 2));
        assert!(!router.resident_on(1, 0), "registered but not on worker 0");
        // Without a residency map every model is everywhere.
        let open = ShardRouter::new(2, true);
        assert!(open.resident_on(9, 1));
    }

    #[test]
    fn same_session_id_under_two_models_binds_independently() {
        let router = ShardRouter::new(2, true);
        // Force both streams onto worker 0's queue via stealing-free
        // drain by worker 0 for model 0 only.
        let id = (0..).find(|&i| shard_home(i, 2) == 0).unwrap();
        router.submit(item_m(0, id, 1));
        let h = router.home_of(1, id);
        router.submit(item_m(1, id, 1));
        match router.poll(0, 1) {
            ShardPoll::Items(v) => {
                assert_eq!(v.len(), 1);
                assert_eq!(v[0].model, 0);
            }
            other => panic!("expected Items, got {other:?}"),
        }
        assert_eq!(router.owner(0, id), Some(0));
        // The model-1 stream is a different key: still unbound (or
        // bound elsewhere once its home drains it).
        assert_eq!(router.owner(1, id), None);
        match router.poll(h, 1) {
            ShardPoll::Items(v) => assert_eq!(v[0].model, 1),
            other => panic!("expected Items, got {other:?}"),
        }
        assert_eq!(router.owner(1, id), Some(h));
    }

    #[test]
    fn zero_capacity_polls_are_empty() {
        let router = ShardRouter::new(1, true);
        router.submit(item(7, 1));
        match router.poll(0, 0) {
            ShardPoll::Empty => {}
            other => panic!("expected Empty, got {other:?}"),
        }
        assert_eq!(router.backlogs(), vec![1]);
    }
}
