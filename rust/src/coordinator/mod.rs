//! The L3 serving coordinator: a streaming stateful-RNN server.
//!
//! The paper's quantization exists to serve *streaming* RNN workloads
//! (speech) on cheap hardware; what makes RNN serving distinctive — and
//! what this coordinator implements — is that every stream carries
//! persistent cell/hidden state across requests, so routing must be
//! *sticky* and batching must group steps, not requests:
//!
//! * [`session`] — per-stream persistent LSTM state with lifecycle;
//! * [`router`] — sticky hash routing of sessions onto workers;
//! * [`batcher`] — bounded micro-batching with a latency deadline,
//!   plus the non-blocking `poll_batch` continuous-batching ingest;
//! * [`scheduler`] — the continuous-batching lane scheduler (admit /
//!   retire / compact between token positions) and its deterministic
//!   virtual-time simulator;
//! * [`server`] — worker threads, each owning an engine instance and
//!   its sessions; open-loop trace replay with latency accounting;
//! * [`metrics`] — counters + the RT-factor / latency reports.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;

pub use batcher::{BatchPolicy, Batcher, Poll};
pub use metrics::ServingReport;
pub use router::Router;
pub use scheduler::{
    simulate_trace, ContinuousScheduler, SchedulerMode, SchedulerStats,
    StreamDone, StreamItem,
};
pub use server::{Server, ServerConfig};
pub use session::{Session, SessionId, SessionManager};
