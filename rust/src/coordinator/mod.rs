//! The L3 serving coordinator: a sharded streaming stateful-RNN
//! server.
//!
//! The paper's quantization exists to serve *streaming* RNN workloads
//! (speech) on cheap hardware; what makes RNN serving distinctive — and
//! what this coordinator implements — is that every stream carries
//! persistent cell/hidden state across requests, so routing must be
//! *sticky* and batching must group steps, not requests:
//!
//! * [`registry`] — the model registry: several quantized model
//!   variants (each with its own packed int8 weights, quantization
//!   recipe, and engine kind) sharded over one worker pool, with
//!   per-model residency and memory accounting;
//! * [`session`] — per-stream persistent LSTM state (keyed by
//!   `(model, session)`) with lifecycle, budget-driven eviction, and
//!   idle-age aging;
//! * [`hibernate`] — the byte-budgeted cold tier: idle sessions' state
//!   serialized exactly (or int8-quantized behind `--spill-quantized`),
//!   spilled coldest-first when a worker's resident-state byte budget
//!   is exceeded, restored transparently before lane admission;
//! * [`router`] — hash-homed session placement over sharded ingest
//!   queues (among each model's resident workers), with work stealing
//!   of untouched sessions so occupancy survives skewed routing;
//! * [`batcher`] — standalone bounded micro-batching with a latency
//!   deadline (not used by the sharded server; kept for embedders
//!   driving a scheduler directly);
//! * [`scheduler`] — the continuous-batching lane scheduler (admit /
//!   retire / compact between token positions; one wave per resident
//!   model, lanes never mixing models) plus the deterministic
//!   virtual-time simulators for one worker ([`simulate_trace`]), a
//!   whole stealing pool ([`simulate_shard_trace`]), and a multi-model
//!   pool ([`simulate_multi_shard_trace`]);
//! * [`server`] — the worker pool: per-resident-model engine
//!   instances, one session table, and one persistent wave per model
//!   per worker; open-loop trace replay with latency accounting;
//! * [`net`] — the wall-clock TCP front: a `std::net`
//!   thread-per-connection streaming server over the same pool, with
//!   a length-prefixed frame protocol, bounded admission (`Busy`
//!   backpressure), and graceful drain;
//! * [`trace`] — structured observability: a bounded per-worker
//!   lifecycle event ring behind an Off/Counters/Full level, merged
//!   into one deterministic virtual-step-ordered log, with per-stage
//!   duration histograms and Chrome-trace / JSONL export (tracing
//!   never perturbs schedules or token values);
//! * [`metrics`] — counters + the RT-factor / latency / occupancy /
//!   steal reports, with per-worker and per-model breakdowns.
//!
//! See `docs/SERVING.md` for the operator-facing guide (architecture,
//! CLI flags, report fields, tuning cookbook).

#![deny(missing_docs)]

pub mod batcher;
pub mod hibernate;
pub mod metrics;
pub mod net;
pub mod registry;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod trace;

pub use batcher::{BatchPolicy, Batcher, Poll};
pub use hibernate::{
    decode_state, dequantize_vec_i8, encode_state, quantize_vec_i8, ColdTier,
    SpillCodec,
};
pub use metrics::{ModelLoad, ServingReport, WorkerLoad};
pub use net::{
    read_frame, write_frame, Frame, NetClient, NetConfig, NetReport, NetServer,
    NetShutdown,
};
pub use registry::{ModelId, ModelRegistry, ModelSpec, Residency};
pub use router::{shard_home, shard_home_model, Router, ShardPoll, ShardRouter};
pub use scheduler::{
    simulate_multi_shard_trace, simulate_registry_trace, simulate_shard_trace,
    simulate_trace, ContinuousScheduler, SchedulerMode, SchedulerStats, ShardConfig,
    ShardSimReport, StreamDone, StreamItem, TokenEvent,
};
pub use server::{Server, ServerConfig};
pub use session::{Session, SessionId, SessionKey, SessionManager};
pub use trace::{
    chrome_trace_string, jsonl_string, merge_events, EventKind, StageLatencies,
    TraceConfig, TraceEvent, TraceLevel, TraceRing,
};
