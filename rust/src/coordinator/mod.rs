//! The L3 serving coordinator: a sharded streaming stateful-RNN
//! server.
//!
//! The paper's quantization exists to serve *streaming* RNN workloads
//! (speech) on cheap hardware; what makes RNN serving distinctive — and
//! what this coordinator implements — is that every stream carries
//! persistent cell/hidden state across requests, so routing must be
//! *sticky* and batching must group steps, not requests:
//!
//! * [`session`] — per-stream persistent LSTM state with lifecycle and
//!   budget-driven eviction;
//! * [`router`] — hash-homed session placement over sharded ingest
//!   queues, with work stealing of untouched sessions so occupancy
//!   survives skewed routing;
//! * [`batcher`] — standalone bounded micro-batching with a latency
//!   deadline (not used by the sharded server; kept for embedders
//!   driving a scheduler directly);
//! * [`scheduler`] — the continuous-batching lane scheduler (admit /
//!   retire / compact between token positions) plus the deterministic
//!   virtual-time simulators for one worker ([`simulate_trace`]) and a
//!   whole stealing pool ([`simulate_shard_trace`]);
//! * [`server`] — the worker pool: one engine instance, session table,
//!   and persistent wave per worker; open-loop trace replay with
//!   latency accounting;
//! * [`metrics`] — counters + the RT-factor / latency / occupancy /
//!   steal reports.
//!
//! See `docs/SERVING.md` for the operator-facing guide (architecture,
//! CLI flags, report fields, tuning cookbook).

#![deny(missing_docs)]

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;

pub use batcher::{BatchPolicy, Batcher, Poll};
pub use metrics::{ServingReport, WorkerLoad};
pub use router::{shard_home, Router, ShardPoll, ShardRouter};
pub use scheduler::{
    simulate_shard_trace, simulate_trace, ContinuousScheduler, SchedulerMode,
    SchedulerStats, ShardConfig, ShardSimReport, StreamDone, StreamItem,
};
pub use server::{Server, ServerConfig};
pub use session::{Session, SessionId, SessionManager};
