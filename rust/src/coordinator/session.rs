//! Per-stream sessions: the persistent LSTM state that makes RNN
//! serving stateful (and quantization "numerically challenging" — the
//! state carries quantization error across invocations).
//!
//! With the model registry, a stream is identified by a
//! [`SessionKey`] = `(model, session)` pair: the same session id under
//! two models is two independent streams with two independent states.
//! The table also carries a **logical activity clock** (ticked once per
//! batched token position by the scheduler) so sessions can be aged out
//! by idle *time*, not just stream length.

use std::collections::HashMap;

use crate::model::lm::{CharLmEngine, LmState};
use super::registry::ModelId;

/// Identifier of one stream within a model; routing and session tables
/// key on it together with the [`ModelId`].
pub type SessionId = u64;

/// Full identity of a stream: the model it runs under plus its session
/// id. Binding, eviction, and protection sets all operate on this key.
pub type SessionKey = (ModelId, SessionId);

/// One live stream.
pub struct Session {
    /// The model this stream runs under.
    pub model: ModelId,
    /// The stream's id (unique within its model).
    pub id: SessionId,
    /// The persistent recurrent state (cell/hidden per layer plus the
    /// last hidden/logits scratch).
    pub state: LmState,
    /// Tokens processed so far (stream position).
    pub tokens_seen: usize,
    /// Accumulated negative log2-likelihood (quality accounting).
    pub nll_bits: f64,
    /// Logical-clock value of the last admission or retirement touching
    /// this stream (see [`SessionManager::tick`]).
    pub last_active: u64,
}

impl Session {
    /// A fresh session with the engine's zero state.
    pub fn new(model: ModelId, id: SessionId, engine: &CharLmEngine) -> Self {
        Session {
            model,
            id,
            state: engine.new_state(),
            tokens_seen: 0,
            nll_bits: 0.0,
            last_active: 0,
        }
    }

    /// The session's full `(model, session)` key.
    pub fn key(&self) -> SessionKey {
        (self.model, self.id)
    }

    /// Mean bits-per-char over the stream so far.
    pub fn bits_per_char(&self) -> f64 {
        if self.tokens_seen <= 1 {
            return f64::NAN;
        }
        self.nll_bits / (self.tokens_seen - 1) as f64
    }
}

/// Session table for one worker, spanning every model resident there.
#[derive(Default)]
pub struct SessionManager {
    sessions: HashMap<SessionKey, Session>,
    created: u64,
    evicted: u64,
    clock: u64,
}

impl SessionManager {
    /// An empty session table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance the logical activity clock one tick (the scheduler calls
    /// this once per batched token position) and return the new value.
    pub fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Current logical-clock value.
    pub fn now(&self) -> u64 {
        self.clock
    }

    /// Get or create the session (sticky: a given `(model, id)` always
    /// lives on the worker the router chose for it). Marks the session
    /// active at the current clock.
    pub fn get_or_create(
        &mut self,
        model: ModelId,
        id: SessionId,
        engine: &CharLmEngine,
    ) -> &mut Session {
        let key = (model, id);
        if !self.sessions.contains_key(&key) {
            self.created += 1;
            self.sessions.insert(key, Session::new(model, id, engine));
        }
        let s = self.sessions.get_mut(&key).unwrap();
        s.last_active = self.clock;
        s
    }

    /// Look up a model-0 session without creating it (single-model
    /// convenience; see [`Self::get_model`]).
    pub fn get(&self, id: SessionId) -> Option<&Session> {
        self.get_model(0, id)
    }

    /// Look up a session of a specific model without creating it.
    pub fn get_model(&self, model: ModelId, id: SessionId) -> Option<&Session> {
        self.sessions.get(&(model, id))
    }

    /// Remove one session, returning it (counts as an eviction).
    pub fn remove(&mut self, model: ModelId, id: SessionId) -> Option<Session> {
        let s = self.sessions.remove(&(model, id));
        if s.is_some() {
            self.evicted += 1;
        }
        s
    }

    /// Remove one session *without* counting it as an eviction — the
    /// hibernation spill path: the stream is not dropped, its state
    /// moves to the cold tier and comes back via [`Self::insert`].
    pub fn take(&mut self, model: ModelId, id: SessionId) -> Option<Session> {
        self.sessions.remove(&(model, id))
    }

    /// Re-insert a previously [`Self::take`]n session *without*
    /// counting a creation — the hibernation restore path. The session
    /// keeps its own `last_active`; the next `get_or_create` touch
    /// refreshes it.
    pub fn insert(&mut self, s: Session) {
        self.sessions.insert(s.key(), s);
    }

    /// Resident keys coldest-first: sorted by `(last_active, model,
    /// id)` ascending, skipping keys in `protected` — the spill order
    /// of the byte-budget enforcement. Like the eviction paths, a pure
    /// function of the table contents (no hash-iteration
    /// nondeterminism).
    pub fn coldest_first(&self, protected: &[SessionKey]) -> Vec<SessionKey> {
        let mut keys: Vec<(u64, ModelId, SessionId)> = self
            .sessions
            .values()
            .filter(|s| !protected.contains(&s.key()))
            .map(|s| (s.last_active, s.model, s.id))
            .collect();
        keys.sort_unstable();
        keys.into_iter().map(|(_, m, i)| (m, i)).collect()
    }

    /// Number of resident sessions across all models.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    /// Number of resident sessions of one model.
    pub fn len_model(&self, model: ModelId) -> usize {
        self.sessions.values().filter(|s| s.model == model).count()
    }

    /// True when no session is resident.
    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Sessions ever created on this table.
    pub fn created(&self) -> u64 {
        self.created
    }

    /// Sessions ever removed or evicted from this table.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Evict sessions beyond a count budget (memory pressure control;
    /// state is the dominant per-stream cost). Returns how many
    /// sessions were evicted.
    pub fn evict_longest(&mut self, keep_at_most: usize) -> usize {
        self.evict_longest_protected(keep_at_most, &[]).len()
    }

    /// Evict the longest-seen sessions until at most `keep_at_most`
    /// remain, never touching keys in `protected` (the serving loop
    /// passes the sessions currently holding a lane or queued for one —
    /// their state is live in a wave and must not be dropped). The
    /// resident count can therefore stay above the budget while many
    /// lanes are live.
    ///
    /// Eviction order is a pure function of the table contents: sort by
    /// `(tokens_seen, model, id)` descending, so ties break by key and
    /// repeated runs evict identical sets — no hash-iteration
    /// nondeterminism. Returns the evicted keys in eviction order.
    pub fn evict_longest_protected(
        &mut self,
        keep_at_most: usize,
        protected: &[SessionKey],
    ) -> Vec<SessionKey> {
        if self.sessions.len() <= keep_at_most {
            return Vec::new();
        }
        let mut keys: Vec<(usize, ModelId, SessionId)> = self
            .sessions
            .values()
            .filter(|s| !protected.contains(&s.key()))
            .map(|s| (s.tokens_seen, s.model, s.id))
            .collect();
        keys.sort_unstable_by(|a, b| b.cmp(a));
        let over = self.sessions.len() - keep_at_most;
        let mut out = Vec::with_capacity(over.min(keys.len()));
        for &(_, model, id) in keys.iter().take(over) {
            self.sessions.remove(&(model, id));
            self.evicted += 1;
            out.push((model, id));
        }
        out
    }

    /// Evict every session idle for *more than* `max_idle` clock ticks
    /// (the idle-age policy: `now - last_active > max_idle`), never
    /// touching keys in `protected`. Oldest activity goes first, ties
    /// broken by `(model, id)` ascending — like the length-based path,
    /// a pure function of the table contents. Returns the evicted keys
    /// in eviction order.
    pub fn evict_idle_protected(
        &mut self,
        max_idle: u64,
        protected: &[SessionKey],
    ) -> Vec<SessionKey> {
        let now = self.clock;
        let mut victims: Vec<(u64, ModelId, SessionId)> = self
            .sessions
            .values()
            .filter(|s| !protected.contains(&s.key()))
            .filter(|s| now.saturating_sub(s.last_active) > max_idle)
            .map(|s| (s.last_active, s.model, s.id))
            .collect();
        victims.sort_unstable();
        let mut out = Vec::with_capacity(victims.len());
        for &(_, model, id) in &victims {
            self.sessions.remove(&(model, id));
            self.evicted += 1;
            out.push((model, id));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{QuantizeOptions, StackEngine};
    use crate::lstm::{LstmSpec, StackWeights};
    use crate::model::lm::CharLm;
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(5);
        let spec = LstmSpec::plain(crate::model::lm::VOCAB, 16);
        let stack_weights = StackWeights::random(crate::model::lm::VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(crate::model::lm::VOCAB, 16);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm {
            stack_weights,
            out_w,
            out_b: vec![0.0; crate::model::lm::VOCAB],
            hidden: 16,
            depth: 1,
        }
    }

    #[test]
    fn session_lifecycle() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        assert!(mgr.is_empty());
        {
            let s = mgr.get_or_create(0, 42, &engine);
            assert_eq!(s.id, 42);
            s.tokens_seen = 10;
        }
        // Sticky: same key returns the same state.
        assert_eq!(mgr.get_or_create(0, 42, &engine).tokens_seen, 10);
        assert_eq!(mgr.len(), 1);
        assert_eq!(mgr.created(), 1);
        assert!(mgr.remove(0, 42).is_some());
        assert!(mgr.remove(0, 42).is_none());
        assert_eq!(mgr.evicted(), 1);
    }

    #[test]
    fn same_id_under_two_models_is_two_streams() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        mgr.get_or_create(0, 7, &engine).tokens_seen = 5;
        mgr.get_or_create(1, 7, &engine).tokens_seen = 9;
        assert_eq!(mgr.len(), 2);
        assert_eq!(mgr.len_model(0), 1);
        assert_eq!(mgr.len_model(1), 1);
        assert_eq!(mgr.get_model(0, 7).unwrap().tokens_seen, 5);
        assert_eq!(mgr.get_model(1, 7).unwrap().tokens_seen, 9);
    }

    #[test]
    fn state_persists_across_steps() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        let s = mgr.get_or_create(0, 1, &engine);
        engine.step_token(3, &mut s.state);
        let logits_after_one = s.state.logits.clone();
        engine.step_token(3, &mut s.state);
        // Recurrent state changed the prediction for the same input.
        assert_ne!(logits_after_one, s.state.logits);
    }

    #[test]
    fn eviction_order_is_deterministic_on_ties() {
        // Equal stream lengths: the (tokens_seen, model, id) sort breaks
        // ties by key descending, so eviction is a pure function of the
        // table contents — no hash-iteration nondeterminism.
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        for _ in 0..2 {
            let mut mgr = SessionManager::new();
            for id in 0..10u64 {
                mgr.get_or_create(0, id, &engine).tokens_seen = 5;
            }
            assert_eq!(mgr.evict_longest(7), 3);
            // Highest ids evicted first on ties.
            for id in 0..7u64 {
                assert!(mgr.get(id).is_some(), "id {id} wrongly evicted");
            }
            for id in 7..10u64 {
                assert!(mgr.get(id).is_none(), "id {id} wrongly kept");
            }
        }
    }

    #[test]
    fn eviction_removes_longest() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        for id in 0..10u64 {
            let s = mgr.get_or_create(0, id, &engine);
            s.tokens_seen = id as usize * 100;
        }
        let evicted = mgr.evict_longest(6);
        assert_eq!(evicted, 4);
        assert_eq!(mgr.len(), 6);
        // The longest streams (ids 6..9) are gone.
        assert!(mgr.get_or_create(0, 0, &engine).tokens_seen == 0);
    }

    #[test]
    fn protected_sessions_survive_eviction() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        for id in 0..6u64 {
            mgr.get_or_create(0, id, &engine).tokens_seen = id as usize * 10;
        }
        // Protect the two longest: eviction must fall through to the
        // next-longest unprotected sessions.
        let evicted = mgr.evict_longest_protected(2, &[(0, 5), (0, 4)]);
        assert_eq!(evicted, vec![(0, 3), (0, 2), (0, 1), (0, 0)]);
        assert_eq!(mgr.len(), 2);
        assert!(mgr.get(5).is_some());
        assert!(mgr.get(4).is_some());
        // With everything protected, nothing is evicted even over
        // budget.
        let evicted = mgr.evict_longest_protected(0, &[(0, 5), (0, 4)]);
        assert!(evicted.is_empty());
        assert_eq!(mgr.len(), 2);
    }

    #[test]
    fn idle_eviction_ages_out_by_activity_clock() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        mgr.get_or_create(0, 1, &engine); // active at t=0
        mgr.tick();
        mgr.tick();
        mgr.get_or_create(0, 2, &engine); // active at t=2
        mgr.tick(); // now = 3: idle ages are 3 and 1
        // Threshold 2: only session 1 (idle 3 > 2) goes.
        assert_eq!(mgr.evict_idle_protected(2, &[]), vec![(0, 1)]);
        assert!(mgr.get(2).is_some());
        // Threshold 0: session 2 (idle 1 > 0) goes too.
        assert_eq!(mgr.evict_idle_protected(0, &[]), vec![(0, 2)]);
        assert!(mgr.is_empty());
        assert_eq!(mgr.evicted(), 2);
    }

    #[test]
    fn idle_eviction_boundary_exact_age_survives() {
        // The documented `--evict-idle-after N` contract (docs/
        // SERVING.md): evict sessions idle for *more than* N ticks.
        // Pin the exact boundary: idle age == N survives, N+1 evicts.
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        mgr.get_or_create(0, 1, &engine); // active at t=0
        for _ in 0..4 {
            mgr.tick();
        }
        // now = 4, idle age exactly 4: threshold 4 keeps it …
        assert!(mgr.evict_idle_protected(4, &[]).is_empty());
        assert!(mgr.get(1).is_some());
        // … and one more tick (age 5 > 4) evicts it.
        mgr.tick();
        assert_eq!(mgr.evict_idle_protected(4, &[]), vec![(0, 1)]);
        assert!(mgr.get(1).is_none());
    }

    #[test]
    fn take_and_insert_do_not_count_as_churn() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        mgr.get_or_create(0, 4, &engine).tokens_seen = 11;
        let s = mgr.take(0, 4).expect("resident");
        assert_eq!(mgr.evicted(), 0, "take is not an eviction");
        assert!(mgr.take(0, 4).is_none());
        mgr.insert(s);
        assert_eq!(mgr.created(), 1, "insert is not a creation");
        assert_eq!(mgr.get(4).unwrap().tokens_seen, 11);
    }

    #[test]
    fn coldest_first_orders_by_activity_then_key() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        mgr.get_or_create(1, 2, &engine); // t=0
        mgr.get_or_create(0, 9, &engine); // t=0
        mgr.tick();
        mgr.get_or_create(0, 1, &engine); // t=1
        // Oldest activity first; ties break (model, id) ascending.
        assert_eq!(mgr.coldest_first(&[]), vec![(0, 9), (1, 2), (0, 1)]);
        // Protection removes a key without disturbing the order.
        assert_eq!(mgr.coldest_first(&[(0, 9)]), vec![(1, 2), (0, 1)]);
    }

    #[test]
    fn idle_eviction_respects_protection_and_order() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        mgr.get_or_create(0, 3, &engine);
        mgr.get_or_create(1, 3, &engine);
        mgr.tick();
        mgr.get_or_create(0, 9, &engine);
        for _ in 0..5 {
            mgr.tick();
        }
        // Oldest first; ties by (model, id) ascending. (1, 3) is
        // protected (e.g. a chunk is queued upstream) and survives.
        let evicted = mgr.evict_idle_protected(1, &[(1, 3)]);
        assert_eq!(evicted, vec![(0, 3), (0, 9)]);
        assert!(mgr.get_model(1, 3).is_some());
        // Touching a session resets its idle age.
        mgr.get_or_create(1, 3, &engine);
        assert!(mgr.evict_idle_protected(0, &[]).is_empty());
    }
}
