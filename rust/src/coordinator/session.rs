//! Per-stream sessions: the persistent LSTM state that makes RNN
//! serving stateful (and quantization "numerically challenging" — the
//! state carries quantization error across invocations).

use std::collections::HashMap;

use crate::model::lm::{CharLmEngine, LmState};

/// Identifier of one stream; routing and session tables key on it.
pub type SessionId = u64;

/// One live stream.
pub struct Session {
    /// The stream's id.
    pub id: SessionId,
    /// The persistent recurrent state (cell/hidden per layer plus the
    /// last hidden/logits scratch).
    pub state: LmState,
    /// Tokens processed so far (stream position).
    pub tokens_seen: usize,
    /// Accumulated negative log2-likelihood (quality accounting).
    pub nll_bits: f64,
}

impl Session {
    /// A fresh session with the engine's zero state.
    pub fn new(id: SessionId, engine: &CharLmEngine) -> Self {
        Session { id, state: engine.new_state(), tokens_seen: 0, nll_bits: 0.0 }
    }

    /// Mean bits-per-char over the stream so far.
    pub fn bits_per_char(&self) -> f64 {
        if self.tokens_seen <= 1 {
            return f64::NAN;
        }
        self.nll_bits / (self.tokens_seen - 1) as f64
    }
}

/// Session table for one worker.
#[derive(Default)]
pub struct SessionManager {
    sessions: HashMap<SessionId, Session>,
    created: u64,
    evicted: u64,
}

impl SessionManager {
    /// An empty session table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the session (sticky: a given id always lives on
    /// the worker the router chose for it).
    pub fn get_or_create(&mut self, id: SessionId, engine: &CharLmEngine) -> &mut Session {
        if !self.sessions.contains_key(&id) {
            self.created += 1;
            self.sessions.insert(id, Session::new(id, engine));
        }
        self.sessions.get_mut(&id).unwrap()
    }

    /// Look up a session without creating it.
    pub fn get(&self, id: SessionId) -> Option<&Session> {
        self.sessions.get(&id)
    }

    /// Remove one session, returning it (counts as an eviction).
    pub fn remove(&mut self, id: SessionId) -> Option<Session> {
        let s = self.sessions.remove(&id);
        if s.is_some() {
            self.evicted += 1;
        }
        s
    }

    /// Number of resident sessions.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    /// True when no session is resident.
    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Sessions ever created on this table.
    pub fn created(&self) -> u64 {
        self.created
    }

    /// Sessions ever removed or evicted from this table.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Evict sessions idle beyond a token-count budget (memory
    /// pressure control; state is the dominant per-stream cost).
    /// Returns how many sessions were evicted.
    pub fn evict_longest(&mut self, keep_at_most: usize) -> usize {
        self.evict_longest_protected(keep_at_most, &[]).len()
    }

    /// Evict the longest-seen sessions until at most `keep_at_most`
    /// remain, never touching ids in `protected` (the serving loop
    /// passes the sessions currently holding a lane or queued for one —
    /// their state is live in the wave and must not be dropped). The
    /// resident count can therefore stay above the budget while many
    /// lanes are live.
    ///
    /// Eviction order is a pure function of the table contents: sort by
    /// `(tokens_seen, id)` descending, so ties break by id and repeated
    /// runs evict identical sets — no hash-iteration nondeterminism.
    /// Returns the evicted ids in eviction order.
    pub fn evict_longest_protected(
        &mut self,
        keep_at_most: usize,
        protected: &[SessionId],
    ) -> Vec<SessionId> {
        if self.sessions.len() <= keep_at_most {
            return Vec::new();
        }
        let mut ids: Vec<(usize, SessionId)> = self
            .sessions
            .values()
            .filter(|s| !protected.contains(&s.id))
            .map(|s| (s.tokens_seen, s.id))
            .collect();
        ids.sort_unstable_by(|a, b| b.cmp(a));
        let over = self.sessions.len() - keep_at_most;
        let mut out = Vec::with_capacity(over.min(ids.len()));
        for &(_, id) in ids.iter().take(over) {
            self.sessions.remove(&id);
            self.evicted += 1;
            out.push(id);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::{QuantizeOptions, StackEngine};
    use crate::lstm::{LstmSpec, StackWeights};
    use crate::model::lm::CharLm;
    use crate::tensor::Matrix;
    use crate::util::Pcg32;

    fn tiny_lm() -> CharLm {
        let mut rng = Pcg32::seeded(5);
        let spec = LstmSpec::plain(crate::model::lm::VOCAB, 16);
        let stack_weights = StackWeights::random(crate::model::lm::VOCAB, spec, 1, &mut rng);
        let mut out_w = Matrix::<f32>::zeros(crate::model::lm::VOCAB, 16);
        rng.fill_uniform_f32(&mut out_w.data, -0.3, 0.3);
        CharLm {
            stack_weights,
            out_w,
            out_b: vec![0.0; crate::model::lm::VOCAB],
            hidden: 16,
            depth: 1,
        }
    }

    #[test]
    fn session_lifecycle() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        assert!(mgr.is_empty());
        {
            let s = mgr.get_or_create(42, &engine);
            assert_eq!(s.id, 42);
            s.tokens_seen = 10;
        }
        // Sticky: same id returns the same state.
        assert_eq!(mgr.get_or_create(42, &engine).tokens_seen, 10);
        assert_eq!(mgr.len(), 1);
        assert_eq!(mgr.created(), 1);
        assert!(mgr.remove(42).is_some());
        assert!(mgr.remove(42).is_none());
        assert_eq!(mgr.evicted(), 1);
    }

    #[test]
    fn state_persists_across_steps() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        let s = mgr.get_or_create(1, &engine);
        engine.step_token(3, &mut s.state);
        let logits_after_one = s.state.logits.clone();
        engine.step_token(3, &mut s.state);
        // Recurrent state changed the prediction for the same input.
        assert_ne!(logits_after_one, s.state.logits);
    }

    #[test]
    fn eviction_order_is_deterministic_on_ties() {
        // Equal stream lengths: the (tokens_seen, id) sort breaks ties
        // by id descending, so eviction is a pure function of the table
        // contents — no hash-iteration nondeterminism.
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        for _ in 0..2 {
            let mut mgr = SessionManager::new();
            for id in 0..10u64 {
                mgr.get_or_create(id, &engine).tokens_seen = 5;
            }
            assert_eq!(mgr.evict_longest(7), 3);
            // Highest ids evicted first on ties.
            for id in 0..7u64 {
                assert!(mgr.get(id).is_some(), "id {id} wrongly evicted");
            }
            for id in 7..10u64 {
                assert!(mgr.get(id).is_none(), "id {id} wrongly kept");
            }
        }
    }

    #[test]
    fn eviction_removes_longest() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        for id in 0..10u64 {
            let s = mgr.get_or_create(id, &engine);
            s.tokens_seen = id as usize * 100;
        }
        let evicted = mgr.evict_longest(6);
        assert_eq!(evicted, 4);
        assert_eq!(mgr.len(), 6);
        // The longest streams (ids 6..9) are gone.
        assert!(mgr.get_or_create(0, &engine).tokens_seen == 0);
    }

    #[test]
    fn protected_sessions_survive_eviction() {
        let lm = tiny_lm();
        let engine = lm.engine(StackEngine::Float, None, QuantizeOptions::default());
        let mut mgr = SessionManager::new();
        for id in 0..6u64 {
            mgr.get_or_create(id, &engine).tokens_seen = id as usize * 10;
        }
        // Protect the two longest: eviction must fall through to the
        // next-longest unprotected sessions.
        let evicted = mgr.evict_longest_protected(2, &[5, 4]);
        assert_eq!(evicted, vec![3, 2, 1, 0]);
        assert_eq!(mgr.len(), 2);
        assert!(mgr.get(5).is_some());
        assert!(mgr.get(4).is_some());
        // With everything protected, nothing is evicted even over
        // budget.
        let evicted = mgr.evict_longest_protected(0, &[5, 4]);
        assert!(evicted.is_empty());
        assert_eq!(mgr.len(), 2);
    }
}
