//! Structured event tracing for the serving stack: a bounded,
//! lock-cheap per-worker event ring every coordinator stage emits
//! into, merged at drain time into one deterministic event log.
//!
//! Three levels ([`TraceLevel`]):
//!
//! * `Off` — nothing is recorded; the hot path pays only one enum
//!   compare per would-be emission.
//! * `Counters` — per-stage duration histograms ([`StageLatencies`])
//!   and the kernel GEMM/MAC counters
//!   ([`crate::tensor::qmatmul::kernel_counters`]) are accumulated,
//!   but no per-event ring.
//! * `Full` — everything in `Counters` plus one [`TraceEvent`] per
//!   lifecycle transition in the per-worker [`TraceRing`].
//!
//! The cardinal invariant (pinned by
//! `rust/tests/trace_observability.rs`): **tracing never perturbs the
//! schedule**. Events and timings are taken *after* every scheduling
//! decision; no branch of the scheduler, router, or kernels consults
//! the trace state. `simulate_shard_trace` therefore emits
//! bit-identical token streams and completions at every level.
//!
//! Two clocks, two export formats (the DESIGN.md §8 discipline):
//!
//! * [`jsonl_string`] serializes the **virtual clock** only — `step`
//!   (the simulator tick / worker loop iteration), worker, model,
//!   session, kind, arg. Reruns of the same simulated trace produce
//!   byte-identical JSONL.
//! * [`chrome_trace_string`] serializes the **wall clock**
//!   (`wall_us`/`dur_us` since the worker's trace epoch) in the
//!   Chrome trace-viewer format, for `chrome://tracing` / Perfetto.
//!   Wall timestamps are real elapsed time and differ across reruns —
//!   byte-stability is never claimed for this surface.

use std::collections::VecDeque;
use std::time::Instant;

use crate::eval::metrics::LatencyStats;
use super::registry::ModelId;
use super::session::SessionId;

/// How much the trace subsystem records (ordered: each level includes
/// everything below it, so `level >= TraceLevel::Counters` gates the
/// timing/counter work and `== Full` gates event emission).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// Record nothing (the default — zero observability overhead).
    #[default]
    Off,
    /// Accumulate stage-duration histograms and kernel counters, no
    /// event ring.
    Counters,
    /// Counters plus one [`TraceEvent`] per lifecycle transition.
    Full,
}

impl TraceLevel {
    /// Every level, in severity order (CLI/help listings).
    pub const ALL: [TraceLevel; 3] =
        [TraceLevel::Off, TraceLevel::Counters, TraceLevel::Full];

    /// Short name used by the CLI and bench JSON.
    pub fn label(&self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Counters => "counters",
            TraceLevel::Full => "full",
        }
    }

    /// Parse a CLI spelling. Unknown levels are an `Err` so the CLI
    /// bails loudly instead of silently defaulting to `Off` (the
    /// silent-default contract).
    pub fn parse(s: &str) -> Result<TraceLevel, String> {
        match s {
            "off" => Ok(TraceLevel::Off),
            "counters" => Ok(TraceLevel::Counters),
            "full" => Ok(TraceLevel::Full),
            other => Err(format!(
                "unknown trace level '{other}': expected off | counters | full"
            )),
        }
    }

    /// The level at numeric index `i` (0 = `Off`, 1 = `Counters`, 2 =
    /// `Full`) — the wire/config encoding. Panics on an out-of-range
    /// index: a level that does not exist is a caller bug, never
    /// "trace off".
    pub fn from_index(i: u8) -> TraceLevel {
        match i {
            0 => TraceLevel::Off,
            1 => TraceLevel::Counters,
            2 => TraceLevel::Full,
            other => panic!("trace level index {other} out of range (0..=2)"),
        }
    }
}

/// Trace configuration carried by `ShardConfig` / `ServerConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Recording level (off by default).
    pub level: TraceLevel,
    /// Per-worker event ring capacity at [`TraceLevel::Full`]. When a
    /// worker emits more events than this, the *oldest* are dropped
    /// and counted ([`TraceRing::dropped`]) — the ring never grows
    /// unbounded and never blocks the scheduling loop.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { level: TraceLevel::Off, capacity: 1 << 16 }
    }
}

impl TraceConfig {
    /// A `Full`-level config with the default ring capacity.
    pub fn full() -> Self {
        TraceConfig { level: TraceLevel::Full, ..TraceConfig::default() }
    }

    /// A `Counters`-level config.
    pub fn counters() -> Self {
        TraceConfig { level: TraceLevel::Counters, ..TraceConfig::default() }
    }
}

/// What happened — one lifecycle transition of the serving stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// An item was admitted into a lane (`arg` = chunk length in
    /// tokens; emitted with a paired immediate `Done` for empty
    /// items, which execute nothing).
    Admit,
    /// A stream's state was materialized for the first time on this
    /// worker (at most one per `(model, session)` per worker).
    Bind,
    /// This worker stole the session from a backlogged peer (`arg` =
    /// victim worker index).
    Steal,
    /// One batched step of one model wave (`arg` = live lanes;
    /// `dur_us` = wall duration of the batched GEMM pass).
    StepBatch,
    /// A session hibernated into the cold tier (`arg` = encoded
    /// bytes).
    Spill,
    /// A session was restored out of the cold tier.
    Restore,
    /// A session was evicted (`arg` = 0 for the session-count budget,
    /// 1 for the idle-age policy). Unlike a spill, an eviction resets
    /// the stream.
    Evict,
    /// A model was demoted to int4 under the weight budget (`arg` =
    /// weight bytes after demotion; emitted by the CLI driver, worker
    /// index `u32::MAX`).
    Demote,
    /// A lane executed its stream's first token position (`arg` =
    /// position within the chunk, always 0).
    FirstToken,
    /// An item finished and was retired from its lane (`arg` = chunk
    /// length in tokens).
    Done,
    /// The network front rejected a request with `Busy` backpressure
    /// (worker index `u32::MAX` — the rejection happens before any
    /// worker is involved).
    Busy,
}

impl EventKind {
    /// Stable lower-snake name used in both export formats.
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Bind => "bind",
            EventKind::Steal => "steal",
            EventKind::StepBatch => "step_batch",
            EventKind::Spill => "spill",
            EventKind::Restore => "restore",
            EventKind::Evict => "evict",
            EventKind::Demote => "demote",
            EventKind::FirstToken => "first_token",
            EventKind::Done => "done",
            EventKind::Busy => "busy",
        }
    }
}

/// One recorded lifecycle transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Virtual step at emission: the simulator tick
    /// (`simulate_shard_trace`) or the worker loop iteration (threaded
    /// server) — the deterministic clock the JSONL log orders by.
    pub step: u64,
    /// Microseconds since the worker's trace epoch (**wall clock** —
    /// feeds the Chrome trace only, never the JSONL log).
    pub wall_us: u64,
    /// Wall-clock duration in microseconds (nonzero only for
    /// [`EventKind::StepBatch`]).
    pub dur_us: u64,
    /// Emitting worker index (`u32::MAX` for front-of-pool events:
    /// `Busy` rejections and CLI `Demote`).
    pub worker: u32,
    /// Model the event concerns.
    pub model: ModelId,
    /// Session the event concerns (0 where not applicable, e.g.
    /// [`EventKind::StepBatch`]).
    pub session: SessionId,
    /// Kind-specific argument (see [`EventKind`] docs).
    pub arg: u64,
    /// What happened.
    pub kind: EventKind,
}

/// Bounded per-worker event ring. Single-owner (each scheduler owns
/// its ring — no locks anywhere near the scheduling loop); overflow
/// drops the oldest events and counts them instead of growing or
/// blocking.
#[derive(Debug)]
pub struct TraceRing {
    level: TraceLevel,
    capacity: usize,
    worker: u32,
    step: u64,
    epoch: Instant,
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

impl TraceRing {
    /// A ring for one worker. `capacity` must be nonzero at
    /// [`TraceLevel::Full`] (a zero-capacity full-level ring could
    /// only drop, which is a config bug, not a quiet no-op).
    pub fn new(config: TraceConfig, worker: u32) -> Self {
        assert!(
            config.level != TraceLevel::Full || config.capacity > 0,
            "trace ring capacity must be nonzero at level full"
        );
        TraceRing {
            level: config.level,
            capacity: config.capacity,
            worker,
            step: 0,
            epoch: Instant::now(),
            events: VecDeque::new(),
            dropped: 0,
        }
    }

    /// The recording level this ring was built with.
    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// Set the virtual-step clock stamped onto subsequent events.
    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    /// Record one event (no-op below [`TraceLevel::Full`]).
    pub fn emit(&mut self, kind: EventKind, model: ModelId, session: SessionId, arg: u64) {
        self.emit_dur(kind, model, session, arg, 0);
    }

    /// Record one event with an explicit wall-clock duration.
    pub fn emit_dur(
        &mut self,
        kind: EventKind,
        model: ModelId,
        session: SessionId,
        arg: u64,
        dur_us: u64,
    ) {
        if self.level != TraceLevel::Full {
            return;
        }
        if self.events.len() == self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(TraceEvent {
            step: self.step,
            wall_us: self.epoch.elapsed().as_micros() as u64,
            dur_us,
            worker: self.worker,
            model,
            session,
            arg,
            kind,
        });
    }

    /// Drain the recorded events (emission order).
    pub fn take(&mut self) -> Vec<TraceEvent> {
        self.events.drain(..).collect()
    }

    /// Events dropped to the capacity bound so far. Nonzero means the
    /// log is a *suffix* of the run — reported, never silent.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Merge per-worker event streams into one deterministic log: ordered
/// by `(step, worker)` with each worker's own emission order preserved
/// within a step (stable sort). Wall timestamps are carried along but
/// never consulted — the merged order is a pure function of the
/// virtual-clock fields, which is what makes the JSONL export
/// byte-stable across reruns.
pub fn merge_events(streams: Vec<Vec<TraceEvent>>) -> Vec<TraceEvent> {
    let mut all: Vec<TraceEvent> = streams.into_iter().flatten().collect();
    all.sort_by_key(|e| (e.step, e.worker));
    all
}

/// Serialize events as one JSON object per line, **virtual-clock
/// fields only** (`step`, `worker`, `model`, `session`, `kind`,
/// `arg`). Identical simulated runs produce byte-identical output —
/// the determinism surface `rust/tests/trace_observability.rs` pins.
pub fn jsonl_string(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&format!(
            "{{\"step\":{},\"worker\":{},\"model\":{},\"session\":{},\"kind\":\"{}\",\"arg\":{}}}\n",
            e.step,
            e.worker,
            e.model,
            e.session,
            e.kind.label(),
            e.arg,
        ));
    }
    out
}

/// Serialize events in the Chrome trace-viewer JSON format (open in
/// `chrome://tracing` or <https://ui.perfetto.dev>): **wall-clock**
/// microseconds since the worker's trace epoch, one thread row per
/// worker. [`EventKind::StepBatch`] renders as a complete (`"X"`)
/// slice with its duration; everything else as a thread-scoped
/// instant (`"i"`).
pub fn chrome_trace_string(events: &[TraceEvent]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    for (i, e) in events.iter().enumerate() {
        let sep = if i + 1 == events.len() { "" } else { "," };
        if e.kind == EventKind::StepBatch {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{},\
                 \"args\":{{\"step\":{},\"model\":{},\"lanes\":{}}}}}{}\n",
                e.kind.label(),
                e.wall_us,
                e.dur_us.max(1),
                e.worker,
                e.step,
                e.model,
                e.arg,
                sep,
            ));
        } else {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"i\",\"ts\":{},\"pid\":0,\"tid\":{},\"s\":\"t\",\
                 \"args\":{{\"step\":{},\"model\":{},\"session\":{},\"arg\":{}}}}}{}\n",
                e.kind.label(),
                e.wall_us,
                e.worker,
                e.step,
                e.model,
                e.session,
                e.arg,
                sep,
            ));
        }
    }
    out.push_str("]}\n");
    out
}

/// Per-stage duration histograms accumulated at
/// [`TraceLevel::Counters`] and above — where a token's wall-clock
/// time went, beside the end-to-end histograms the report already
/// carries. All three are **wall-clock** milliseconds (the two-clock
/// discipline: virtual-step schedule counters live in
/// `SchedulerStats`, never here).
#[derive(Debug, Clone, Default)]
pub struct StageLatencies {
    /// Submission → lane-admission wait, one sample per admitted
    /// chunk (the queue time; the mean of these is
    /// `mean_admission_ms`).
    pub admission_wait: LatencyStats,
    /// Duration of one batched step of one model wave (the GEMM
    /// pass), one sample per `StepBatch`.
    pub execute: LatencyStats,
    /// Duration of one cold-tier spill or restore (state encode /
    /// decode + table move), one sample per event.
    pub spill_restore: LatencyStats,
}

impl StageLatencies {
    /// Fold another worker's stage histograms into this one.
    /// Order-independent: percentiles are computed over the sorted
    /// union of samples, so any merge order yields identical stats
    /// (pinned by a unit test in `eval::metrics`).
    pub fn merge(&mut self, other: &StageLatencies) {
        self.admission_wait.merge(&other.admission_wait);
        self.execute.merge(&other.execute);
        self.spill_restore.merge(&other.spill_restore);
    }

    /// True when no stage recorded any sample (trace level `Off`).
    pub fn is_empty(&self) -> bool {
        self.admission_wait.count() == 0
            && self.execute.count() == 0
            && self.spill_restore.count() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(step: u64, worker: u32, kind: EventKind) -> TraceEvent {
        TraceEvent {
            step,
            wall_us: 999, // wall clock must never affect merge order or JSONL bytes
            dur_us: 0,
            worker,
            model: 0,
            session: 7,
            arg: 3,
            kind,
        }
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let mut ring =
            TraceRing::new(TraceConfig { level: TraceLevel::Full, capacity: 3 }, 0);
        for i in 0..5u64 {
            ring.set_step(i);
            ring.emit(EventKind::Admit, 0, i, 0);
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.dropped(), 2);
        let steps: Vec<u64> = ring.take().iter().map(|e| e.step).collect();
        assert_eq!(steps, vec![2, 3, 4], "oldest events must be the ones dropped");
        assert!(ring.is_empty());
    }

    #[test]
    fn levels_below_full_emit_nothing() {
        for level in [TraceLevel::Off, TraceLevel::Counters] {
            let mut ring = TraceRing::new(TraceConfig { level, capacity: 8 }, 0);
            ring.emit(EventKind::Admit, 0, 1, 0);
            assert!(ring.is_empty(), "{level:?} must not record events");
        }
    }

    #[test]
    fn merge_orders_by_step_then_worker_preserving_emission_order() {
        // Worker 1 emitted (step 0: admit, bind) and (step 2: done);
        // worker 0 emitted (step 1: admit). Wall timestamps are
        // deliberately identical garbage.
        let w1 = vec![
            ev(0, 1, EventKind::Admit),
            ev(0, 1, EventKind::Bind),
            ev(2, 1, EventKind::Done),
        ];
        let w0 = vec![ev(1, 0, EventKind::Admit)];
        let merged = merge_events(vec![w1, w0]);
        let order: Vec<(u64, u32, &str)> =
            merged.iter().map(|e| (e.step, e.worker, e.kind.label())).collect();
        assert_eq!(
            order,
            vec![(0, 1, "admit"), (0, 1, "bind"), (1, 0, "admit"), (2, 1, "done")]
        );
    }

    #[test]
    fn jsonl_is_a_pure_function_of_virtual_fields() {
        let mut a = ev(4, 2, EventKind::Spill);
        let mut b = a;
        // Different wall clocks, identical virtual fields: identical
        // bytes.
        a.wall_us = 1;
        b.wall_us = 123_456;
        assert_eq!(jsonl_string(&[a]), jsonl_string(&[b]));
        assert_eq!(
            jsonl_string(&[a]),
            "{\"step\":4,\"worker\":2,\"model\":0,\"session\":7,\"kind\":\"spill\",\"arg\":3}\n"
        );
    }

    #[test]
    fn chrome_trace_renders_slices_and_instants() {
        let mut step = ev(1, 0, EventKind::StepBatch);
        step.dur_us = 42;
        let out = chrome_trace_string(&[step, ev(1, 0, EventKind::Done)]);
        assert!(out.starts_with("{\"traceEvents\":["));
        assert!(out.contains("\"ph\":\"X\""), "StepBatch must be a slice");
        assert!(out.contains("\"dur\":42"));
        assert!(out.contains("\"ph\":\"i\""), "Done must be an instant");
        assert!(out.trim_end().ends_with("]}"));
    }

    #[test]
    fn level_parse_round_trips_and_orders() {
        for level in TraceLevel::ALL {
            assert_eq!(TraceLevel::parse(level.label()), Ok(level));
        }
        assert!(TraceLevel::parse("verbose").is_err());
        assert!(TraceLevel::Off < TraceLevel::Counters);
        assert!(TraceLevel::Counters < TraceLevel::Full);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_level_index_panics() {
        let _ = TraceLevel::from_index(3);
    }

    #[test]
    fn stage_latencies_merge_is_order_independent() {
        let mut a = StageLatencies::default();
        let mut b = StageLatencies::default();
        for v in [5.0, 1.0, 9.0] {
            a.execute.record(v);
        }
        for v in [2.0, 8.0] {
            b.execute.record(v);
        }
        let mut ab = StageLatencies::default();
        ab.merge(&a);
        ab.merge(&b);
        let mut ba = StageLatencies::default();
        ba.merge(&b);
        ba.merge(&a);
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(ab.execute.percentile(p), ba.execute.percentile(p));
        }
        assert!(StageLatencies::default().is_empty());
        assert!(!ab.is_empty());
    }
}
