//! Serving metrics: what the benchmark harness reports for E4/E10,
//! including batch-occupancy of the batch-major execution path, the
//! per-worker load/steal breakdown of the sharded server, and the
//! per-model breakdown of a registry deployment.

use crate::eval::metrics::{LatencyStats, RtFactor};
use crate::tensor::qmatmul::kernel_counters::KernelCounters;
use super::registry::ModelId;
use super::trace::{StageLatencies, TraceEvent};

/// Per-worker load breakdown of one serving run: how much of the work
/// each shard executed, how wide its waves ran, and how much work it
/// pulled over from peers.
#[derive(Debug, Clone)]
pub struct WorkerLoad {
    /// Worker (shard) index.
    pub worker: usize,
    /// Batched step invocations on this worker (one per token position
    /// per model wave).
    pub batched_steps: usize,
    /// Lane-steps (tokens) this worker executed.
    pub lane_steps: usize,
    /// Lane-slots this worker executed including SIMD tile padding
    /// (physical GEMM width summed per step; `>= lane_steps`).
    pub padded_lane_steps: usize,
    /// Widest live batch this worker ran (total across model waves).
    pub peak_lanes: usize,
    /// Admissions into this worker's waves.
    pub admissions: usize,
    /// Retirements out of this worker's waves.
    pub retirements: usize,
    /// Steal invocations this worker performed (as thief).
    pub steal_events: usize,
    /// Sessions this worker stole from peers (as thief).
    pub stolen_sessions: usize,
    /// Sessions the session-count budget evicted on this worker.
    pub evictions: usize,
    /// Sessions the idle-age policy evicted on this worker.
    pub idle_evictions: usize,
    /// Sessions hibernated into this worker's cold tier by the
    /// resident-state byte budget (lossless, unlike an eviction).
    pub spills: usize,
    /// Sessions restored out of this worker's cold tier.
    pub restores: usize,
    /// Largest resident-state byte total this worker observed (sampled
    /// after budget enforcement, so it never exceeds the byte budget).
    pub peak_resident_state_bytes: usize,
}

impl WorkerLoad {
    /// Mean lanes per batched step on this worker.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Mean physical (tile-padded) lanes per batched step on this
    /// worker — what its GEMMs actually executed.
    pub fn padded_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.padded_lane_steps as f64 / self.batched_steps as f64
        }
    }
}

/// Per-model breakdown of one serving run under the model registry:
/// the occupancy, turnover, steal, eviction, and memory accounting of
/// one registered variant across the whole pool.
#[derive(Debug, Clone)]
pub struct ModelLoad {
    /// The registry id of this model.
    pub model: ModelId,
    /// Operator-facing model name.
    pub name: String,
    /// Engine label ("Float"/"Hybrid"/"Integer").
    pub engine: &'static str,
    /// Weight bit-width label ("int8"/"int4") — int4 after a
    /// byte-pressure demotion or an explicit `--weight-bits 4`.
    pub weight_bits: &'static str,
    /// Workers holding this model's weights.
    pub resident_workers: usize,
    /// Packed weight bytes of one replica.
    pub weight_bytes: usize,
    /// Weight bytes resident across the pool
    /// (`weight_bytes * resident_workers`) — the dominant memory cost
    /// the registry's residency policy trades against occupancy.
    pub resident_weight_bytes: usize,
    /// Sessions of this model resident (hot) at the end of the run,
    /// across all workers. Hibernated sessions are counted separately
    /// in [`Self::hibernated_sessions`].
    pub resident_sessions: usize,
    /// Bytes of resident per-stream state at the end of the run
    /// (`resident_sessions` × per-stream state size) — a live number:
    /// hibernated sessions' bytes leave this total.
    pub resident_state_bytes: usize,
    /// Sessions of this model hibernated in cold tiers at the end of
    /// the run, across all workers.
    pub hibernated_sessions: usize,
    /// Serialized bytes the hibernated sessions occupy (exact-codec
    /// images equal the hot state size; int8 images are ~4x smaller).
    pub hibernated_state_bytes: usize,
    /// Batched step invocations on this model's waves.
    pub batched_steps: usize,
    /// Lane-steps (tokens) executed for this model.
    pub lane_steps: usize,
    /// Lane-slots executed including SIMD tile padding.
    pub padded_lane_steps: usize,
    /// Widest wave any worker ran for this model.
    pub peak_lanes: usize,
    /// Admissions into this model's waves.
    pub admissions: usize,
    /// Retirements out of this model's waves.
    pub retirements: usize,
    /// Sessions of this model moved between workers by stealing.
    pub steals: usize,
    /// Sessions of this model evicted by the session-count budget.
    pub evictions: usize,
    /// Sessions of this model evicted by the idle-age policy.
    pub idle_evictions: usize,
    /// Sessions of this model hibernated by the byte budget.
    pub spills: usize,
    /// Sessions of this model restored from cold tiers.
    pub restores: usize,
    /// Measured GEMM invocations and MAC counts for this model's
    /// steps, by weight format (zero unless the run traced at
    /// `counters` or above).
    pub kernels: KernelCounters,
}

impl ModelLoad {
    /// Mean lanes per batched step on this model's waves.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Mean physical (tile-padded) lanes per batched step on this
    /// model's waves.
    pub fn padded_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.padded_lane_steps as f64 / self.batched_steps as f64
        }
    }
}

/// The report a serving run produces.
#[derive(Debug)]
pub struct ServingReport {
    /// Engine label of a single-model run ("Float"/"Hybrid"/"Integer"),
    /// or `"multi"` when the registry serves more than one model (see
    /// [`Self::per_model`] for the per-variant engines).
    pub engine: &'static str,
    /// Scheduling discipline ("continuous" or "wave").
    pub mode: &'static str,
    /// Models resident in the registry for this run.
    pub models: usize,
    /// Requests completed.
    pub requests: usize,
    /// Tokens executed.
    pub tokens: usize,
    /// Wall-clock seconds of the whole replay.
    pub wall_secs: f64,
    /// Total model-execution time across workers (excludes queueing).
    pub compute_secs: f64,
    /// End-to-end request latency distribution, **wall-clock**
    /// (submission → completion). The virtual-step schedule metrics
    /// (`batched_steps`, occupancy, admissions) are a separate clock
    /// and never feed these histograms.
    pub latency: LatencyStats,
    /// Wall-clock submission → first-executed-token latency
    /// distribution (the "time to first token" a streaming client
    /// observes; empty items contribute their completion latency).
    pub first_token_latency: LatencyStats,
    /// Wall-clock per-token latency distribution: for each completed
    /// item with ≥ 2 tokens, `(e2e − first_token) / (tokens − 1)` —
    /// the steady-state token cadence after the first token landed.
    pub per_token_latency: LatencyStats,
    /// Worker (shard) count the run used.
    pub workers: usize,
    /// Mean items per *ingest* (router pull that yielded items). In
    /// wave mode this approximates execution batch width; in continuous
    /// mode it measures arrival burstiness only — compare execution
    /// width across modes with [`Self::mean_occupancy`], not this.
    pub mean_batch: f64,
    /// Batched step invocations across all workers (one per token
    /// position per model wave).
    pub batched_steps: usize,
    /// Lane-steps executed across all workers (equals tokens processed
    /// through the batched path).
    pub lane_steps: usize,
    /// Lane-slots executed across all workers including SIMD tile
    /// padding: the physical GEMM width summed per batched step. The
    /// padding contract rounds every live batch up to the register-tile
    /// width so the int8 kernels never run scalar tails; the gap
    /// between this and `lane_steps` is the price paid for that
    /// (reported separately so `occ` stays an honest live-lane metric).
    pub padded_lane_steps: usize,
    /// Widest cross-session batch any worker ran.
    pub peak_lanes: usize,
    /// Lane turnover: admissions into live waves across all workers.
    pub lane_admissions: usize,
    /// Lane turnover: retirements out of live waves across all workers.
    pub lane_retirements: usize,
    /// Mean submission→admission wait across admitted items.
    pub mean_admission_ms: f64,
    /// Sessions moved between workers by work stealing (0 when
    /// stealing is disabled or `workers == 1`).
    pub steals: usize,
    /// Sessions evicted under the session-count budget across all
    /// workers.
    pub evictions: usize,
    /// Sessions evicted under the idle-age policy across all workers.
    pub idle_evictions: usize,
    /// Sessions hibernated under the resident-state byte budget across
    /// all workers (lossless — the stream resumes from its restored
    /// state, unlike an eviction).
    pub spills: usize,
    /// Sessions restored from cold tiers across all workers.
    pub restores: usize,
    /// Bytes of hot per-stream state resident at the end of the run
    /// across all workers (hibernated sessions excluded).
    pub resident_state_bytes: usize,
    /// Serialized bytes held by the cold tiers at the end of the run.
    pub hibernated_state_bytes: usize,
    /// Largest post-enforcement resident-state byte total any single
    /// worker observed — the quantity the `--session-budget` byte
    /// budget bounds.
    pub peak_resident_state_bytes: usize,
    /// Packed weight bytes resident across the pool (every model ×
    /// its resident worker count).
    pub resident_weight_bytes: usize,
    /// Per-worker load breakdown (occupancy, turnover, steals), indexed
    /// by worker.
    pub per_worker: Vec<WorkerLoad>,
    /// Per-model breakdown (occupancy, steals, evictions, memory),
    /// indexed by [`ModelId`].
    pub per_model: Vec<ModelLoad>,
    /// Per-stage wall-clock duration histograms (admission wait,
    /// batched execute, spill/restore), merged across workers — where
    /// a token's latency went, beside the end-to-end histograms above.
    /// Empty unless the run traced at `counters` or above.
    pub stage: StageLatencies,
    /// Measured GEMM invocations and MAC counts by weight format,
    /// summed across workers (zero unless the run traced at `counters`
    /// or above).
    pub kernels: KernelCounters,
    /// The merged lifecycle event log, `(step, worker)`-ordered; empty
    /// unless the run traced at `full`. Export with
    /// [`super::trace::jsonl_string`] /
    /// [`super::trace::chrome_trace_string`].
    pub trace_events: Vec<TraceEvent>,
}

impl ServingReport {
    /// Tokens per wall-clock second.
    pub fn throughput(&self) -> f64 {
        self.tokens as f64 / self.wall_secs
    }

    /// Mean lanes per batched step — how much of each GEMM invocation
    /// the batcher actually filled. 1.0 means the batch-major path ran
    /// degenerate single-stream; higher is better amortization.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// Mean *physical* lanes per batched step, pad lanes included —
    /// what the tile-padded GEMMs actually executed (`pad` in the
    /// report line; always `>=` [`Self::mean_occupancy`]).
    pub fn padded_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.padded_lane_steps as f64 / self.batched_steps as f64
        }
    }

    /// RT factor against the nominal stream rate (compute time only —
    /// the paper's RT factor is processing time per unit of audio).
    pub fn rt_factor(&self) -> RtFactor {
        RtFactor::from_tokens(self.compute_secs / self.workers as f64, self.tokens)
    }

    /// Print the one-line summary of the run. Empty histograms print
    /// `-`, never a plausible-looking 0.
    pub fn print(&self) {
        println!(
            "  {:<8} {:<10} models={:<2} reqs={:<5} tokens={:<7} wall={:>7.2}s \
             tput={:>9.0} tok/s RT={:.4} p50={}ms p99={}ms batch={:.2} occ={:.2} \
             pad={:.2} peak={} adm={} wait={:.2}ms steals={} evict={} evictI={}",
            self.engine,
            self.mode,
            self.models,
            self.requests,
            self.tokens,
            self.wall_secs,
            self.throughput(),
            self.rt_factor().value(),
            self.latency.fmt_percentile(50.0, 1),
            self.latency.fmt_percentile(99.0, 1),
            self.mean_batch,
            self.mean_occupancy(),
            self.padded_occupancy(),
            self.peak_lanes,
            self.lane_admissions,
            self.mean_admission_ms,
            self.steals,
            self.evictions,
            self.idle_evictions,
        );
        // Second line: the wall-clock latency histograms next to the
        // virtual-step counters above — two clocks, never one field.
        println!(
            "    wall-clock: first-token p50/p95/p99={}/{}/{}ms \
             per-token p50/p95/p99={}/{}/{}ms e2e p95={}ms",
            self.first_token_latency.fmt_percentile(50.0, 1),
            self.first_token_latency.fmt_percentile(95.0, 1),
            self.first_token_latency.fmt_percentile(99.0, 1),
            self.per_token_latency.fmt_percentile(50.0, 3),
            self.per_token_latency.fmt_percentile(95.0, 3),
            self.per_token_latency.fmt_percentile(99.0, 3),
            self.latency.fmt_percentile(95.0, 1),
        );
        // Stage attribution (trace level `counters`+): where the time
        // above went.
        if !self.stage.is_empty() {
            println!(
                "    stages: admission-wait p50/p99={}/{}ms ({} samples) \
                 execute p50/p99={}/{}ms ({} steps) spill-restore p50/p99={}/{}ms \
                 ({} events)",
                self.stage.admission_wait.fmt_percentile(50.0, 2),
                self.stage.admission_wait.fmt_percentile(99.0, 2),
                self.stage.admission_wait.count(),
                self.stage.execute.fmt_percentile(50.0, 3),
                self.stage.execute.fmt_percentile(99.0, 3),
                self.stage.execute.count(),
                self.stage.spill_restore.fmt_percentile(50.0, 3),
                self.stage.spill_restore.fmt_percentile(99.0, 3),
                self.stage.spill_restore.count(),
            );
        }
        // Measured kernel work by format (trace level `counters`+).
        if !self.kernels.is_empty() {
            println!(
                "    kernels: gemms={} macs={} (i8 {}/{} i4 {}/{} bsr {}/{})",
                self.kernels.total_gemms(),
                self.kernels.total_macs(),
                self.kernels.gemm_i8,
                self.kernels.macs_i8,
                self.kernels.gemm_i4,
                self.kernels.macs_i4,
                self.kernels.gemm_bsr,
                self.kernels.macs_bsr,
            );
        }
        // Third line: the state-memory closed loop — only printed when
        // hibernation did anything (or holds anything), so single-model
        // runs without a byte budget keep their two-line report.
        if self.spills > 0 || self.restores > 0 || self.hibernated_state_bytes > 0 {
            println!(
                "    state-mem: resident={}B hibernated={}B peak={}B \
                 spills={} restores={}",
                self.resident_state_bytes,
                self.hibernated_state_bytes,
                self.peak_resident_state_bytes,
                self.spills,
                self.restores,
            );
        }
    }

    /// Print one line per worker: occupancy, turnover, and steals —
    /// the load-balance view of a sharded run.
    pub fn print_workers(&self) {
        for w in &self.per_worker {
            println!(
                "    worker {:<2} steps={:<6} lanes={:<7} occ={:.2} pad={:.2} peak={} \
                 adm={} ret={} stole={} evict={} evictI={} spills={} restores={} \
                 peakStateB={}",
                w.worker,
                w.batched_steps,
                w.lane_steps,
                w.mean_occupancy(),
                w.padded_occupancy(),
                w.peak_lanes,
                w.admissions,
                w.retirements,
                w.stolen_sessions,
                w.evictions,
                w.idle_evictions,
                w.spills,
                w.restores,
                w.peak_resident_state_bytes,
            );
        }
    }

    /// Print one line per model: occupancy, steals, evictions, and the
    /// resident memory accounting — the registry view of a multi-model
    /// run.
    pub fn print_models(&self) {
        for m in &self.per_model {
            println!(
                "    model {:<2} {:<12} {:<8} {:<5} workers={:<2} weights={:<9}B \
                 ({}B resident) lanes={:<7} occ={:.2} peak={} steals={} evict={} \
                 evictI={} sessions={} ({}B state) cold={} ({}B, spills={} \
                 restores={})",
                m.model,
                m.name,
                m.engine,
                m.weight_bits,
                m.resident_workers,
                m.weight_bytes,
                m.resident_weight_bytes,
                m.lane_steps,
                m.mean_occupancy(),
                m.peak_lanes,
                m.steals,
                m.evictions,
                m.idle_evictions,
                m.resident_sessions,
                m.resident_state_bytes,
                m.hibernated_sessions,
                m.hibernated_state_bytes,
                m.spills,
                m.restores,
            );
            if !m.kernels.is_empty() {
                println!(
                    "      kernels: gemms={} macs={} (i8 {}/{} i4 {}/{} bsr {}/{})",
                    m.kernels.total_gemms(),
                    m.kernels.total_macs(),
                    m.kernels.gemm_i8,
                    m.kernels.macs_i8,
                    m.kernels.gemm_i4,
                    m.kernels.macs_i4,
                    m.kernels.gemm_bsr,
                    m.kernels.macs_bsr,
                );
            }
        }
    }
}
