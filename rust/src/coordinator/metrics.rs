//! Serving metrics: what the benchmark harness reports for E4/E10.

use crate::eval::metrics::{LatencyStats, RtFactor};

/// The report a serving run produces.
#[derive(Debug)]
pub struct ServingReport {
    pub engine: &'static str,
    pub requests: usize,
    pub tokens: usize,
    pub wall_secs: f64,
    /// Total model-execution time across workers (excludes queueing).
    pub compute_secs: f64,
    pub latency: LatencyStats,
    pub workers: usize,
    pub mean_batch: f64,
}

impl ServingReport {
    /// Tokens per wall-clock second.
    pub fn throughput(&self) -> f64 {
        self.tokens as f64 / self.wall_secs
    }

    /// RT factor against the nominal stream rate (compute time only —
    /// the paper's RT factor is processing time per unit of audio).
    pub fn rt_factor(&self) -> RtFactor {
        RtFactor::from_tokens(self.compute_secs / self.workers as f64, self.tokens)
    }

    pub fn print(&self) {
        println!(
            "  {:<8} reqs={:<5} tokens={:<7} wall={:>7.2}s tput={:>9.0} tok/s \
             RT={:.4} p50={:.1}ms p99={:.1}ms batch={:.2}",
            self.engine,
            self.requests,
            self.tokens,
            self.wall_secs,
            self.throughput(),
            self.rt_factor().value(),
            self.latency.percentile(50.0),
            self.latency.percentile(99.0),
            self.mean_batch,
        );
    }
}
