//! Bidirectional LSTM (§7): the paper notes that uni/bidirectional
//! RNNs "have loops on top of LSTM cell and the quantization strategy
//! described in this work can be directly applied" — this wrapper is
//! that loop: a forward stack and a backward stack over the same input,
//! outputs concatenated per step. Any engine (float/hybrid/integer)
//! plugs in unchanged.

use super::quantize::QuantizeOptions;
use super::stack::{LstmStack, StackEngine, StackWeights};
use crate::lstm::CalibrationStats;
use crate::tensor::Matrix;

/// A bidirectional wrapper over two independent stacks.
pub struct BiLstm {
    pub forward: LstmStack,
    pub backward: LstmStack,
}

impl BiLstm {
    /// Build from two weight sets (they may differ — e.g. separately
    /// trained directions).
    pub fn build(
        fwd: &StackWeights,
        bwd: &StackWeights,
        engine: StackEngine,
        stats_fwd: Option<&[CalibrationStats]>,
        stats_bwd: Option<&[CalibrationStats]>,
        opts: QuantizeOptions,
    ) -> Self {
        BiLstm {
            forward: LstmStack::build(fwd, engine, stats_fwd, opts),
            backward: LstmStack::build(bwd, engine, stats_bwd, opts),
        }
    }

    /// Concatenated output width.
    pub fn n_output(&self) -> usize {
        self.forward.n_output() + self.backward.n_output()
    }

    /// Run a full sequence (bidirectional processing is inherently
    /// non-streaming): outputs `[T][fwd_out + bwd_out]`, where position
    /// `t` concatenates the forward pass at `t` with the backward pass
    /// at `t` (i.e. backward state has consumed `x[t..]`).
    pub fn run_sequence(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut fwd_states = self.forward.zero_state();
        let fo = self.forward.run_sequence(xs, &mut fwd_states);
        let reversed: Vec<Vec<f32>> = xs.iter().rev().cloned().collect();
        let mut bwd_states = self.backward.zero_state();
        let mut bo = self.backward.run_sequence(&reversed, &mut bwd_states);
        bo.reverse();
        fo.into_iter()
            .zip(bo)
            .map(|(mut f, b)| {
                f.extend(b);
                f
            })
            .collect()
    }

    /// Batch-major bidirectional run over a batch of equal-length
    /// sequences: `xs[t]` is `[batch, n_input]`; output `[T]` of
    /// `[batch, fwd_out + bwd_out]`. Both directions run the batched
    /// stack path, so all engines execute the same batch-major code.
    pub fn run_sequence_batch(&self, xs: &[Matrix<f32>]) -> Vec<Matrix<f32>> {
        let Some(first) = xs.first() else {
            return Vec::new();
        };
        let batch = first.rows;
        let mut fwd_states = self.forward.zero_batch_state(batch);
        let fo = self.forward.run_sequence_batch(xs, &mut fwd_states);
        // Backward pass iterates the inputs in reverse in place — no
        // reversed copy of the batch.
        let mut bwd_states = self.backward.zero_batch_state(batch);
        let bwd_out = self.backward.n_output();
        let mut bo = Vec::with_capacity(xs.len());
        for x in xs.iter().rev() {
            let mut out = Matrix::zeros(batch, bwd_out);
            self.backward.step_batch(x, &mut bwd_states, &mut out);
            bo.push(out);
        }
        bo.reverse();
        fo.into_iter()
            .zip(bo)
            .map(|(f, b)| {
                let mut m = Matrix::zeros(batch, f.cols + b.cols);
                for lane in 0..batch {
                    m.row_mut(lane)[..f.cols].copy_from_slice(f.row(lane));
                    m.row_mut(lane)[f.cols..].copy_from_slice(b.row(lane));
                }
                m
            })
            .collect()
    }

    /// Batch-major run over a *ragged* batch of variable-length
    /// sequences. Lanes are ordered longest-first so the live set is
    /// always a dense prefix, and each direction sheds lanes by
    /// truncation as its stream runs out (forward: the sequence ends;
    /// backward: the reversed stream ends — every lane starts its
    /// reversed sequence at reverse step 0, which is valid because the
    /// backward pass is independent per sequence). Outputs are
    /// bit-exact with running [`Self::run_sequence`] on each sequence
    /// alone.
    pub fn run_sequences(&self, seqs: &[Vec<Vec<f32>>]) -> Vec<Vec<Vec<f32>>> {
        let mut outs: Vec<Vec<Vec<f32>>> =
            seqs.iter().map(|s| vec![Vec::new(); s.len()]).collect();
        let t_max = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
        if t_max == 0 {
            return outs;
        }
        let mut live: Vec<usize> =
            (0..seqs.len()).filter(|&i| !seqs[i].is_empty()).collect();
        live.sort_by(|&a, &b| seqs[b].len().cmp(&seqs[a].len()).then(a.cmp(&b)));
        let n_live = live.len();
        let fwd_w = self.forward.n_output();
        let bwd_w = self.backward.n_output();

        // Both direction loops reuse one input and one output buffer,
        // shrunk in place as lanes retire (no per-step allocation).
        // Forward: all lanes start together, truncate as they finish.
        {
            let n_in = self.forward.specs()[0].n_input;
            let mut states = self.forward.zero_batch_state(n_live);
            let mut active = n_live;
            let mut x = Matrix::<f32>::zeros(n_live, n_in);
            let mut out = Matrix::<f32>::zeros(n_live, fwd_w);
            for t in 0..t_max {
                let still = live.iter().take_while(|&&i| seqs[i].len() > t).count();
                if still < active {
                    self.forward.truncate_batch(&mut states, still);
                    x.truncate_rows(still);
                    out.truncate_rows(still);
                    active = still;
                }
                if active == 0 {
                    break;
                }
                for (lane, &i) in live[..active].iter().enumerate() {
                    x.row_mut(lane).copy_from_slice(&seqs[i][t]);
                }
                self.forward.step_batch(&x, &mut states, &mut out);
                for (lane, &i) in live[..active].iter().enumerate() {
                    let dst = &mut outs[i][t];
                    dst.reserve_exact(fwd_w + bwd_w);
                    dst.extend_from_slice(out.row(lane));
                }
            }
        }

        // Backward: lane `i`'s reverse step `r` consumes
        // `seqs[i][len_i - 1 - r]`, so its output lands at that
        // original position (appended after the forward half).
        {
            let n_in = self.backward.specs()[0].n_input;
            let mut states = self.backward.zero_batch_state(n_live);
            let mut active = n_live;
            let mut x = Matrix::<f32>::zeros(n_live, n_in);
            let mut out = Matrix::<f32>::zeros(n_live, bwd_w);
            for r in 0..t_max {
                let still = live.iter().take_while(|&&i| seqs[i].len() > r).count();
                if still < active {
                    self.backward.truncate_batch(&mut states, still);
                    x.truncate_rows(still);
                    out.truncate_rows(still);
                    active = still;
                }
                if active == 0 {
                    break;
                }
                for (lane, &i) in live[..active].iter().enumerate() {
                    x.row_mut(lane).copy_from_slice(&seqs[i][seqs[i].len() - 1 - r]);
                }
                self.backward.step_batch(&x, &mut states, &mut out);
                for (lane, &i) in live[..active].iter().enumerate() {
                    outs[i][seqs[i].len() - 1 - r].extend_from_slice(out.row(lane));
                }
            }
        }
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::LstmSpec;
    use crate::util::Pcg32;

    fn seqs(rng: &mut Pcg32, n: usize, t: usize, d: usize) -> Vec<Vec<Vec<f32>>> {
        (0..n)
            .map(|_| {
                (0..t)
                    .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect()
    }

    fn build_pair(seed: u64) -> (StackWeights, StackWeights, Vec<CalibrationStats>, Vec<CalibrationStats>, Vec<Vec<Vec<f32>>>) {
        let mut rng = Pcg32::seeded(seed);
        let spec = LstmSpec::plain(8, 16);
        let fwd = StackWeights::random(8, spec, 2, &mut rng);
        let bwd = StackWeights::random(8, spec, 2, &mut rng);
        let calib = seqs(&mut rng, 4, 12, 8);
        let rev: Vec<Vec<Vec<f32>>> = calib
            .iter()
            .map(|s| s.iter().rev().cloned().collect())
            .collect();
        let sf = fwd.calibrate(&calib);
        let sb = bwd.calibrate(&rev);
        (fwd, bwd, sf, sb, calib)
    }

    #[test]
    fn integer_bidirectional_tracks_float() {
        let (fwd, bwd, sf, sb, calib) = build_pair(61);
        let float = BiLstm::build(&fwd, &bwd, StackEngine::Float, None, None, Default::default());
        let integer = BiLstm::build(
            &fwd, &bwd, StackEngine::Integer, Some(&sf), Some(&sb), Default::default(),
        );
        assert_eq!(float.n_output(), 32);
        let seq = &calib[0];
        let fo = float.run_sequence(seq);
        let io = integer.run_sequence(seq);
        assert_eq!(fo.len(), seq.len());
        let mut worst = 0f64;
        for (a, b) in fo.iter().zip(&io) {
            assert_eq!(a.len(), 32);
            for (&x, &y) in a.iter().zip(b) {
                worst = worst.max(f64::from((x - y).abs()));
            }
        }
        assert!(worst < 0.1, "bidirectional divergence {worst}");
    }

    #[test]
    fn ragged_batch_matches_per_sequence() {
        // Variable-length lanes through the lane-truncating batch path
        // must be bit-exact with each sequence run alone, for the float
        // oracle and the integer engine alike.
        let (fwd, bwd, sf, sb, _) = build_pair(63);
        let engines = [
            BiLstm::build(&fwd, &bwd, StackEngine::Float, None, None, Default::default()),
            BiLstm::build(
                &fwd, &bwd, StackEngine::Integer, Some(&sf), Some(&sb), Default::default(),
            ),
        ];
        let mut rng = Pcg32::seeded(64);
        let lens = [1usize, 5, 12, 12, 7, 3];
        let seqs_in: Vec<Vec<Vec<f32>>> = lens
            .iter()
            .map(|&t| seqs(&mut rng, 1, t, 8).pop().unwrap())
            .collect();
        for bi in &engines {
            let ragged = bi.run_sequences(&seqs_in);
            for (i, s) in seqs_in.iter().enumerate() {
                let solo = bi.run_sequence(s);
                assert_eq!(ragged[i], solo, "seq {i} (len {})", s.len());
            }
        }
        // Degenerate lanes: empty batch and an empty sequence.
        assert!(engines[0].run_sequences(&[]).is_empty());
        let with_empty = engines[0].run_sequences(&[Vec::new(), seqs_in[1].clone()]);
        assert!(with_empty[0].is_empty());
        assert_eq!(with_empty[1], engines[0].run_sequence(&seqs_in[1]));
    }

    #[test]
    fn backward_direction_sees_future_context() {
        // The backward half at t=0 must depend on the *last* input.
        let (fwd, bwd, _, _, calib) = build_pair(62);
        let float = BiLstm::build(&fwd, &bwd, StackEngine::Float, None, None, Default::default());
        let mut seq = calib[0].clone();
        let out1 = float.run_sequence(&seq);
        let last = seq.len() - 1;
        seq[last].iter_mut().for_each(|v| *v += 1.0);
        let out2 = float.run_sequence(&seq);
        // Forward half at t=0 unchanged; backward half changed.
        let fwd_w = float.forward.n_output();
        assert_eq!(&out1[0][..fwd_w], &out2[0][..fwd_w]);
        assert_ne!(&out1[0][fwd_w..], &out2[0][fwd_w..]);
    }
}
