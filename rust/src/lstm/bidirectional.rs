//! Bidirectional LSTM (§7): the paper notes that uni/bidirectional
//! RNNs "have loops on top of LSTM cell and the quantization strategy
//! described in this work can be directly applied" — this wrapper is
//! that loop: a forward stack and a backward stack over the same input,
//! outputs concatenated per step. Any engine (float/hybrid/integer)
//! plugs in unchanged.

use super::quantize::QuantizeOptions;
use super::stack::{LstmStack, StackEngine, StackWeights};
use crate::lstm::CalibrationStats;
use crate::tensor::Matrix;

/// A bidirectional wrapper over two independent stacks.
pub struct BiLstm {
    pub forward: LstmStack,
    pub backward: LstmStack,
}

impl BiLstm {
    /// Build from two weight sets (they may differ — e.g. separately
    /// trained directions).
    pub fn build(
        fwd: &StackWeights,
        bwd: &StackWeights,
        engine: StackEngine,
        stats_fwd: Option<&[CalibrationStats]>,
        stats_bwd: Option<&[CalibrationStats]>,
        opts: QuantizeOptions,
    ) -> Self {
        BiLstm {
            forward: LstmStack::build(fwd, engine, stats_fwd, opts),
            backward: LstmStack::build(bwd, engine, stats_bwd, opts),
        }
    }

    /// Concatenated output width.
    pub fn n_output(&self) -> usize {
        self.forward.n_output() + self.backward.n_output()
    }

    /// Run a full sequence (bidirectional processing is inherently
    /// non-streaming): outputs `[T][fwd_out + bwd_out]`, where position
    /// `t` concatenates the forward pass at `t` with the backward pass
    /// at `t` (i.e. backward state has consumed `x[t..]`).
    pub fn run_sequence(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut fwd_states = self.forward.zero_state();
        let fo = self.forward.run_sequence(xs, &mut fwd_states);
        let reversed: Vec<Vec<f32>> = xs.iter().rev().cloned().collect();
        let mut bwd_states = self.backward.zero_state();
        let mut bo = self.backward.run_sequence(&reversed, &mut bwd_states);
        bo.reverse();
        fo.into_iter()
            .zip(bo)
            .map(|(mut f, b)| {
                f.extend(b);
                f
            })
            .collect()
    }

    /// Batch-major bidirectional run over a batch of equal-length
    /// sequences: `xs[t]` is `[batch, n_input]`; output `[T]` of
    /// `[batch, fwd_out + bwd_out]`. Both directions run the batched
    /// stack path, so all engines execute the same batch-major code.
    pub fn run_sequence_batch(&self, xs: &[Matrix<f32>]) -> Vec<Matrix<f32>> {
        let Some(first) = xs.first() else {
            return Vec::new();
        };
        let batch = first.rows;
        let mut fwd_states = self.forward.zero_batch_state(batch);
        let fo = self.forward.run_sequence_batch(xs, &mut fwd_states);
        // Backward pass iterates the inputs in reverse in place — no
        // reversed copy of the batch.
        let mut bwd_states = self.backward.zero_batch_state(batch);
        let bwd_out = self.backward.n_output();
        let mut bo = Vec::with_capacity(xs.len());
        for x in xs.iter().rev() {
            let mut out = Matrix::zeros(batch, bwd_out);
            self.backward.step_batch(x, &mut bwd_states, &mut out);
            bo.push(out);
        }
        bo.reverse();
        fo.into_iter()
            .zip(bo)
            .map(|(f, b)| {
                let mut m = Matrix::zeros(batch, f.cols + b.cols);
                for lane in 0..batch {
                    m.row_mut(lane)[..f.cols].copy_from_slice(f.row(lane));
                    m.row_mut(lane)[f.cols..].copy_from_slice(b.row(lane));
                }
                m
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::LstmSpec;
    use crate::util::Pcg32;

    fn seqs(rng: &mut Pcg32, n: usize, t: usize, d: usize) -> Vec<Vec<Vec<f32>>> {
        (0..n)
            .map(|_| {
                (0..t)
                    .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect()
    }

    fn build_pair(seed: u64) -> (StackWeights, StackWeights, Vec<CalibrationStats>, Vec<CalibrationStats>, Vec<Vec<Vec<f32>>>) {
        let mut rng = Pcg32::seeded(seed);
        let spec = LstmSpec::plain(8, 16);
        let fwd = StackWeights::random(8, spec, 2, &mut rng);
        let bwd = StackWeights::random(8, spec, 2, &mut rng);
        let calib = seqs(&mut rng, 4, 12, 8);
        let rev: Vec<Vec<Vec<f32>>> = calib
            .iter()
            .map(|s| s.iter().rev().cloned().collect())
            .collect();
        let sf = fwd.calibrate(&calib);
        let sb = bwd.calibrate(&rev);
        (fwd, bwd, sf, sb, calib)
    }

    #[test]
    fn integer_bidirectional_tracks_float() {
        let (fwd, bwd, sf, sb, calib) = build_pair(61);
        let float = BiLstm::build(&fwd, &bwd, StackEngine::Float, None, None, Default::default());
        let integer = BiLstm::build(
            &fwd, &bwd, StackEngine::Integer, Some(&sf), Some(&sb), Default::default(),
        );
        assert_eq!(float.n_output(), 32);
        let seq = &calib[0];
        let fo = float.run_sequence(seq);
        let io = integer.run_sequence(seq);
        assert_eq!(fo.len(), seq.len());
        let mut worst = 0f64;
        for (a, b) in fo.iter().zip(&io) {
            assert_eq!(a.len(), 32);
            for (&x, &y) in a.iter().zip(b) {
                worst = worst.max(f64::from((x - y).abs()));
            }
        }
        assert!(worst < 0.1, "bidirectional divergence {worst}");
    }

    #[test]
    fn backward_direction_sees_future_context() {
        // The backward half at t=0 must depend on the *last* input.
        let (fwd, bwd, _, _, calib) = build_pair(62);
        let float = BiLstm::build(&fwd, &bwd, StackEngine::Float, None, None, Default::default());
        let mut seq = calib[0].clone();
        let out1 = float.run_sequence(&seq);
        let last = seq.len() - 1;
        seq[last].iter_mut().for_each(|v| *v += 1.0);
        let out2 = float.run_sequence(&seq);
        // Forward half at t=0 unchanged; backward half changed.
        let fwd_w = float.forward.n_output();
        assert_eq!(&out1[0][..fwd_w], &out2[0][..fwd_w]);
        assert_ne!(&out1[0][fwd_w..], &out2[0][fwd_w..]);
    }
}
