//! The paper's contribution: the integer-only LSTM cell (§3).
//!
//! Everything on this execution path is integer arithmetic:
//!
//! * gate matmuls: int8 × int8 → int32, zero points folded into the
//!   bias offline (§6);
//! * three accumulators (`Wx`, `Rh + b`, `P⊙c`) rescaled by
//!   precomputed effective scales and saturating-added into the int16
//!   gate pre-activation — `Q3.12` without LN (§3.2.4), measured-scale
//!   int16 with LN (§3.2.5) followed by integer layer norm (§3.2.6);
//! * sigmoid/tanh in 16-bit fixed point, outputs `Q0.15` (§3.2.1);
//! * cell update with rounding shifts into `Q_{m.15-m}` int16 (§3.2.7);
//! * hidden/projection back to asymmetric int8 (§3.2.7–3.2.8);
//! * CIFG coupling as `min(32768 - f, 32767)` (§3.2.9).
//!
//! No floats, no branches in the elementwise loops, no lookup tables.

use crate::fixedpoint::mul::{
    rounding_divide_by_pot, saturate_i32_to_i16, saturate_i32_to_i8,
};
use crate::fixedpoint::Rescale;
use crate::nonlin::{sigmoid_q15_slice, tanh_q15_slice};
use crate::quant::params::AsymmetricQuant;
use crate::quant::recipe::Gate;
use crate::sparse::BlockSparseI8;
use crate::tensor::qmatmul::{PackedWeightsI4, PackedWeightsI8};
use crate::tensor::Matrix;
use super::layernorm::IntegerLayerNorm;
use super::spec::{gate_index, LstmSpec};

/// Dense, block-sparse, or nibble-packed weight matrix (the sparse and
/// sub-8-bit rows of Table 1).
///
/// Dense int8 weights are held pre-packed ([`PackedWeightsI8`]); pruned
/// weights are re-blocked into the same MR × K_BLOCK tile geometry
/// ([`BlockSparseI8`]) with all-zero blocks dropped; int4 weights are
/// nibble-packed into the same panel geometry at half the bytes
/// ([`PackedWeightsI4`]) and unpacked to i8 in-register by the GEMM.
/// Every conversion happens once, at quantization time, so the batched
/// step never packs, gathers, or hits scalar remainder tails.
#[derive(Debug, Clone)]
pub enum WeightMat {
    Dense(PackedWeightsI8),
    Sparse(BlockSparseI8),
    Int4(PackedWeightsI4),
}

impl WeightMat {
    /// Wrap a dense int8 matrix, packing it for the tiled batched GEMM.
    pub fn dense(m: Matrix<i8>) -> Self {
        WeightMat::Dense(PackedWeightsI8::pack(m))
    }

    /// Wrap a pruned int8 matrix, re-blocking it into the block-sparse
    /// execution format (all-zero MR × K_BLOCK tiles dropped).
    pub fn sparse(m: Matrix<i8>) -> Self {
        WeightMat::Sparse(BlockSparseI8::from_dense(&m))
    }

    /// Wrap an int4-range matrix (every value in `-8..=7`, which the
    /// symmetric −7..7 quantization rule guarantees), nibble-packing it
    /// into the half-width panel format. Values outside the int4 range
    /// panic at pack time.
    pub fn int4(m: &Matrix<i8>) -> Self {
        WeightMat::Int4(PackedWeightsI4::pack(m))
    }

    pub fn rows(&self) -> usize {
        match self {
            WeightMat::Dense(m) => m.rows(),
            WeightMat::Sparse(s) => s.rows,
            WeightMat::Int4(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            WeightMat::Dense(m) => m.cols(),
            WeightMat::Sparse(s) => s.cols,
            WeightMat::Int4(m) => m.cols(),
        }
    }

    /// `out[r] = bias[r] + Σ_c w[r,c] x[c]`.
    #[inline]
    pub fn matvec(&self, x: &[i8], bias: &[i32], out: &mut [i32]) {
        match self {
            WeightMat::Dense(m) => m.matvec(x, bias, out),
            WeightMat::Sparse(s) => s.matvec_i32(x, bias, out),
            WeightMat::Int4(m) => m.matvec(x, bias, out),
        }
    }

    /// Batched `out[b,r] = bias[r] + Σ_c w[r,c] x[b,c]`: dense weights
    /// go through the packed register-tiled GEMM, block-sparse weights
    /// through the block-list variant, int4 weights through the
    /// nibble-unpacking variant of the same kernel — all three run
    /// zero scalar tails for any batch or depth and are bit-exact with
    /// [`Self::matvec`] per lane.
    #[inline]
    pub fn matmul_batch(&self, x: &Matrix<i8>, bias: &[i32], out: &mut Matrix<i32>) {
        match self {
            WeightMat::Dense(m) => m.gemm(x, bias, out),
            WeightMat::Sparse(s) => s.gemm(x, bias, out),
            WeightMat::Int4(m) => m.gemm(x, bias, out),
        }
    }

    /// Storage bytes of the weight data (logical — the dense packing
    /// copy is an execution detail, not model size; int4 counts its
    /// nibble bytes, half the int8 figure).
    pub fn storage_bytes(&self) -> usize {
        match self {
            WeightMat::Dense(m) => m.storage_bytes(),
            WeightMat::Sparse(s) => s.storage_bytes(),
            WeightMat::Int4(m) => m.storage_bytes(),
        }
    }
}

/// One quantized gate (figs 2/3 and 5/6).
#[derive(Debug, Clone)]
pub struct IntegerGate {
    pub w: WeightMat,
    pub r: WeightMat,
    /// Folded bias for the `W x` accumulator: `zp_x * Σ_j W[i,j]`.
    pub w_bias: Vec<i32>,
    /// Folded bias for the `R h` accumulator: `zp_h * Σ_j R[i,j]`, plus
    /// the quantized gate bias (scale `s_R s_h`) when there is no LN.
    pub r_bias: Vec<i32>,
    /// `s_effx`: accumulator → gate-output domain.
    pub eff_x: Rescale,
    /// `s_effh`.
    pub eff_h: Rescale,
    /// Peephole weights (int16) and `s_effc`.
    pub peephole: Option<(Vec<i16>, Rescale)>,
    /// Integer layer norm (LN variants), producing `Q3.12`.
    pub ln: Option<IntegerLayerNorm>,
}

/// Quantized projection (figs 14/15).
#[derive(Debug, Clone)]
pub struct IntegerProjection {
    pub w: WeightMat,
    /// Quantized projection bias (scale `s_Wproj s_m`) + `zp_m` fold.
    pub bias: Vec<i32>,
    /// `s_Wproj s_m / s_h`.
    pub eff: Rescale,
}

/// The integer-only LSTM cell.
#[derive(Debug, Clone)]
pub struct IntegerLstm {
    pub spec: LstmSpec,
    pub gates: [Option<IntegerGate>; 4],
    /// Input quantization (`x`, int8 asymmetric).
    pub input_q: AsymmetricQuant,
    /// Output quantization (`h`, int8 asymmetric).
    pub output_q: AsymmetricQuant,
    /// Hidden quantization (`m`; equals `output_q` without projection).
    pub hidden_q: AsymmetricQuant,
    /// `2^-30 / s_m`: gate ⊙ tanh(c) product → hidden domain.
    pub eff_hidden: Rescale,
    /// Integer bits `m` of the cell state `Q_{m.15-m}` (POT-extended).
    pub cell_ib: u32,
    pub proj: Option<IntegerProjection>,
    scratch: std::cell::RefCell<Scratch>,
    /// Input quantization buffer (separate cell so `step` can fill it
    /// while `step_q` borrows the main scratch).
    qx_buf: std::cell::RefCell<Vec<i8>>,
    batch_scratch: std::cell::RefCell<BatchScratch>,
    /// Batched input quantization buffer (separate cell, same reason as
    /// `qx_buf`).
    batch_qx: std::cell::RefCell<Matrix<i8>>,
}

/// Integer recurrent state: the persistent tensors of §3.2.2/§3.2.7.
#[derive(Debug, Clone)]
pub struct IntegerState {
    /// Cell state, int16 `Q_{m.15-m}`.
    pub c: Vec<i16>,
    /// Output, int8 asymmetric (raw stored values).
    pub h: Vec<i8>,
}

impl IntegerState {
    /// Zero state: `c = 0`; `h` at its zero point (so it dequantizes to
    /// exactly 0.0 — guaranteed representable by the nudging of §3.2.4).
    pub fn zeros(lstm: &IntegerLstm) -> Self {
        IntegerState {
            c: vec![0; lstm.spec.n_cell],
            h: vec![lstm.output_q.zero_point as i8; lstm.spec.n_output],
        }
    }
}

/// Batch-major integer recurrent state: lane `b` is row `b` of each
/// matrix, so packing/unpacking a session is a row copy — with int16
/// cell + int8 hidden this is ~3 bytes per element, the cheapness that
/// makes per-token gather/scatter viable in the serving loop.
#[derive(Debug, Clone)]
pub struct IntegerBatchState {
    /// Cell states, int16 `Q_{m.15-m}`: `[batch, n_cell]`.
    pub c: Matrix<i16>,
    /// Outputs, int8 asymmetric: `[batch, n_output]`.
    pub h: Matrix<i8>,
}

impl IntegerBatchState {
    /// Zero state for `batch` lanes (`h` at its zero point, like
    /// [`IntegerState::zeros`]).
    pub fn zeros(lstm: &IntegerLstm, batch: usize) -> Self {
        let mut h = Matrix::zeros(batch, lstm.spec.n_output);
        for v in &mut h.data {
            *v = lstm.output_q.zero_point as i8;
        }
        IntegerBatchState { c: Matrix::zeros(batch, lstm.spec.n_cell), h }
    }

    /// Live lane count.
    pub fn batch(&self) -> usize {
        self.c.rows
    }

    /// Pack one session's state into lane `lane`.
    pub fn gather(&mut self, lane: usize, s: &IntegerState) {
        self.c.row_mut(lane).copy_from_slice(&s.c);
        self.h.row_mut(lane).copy_from_slice(&s.h);
    }

    /// Unpack lane `lane` back into a session's state.
    pub fn scatter(&self, lane: usize, s: &mut IntegerState) {
        s.c.copy_from_slice(self.c.row(lane));
        s.h.copy_from_slice(self.h.row(lane));
    }

    /// Drop lanes `k..` (scatter them out first); the surviving prefix
    /// stays in place so no repacking is needed.
    pub fn truncate(&mut self, k: usize) {
        self.c.truncate_rows(k);
        self.h.truncate_rows(k);
    }

    /// Resize to `batch` lanes in place (allocation-reusing). Existing
    /// lanes keep their contents; grown lanes are unspecified — gather
    /// into them before stepping.
    pub fn resize(&mut self, batch: usize) {
        self.c.resize(batch, self.c.cols);
        self.h.resize(batch, self.h.cols);
    }

    /// Copy lane `src` over lane `dst` (continuous-batching compaction:
    /// survivors move down so live lanes stay a dense prefix).
    pub fn copy_lane(&mut self, src: usize, dst: usize) {
        self.c.copy_row_within(src, dst);
        self.h.copy_row_within(src, dst);
    }

    /// Zero lanes `from..` — the SIMD padding contract: a serving batch
    /// is rounded up to the register-tile width, and the pad lanes are
    /// zeroed here so they carry a deterministic zero stream. They are
    /// stepped (that is the point: the GEMM always sees full tiles) but
    /// never gathered into, scattered out, or read back.
    pub fn clear_lanes(&mut self, from: usize) {
        let c0 = from.min(self.c.rows) * self.c.cols;
        self.c.data[c0..].fill(0);
        let h0 = from.min(self.h.rows) * self.h.cols;
        self.h.data[h0..].fill(0);
    }
}

#[derive(Debug, Clone)]
struct Scratch {
    acc_x: Vec<i32>,
    acc_h: Vec<i32>,
    gate_out: [Vec<i16>; 4],
    gate_act: [Vec<i16>; 4],
    ln_in: Vec<i16>,
    tanh_c: Vec<i16>,
    m: Vec<i8>,
}

/// Batch-major scratch: the [`Scratch`] buffers widened to
/// `[batch, n]`, lazily resized to the live batch.
#[derive(Debug, Clone)]
struct BatchScratch {
    acc_x: Matrix<i32>,
    acc_h: Matrix<i32>,
    acc_proj: Matrix<i32>,
    gate_out: [Vec<i16>; 4],
    gate_act: [Vec<i16>; 4],
    ln_in: Vec<i16>,
    tanh_c: Vec<i16>,
    m: Matrix<i8>,
}

impl BatchScratch {
    fn empty() -> Self {
        BatchScratch {
            acc_x: Matrix::zeros(0, 0),
            acc_h: Matrix::zeros(0, 0),
            acc_proj: Matrix::zeros(0, 0),
            gate_out: std::array::from_fn(|_| Vec::new()),
            gate_act: std::array::from_fn(|_| Vec::new()),
            ln_in: Vec::new(),
            tanh_c: Vec::new(),
            m: Matrix::zeros(0, 0),
        }
    }

    fn ensure(&mut self, spec: &LstmSpec, batch: usize) {
        if self.m.rows != batch || self.m.cols != spec.n_cell {
            // Every buffer is fully overwritten before it is read, so
            // resize-in-place (allocation-reusing) is safe — per-wave
            // batch changes in the serving loop must not reallocate.
            let total = batch * spec.n_cell;
            self.acc_x.resize(batch, spec.n_cell);
            self.acc_h.resize(batch, spec.n_cell);
            self.acc_proj.resize(batch, spec.n_output);
            for v in self.gate_out.iter_mut().chain(self.gate_act.iter_mut()) {
                v.resize(total, 0);
            }
            self.ln_in.resize(total, 0);
            self.tanh_c.resize(total, 0);
            self.m.resize(batch, spec.n_cell);
        }
    }
}

impl IntegerLstm {
    pub(super) fn new_with_parts(
        spec: LstmSpec,
        gates: [Option<IntegerGate>; 4],
        input_q: AsymmetricQuant,
        output_q: AsymmetricQuant,
        hidden_q: AsymmetricQuant,
        cell_ib: u32,
        proj: Option<IntegerProjection>,
    ) -> Self {
        let s_m = hidden_q.scale;
        let eff_hidden = Rescale::from_scale(2f64.powi(-30) / s_m);
        let scratch = Scratch {
            acc_x: vec![0; spec.n_cell.max(spec.n_output)],
            acc_h: vec![0; spec.n_cell],
            gate_out: std::array::from_fn(|_| vec![0; spec.n_cell]),
            gate_act: std::array::from_fn(|_| vec![0; spec.n_cell]),
            ln_in: vec![0; spec.n_cell],
            tanh_c: vec![0; spec.n_cell],
            m: vec![0; spec.n_cell],
        };
        IntegerLstm {
            spec,
            gates,
            input_q,
            output_q,
            hidden_q,
            eff_hidden,
            cell_ib,
            proj,
            scratch: std::cell::RefCell::new(scratch),
            qx_buf: std::cell::RefCell::new(vec![0; spec.n_input]),
            batch_scratch: std::cell::RefCell::new(BatchScratch::empty()),
            batch_qx: std::cell::RefCell::new(Matrix::zeros(0, 0)),
        }
    }

    /// Build directly from raw integer parts (multipliers, shifts, zero
    /// points) — used by the cross-layer golden tests, where the
    /// parameters come from the python quantizer and must be used
    /// verbatim (bit-exactness would be lost re-deriving them from
    /// float scales).
    #[allow(clippy::too_many_arguments)]
    pub fn from_raw_parts(
        spec: LstmSpec,
        gates: [Option<IntegerGate>; 4],
        zp_x: i32,
        zp_h: i32,
        zp_m: i32,
        eff_hidden: Rescale,
        cell_ib: u32,
        proj: Option<IntegerProjection>,
    ) -> Self {
        let mut cell = Self::new_with_parts(
            spec,
            gates,
            AsymmetricQuant { scale: 1.0, zero_point: zp_x },
            AsymmetricQuant { scale: 1.0, zero_point: zp_h },
            AsymmetricQuant { scale: 1.0, zero_point: zp_m },
            cell_ib,
            proj,
        );
        cell.eff_hidden = eff_hidden;
        cell
    }

    fn gate(&self, g: Gate) -> &IntegerGate {
        self.gates[gate_index(g)].as_ref().expect("gate absent")
    }

    /// Quantized-weight bytes (Table 1 size accounting).
    pub fn weight_bytes(&self) -> usize {
        let mut bytes = 0;
        for g in self.gates.iter().flatten() {
            bytes += g.w.storage_bytes() + g.r.storage_bytes();
            bytes += 4 * g.r_bias.len(); // int32 bias
            if let Some((p, _)) = &g.peephole {
                bytes += 2 * p.len();
            }
            if let Some(ln) = &g.ln {
                bytes += 2 * ln.weight.len() + 4 * ln.bias.len();
            }
        }
        if let Some(p) = &self.proj {
            bytes += p.w.storage_bytes() + 4 * p.bias.len();
        }
        bytes
    }

    /// Compute one gate's int16 pre-activation (fig 3 / fig 6):
    /// `rescale(Wx, effx) + rescale(Rh + b, effh) + rescale(P⊙c, effc)`,
    /// then integer LN when present. Output is `Q3.12`.
    fn gate_forward(
        &self,
        g: Gate,
        qx: &[i8],
        state: &IntegerState,
        c_for_peephole: &[i16],
        acc_x: &mut [i32],
        acc_h: &mut [i32],
        ln_in: &mut [i16],
        out: &mut [i16],
    ) {
        let ig = self.gate(g);
        let n = self.spec.n_cell;
        ig.w.matvec(qx, &ig.w_bias, &mut acc_x[..n]);
        ig.r.matvec(&state.h, &ig.r_bias, &mut acc_h[..n]);
        let target: &mut [i16] =
            if ig.ln.is_some() { &mut ln_in[..n] } else { &mut out[..n] };
        #[cfg(target_arch = "x86_64")]
        {
            if crate::util::avx2_enabled() {
                // SAFETY: feature checked; fused kernels are bit-exact
                // with the scalar fallback below (property-tested).
                unsafe {
                    match &ig.peephole {
                        Some((p, eff_c)) => {
                            crate::nonlin::simd::gate_rescale_peephole_avx2(
                                &acc_x[..n], ig.eff_x, &acc_h[..n], ig.eff_h,
                                p, c_for_peephole, *eff_c, target,
                            );
                        }
                        None => crate::nonlin::simd::gate_rescale_avx2(
                            &acc_x[..n], ig.eff_x, &acc_h[..n], ig.eff_h, target,
                        ),
                    }
                }
                if let Some(ln) = &ig.ln {
                    ln.apply(&ln_in[..n], &mut out[..n]);
                }
                return;
            }
        }
        match &ig.peephole {
            Some((p, eff_c)) => {
                for j in 0..n {
                    // P⊙c: int16 × int16 → int32 (no accumulation, §3.2.4).
                    let pc = i32::from(p[j]) * i32::from(c_for_peephole[j]);
                    let sum = ig.eff_x.apply(acc_x[j])
                        + ig.eff_h.apply(acc_h[j])
                        + eff_c.apply(pc);
                    target[j] = saturate_i32_to_i16(sum);
                }
            }
            None => {
                for j in 0..n {
                    let sum = ig.eff_x.apply(acc_x[j]) + ig.eff_h.apply(acc_h[j]);
                    target[j] = saturate_i32_to_i16(sum);
                }
            }
        }
        if let Some(ln) = &ig.ln {
            ln.apply(&ln_in[..n], &mut out[..n]);
        }
    }

    /// One time step with an int8 input (already in the `x` domain).
    pub fn step_q(&self, qx: &[i8], state: &mut IntegerState) {
        let spec = self.spec;
        assert_eq!(qx.len(), spec.n_input);
        assert_eq!(state.c.len(), spec.n_cell);
        assert_eq!(state.h.len(), spec.n_output);
        let mut s = self.scratch.borrow_mut();
        let Scratch { acc_x, acc_h, gate_out, gate_act, ln_in, tanh_c, m } = &mut *s;
        let n = spec.n_cell;

        // Pre-activations for f, z (and i when physical); all Q3.12.
        for (g, idx) in [(Gate::Forget, 1), (Gate::Update, 2), (Gate::Input, 0)] {
            if g == Gate::Input && !spec.has_input_gate() {
                continue;
            }
            let (a, b) = {
                // Split borrows: gate_out[idx] vs scratch accumulators.
                (&mut *acc_x, &mut *acc_h)
            };
            self.gate_forward(g, qx, state, &state.c, a, b, ln_in, &mut gate_out[idx]);
        }

        // Activations: σ for gates, tanh for the update (§3.2.1) —
        // slice kernels (AVX2 when available).
        sigmoid_q15_slice(&gate_out[1][..n], 3, &mut gate_act[1][..n]);
        tanh_q15_slice(&gate_out[2][..n], 3, &mut gate_act[2][..n]);
        if spec.has_input_gate() {
            sigmoid_q15_slice(&gate_out[0][..n], 3, &mut gate_act[0][..n]);
        } else {
            // CIFG (§3.2.9): i = min(32768 - f, 32767), clamped into
            // [1/32768, 32767/32768].
            for j in 0..n {
                gate_act[0][j] =
                    saturate_i32_to_i16((32768 - i32::from(gate_act[1][j])).min(32767));
            }
        }

        // Cell update (§3.2.7): c = shift(i⊙z) + shift(f⊙c), saturated
        // into Q_{m.15-m}. i,z are Q0.15 (30 fractional bits in the
        // product); the cell has 15-m fractional bits, so the product
        // shifts right by 15+m; f⊙c has 15 extra fractional bits.
        let iz_shift = 15 + self.cell_ib as i32;
        for j in 0..n {
            let iz = i32::from(gate_act[0][j]) * i32::from(gate_act[2][j]);
            let fc = i32::from(gate_act[1][j]) * i32::from(state.c[j]);
            let sum = rounding_divide_by_pot(iz, iz_shift)
                + rounding_divide_by_pot(fc, 15);
            state.c[j] = saturate_i32_to_i16(sum);
        }

        // Output gate (peephole reads the *new* c, eq 5).
        {
            let (a, b) = (&mut *acc_x, &mut *acc_h);
            self.gate_forward(Gate::Output, qx, state, &state.c, a, b, ln_in, &mut gate_out[3]);
        }
        sigmoid_q15_slice(&gate_out[3][..n], 3, &mut gate_act[3][..n]);

        // Hidden state (§3.2.7): m = rescale(o ⊙ tanh(c), 2^-30/s_m) + zp_m.
        tanh_q15_slice(&state.c[..n], self.cell_ib, &mut tanh_c[..n]);
        let zp_m = self.hidden_q.zero_point;
        #[cfg(target_arch = "x86_64")]
        let simd_done = if crate::util::avx2_enabled() {
            // SAFETY: feature checked; bit-exact with the scalar loop.
            unsafe {
                crate::nonlin::simd::hidden_rescale_avx2(
                    &gate_act[3][..n], &tanh_c[..n], self.eff_hidden, zp_m, &mut m[..n],
                );
            }
            true
        } else {
            false
        };
        #[cfg(not(target_arch = "x86_64"))]
        let simd_done = false;
        if !simd_done {
            for j in 0..n {
                let prod = i32::from(gate_act[3][j]) * i32::from(tanh_c[j]);
                m[j] = saturate_i32_to_i8(self.eff_hidden.apply(prod) + zp_m);
            }
        }

        // Projection (§3.2.8) or pass-through.
        match &self.proj {
            Some(p) => {
                let n_out = spec.n_output;
                p.w.matvec(m, &p.bias, &mut acc_x[..n_out]);
                let zp_h = self.output_q.zero_point;
                for j in 0..n_out {
                    state.h[j] = saturate_i32_to_i8(p.eff.apply(acc_x[j]) + zp_h);
                }
            }
            None => {
                for j in 0..n {
                    state.h[j] = m[j];
                }
            }
        }
    }

    /// Batch-major gate pre-activation: [`Self::gate_forward`] with the
    /// two matmuls batched and the fused rescale kernels run per lane —
    /// identical per-element operations, so bit-exact with sequential.
    #[allow(clippy::too_many_arguments)]
    fn gate_forward_batch(
        &self,
        g: Gate,
        qx: &Matrix<i8>,
        h: &Matrix<i8>,
        c_for_peephole: &Matrix<i16>,
        acc_x: &mut Matrix<i32>,
        acc_h: &mut Matrix<i32>,
        ln_in: &mut [i16],
        out: &mut [i16],
    ) {
        let ig = self.gate(g);
        let n = self.spec.n_cell;
        let batch = qx.rows;
        ig.w.matmul_batch(qx, &ig.w_bias, acc_x);
        ig.r.matmul_batch(h, &ig.r_bias, acc_h);
        for b in 0..batch {
            let ax = acc_x.row(b);
            let ah = acc_h.row(b);
            let target: &mut [i16] = if ig.ln.is_some() {
                &mut ln_in[b * n..(b + 1) * n]
            } else {
                &mut out[b * n..(b + 1) * n]
            };
            #[cfg(target_arch = "x86_64")]
            {
                if crate::util::avx2_enabled() {
                    // SAFETY: feature checked; kernels are bit-exact
                    // with the scalar fallback (property-tested).
                    unsafe {
                        match &ig.peephole {
                            Some((p, eff_c)) => {
                                crate::nonlin::simd::gate_rescale_peephole_avx2(
                                    ax, ig.eff_x, ah, ig.eff_h,
                                    p, c_for_peephole.row(b), *eff_c, target,
                                );
                            }
                            None => crate::nonlin::simd::gate_rescale_avx2(
                                ax, ig.eff_x, ah, ig.eff_h, target,
                            ),
                        }
                    }
                    continue;
                }
            }
            match &ig.peephole {
                Some((p, eff_c)) => {
                    let c_row = c_for_peephole.row(b);
                    for j in 0..n {
                        let pc = i32::from(p[j]) * i32::from(c_row[j]);
                        let sum = ig.eff_x.apply(ax[j])
                            + ig.eff_h.apply(ah[j])
                            + eff_c.apply(pc);
                        target[j] = saturate_i32_to_i16(sum);
                    }
                }
                None => {
                    for j in 0..n {
                        let sum = ig.eff_x.apply(ax[j]) + ig.eff_h.apply(ah[j]);
                        target[j] = saturate_i32_to_i16(sum);
                    }
                }
            }
        }
        if let Some(ln) = &ig.ln {
            // Integer LN normalizes across the hidden dimension: per lane.
            for b in 0..batch {
                ln.apply(&ln_in[b * n..(b + 1) * n], &mut out[b * n..(b + 1) * n]);
            }
        }
    }

    /// One batch-major time step with int8 inputs already in the `x`
    /// domain: row `b` of `qx` advances lane `b` of `state`. Bit-exact
    /// with running [`Self::step_q`] on each lane independently (the
    /// acceptance property of the batch-major refactor).
    pub fn step_batch_q(&self, qx: &Matrix<i8>, state: &mut IntegerBatchState) {
        let spec = self.spec;
        let batch = qx.rows;
        assert_eq!(qx.cols, spec.n_input);
        assert_eq!(state.c.rows, batch);
        assert_eq!(state.h.rows, batch);
        let mut s = self.batch_scratch.borrow_mut();
        s.ensure(&spec, batch);
        let BatchScratch { acc_x, acc_h, acc_proj, gate_out, gate_act, ln_in, tanh_c, m } =
            &mut *s;
        let n = spec.n_cell;
        let total = batch * n;

        // Pre-activations for f, z (and i when physical); all Q3.12.
        for (g, idx) in [(Gate::Forget, 1), (Gate::Update, 2), (Gate::Input, 0)] {
            if g == Gate::Input && !spec.has_input_gate() {
                continue;
            }
            self.gate_forward_batch(
                g, qx, &state.h, &state.c, acc_x, acc_h, ln_in, &mut gate_out[idx],
            );
        }

        // Activations over the flat `[batch * n_cell]` buffers — the
        // slice kernels are elementwise, so grouping lanes into one call
        // changes nothing per element.
        sigmoid_q15_slice(&gate_out[1][..total], 3, &mut gate_act[1][..total]);
        tanh_q15_slice(&gate_out[2][..total], 3, &mut gate_act[2][..total]);
        if spec.has_input_gate() {
            sigmoid_q15_slice(&gate_out[0][..total], 3, &mut gate_act[0][..total]);
        } else {
            // CIFG (§3.2.9).
            for j in 0..total {
                gate_act[0][j] =
                    saturate_i32_to_i16((32768 - i32::from(gate_act[1][j])).min(32767));
            }
        }

        // Cell update (§3.2.7).
        let iz_shift = 15 + self.cell_ib as i32;
        for j in 0..total {
            let iz = i32::from(gate_act[0][j]) * i32::from(gate_act[2][j]);
            let fc = i32::from(gate_act[1][j]) * i32::from(state.c.data[j]);
            let sum = rounding_divide_by_pot(iz, iz_shift)
                + rounding_divide_by_pot(fc, 15);
            state.c.data[j] = saturate_i32_to_i16(sum);
        }

        // Output gate (peephole reads the *new* c, eq 5).
        self.gate_forward_batch(
            Gate::Output, qx, &state.h, &state.c, acc_x, acc_h, ln_in, &mut gate_out[3],
        );
        sigmoid_q15_slice(&gate_out[3][..total], 3, &mut gate_act[3][..total]);

        // Hidden state (§3.2.7).
        tanh_q15_slice(&state.c.data[..total], self.cell_ib, &mut tanh_c[..total]);
        let zp_m = self.hidden_q.zero_point;
        #[cfg(target_arch = "x86_64")]
        let simd_done = if crate::util::avx2_enabled() {
            // SAFETY: feature checked; bit-exact with the scalar loop.
            unsafe {
                crate::nonlin::simd::hidden_rescale_avx2(
                    &gate_act[3][..total],
                    &tanh_c[..total],
                    self.eff_hidden,
                    zp_m,
                    &mut m.data[..total],
                );
            }
            true
        } else {
            false
        };
        #[cfg(not(target_arch = "x86_64"))]
        let simd_done = false;
        if !simd_done {
            for j in 0..total {
                let prod = i32::from(gate_act[3][j]) * i32::from(tanh_c[j]);
                m.data[j] = saturate_i32_to_i8(self.eff_hidden.apply(prod) + zp_m);
            }
        }

        // Projection (§3.2.8) or pass-through.
        match &self.proj {
            Some(p) => {
                p.w.matmul_batch(m, &p.bias, acc_proj);
                let zp_h = self.output_q.zero_point;
                for (hv, &a) in state.h.data.iter_mut().zip(acc_proj.data.iter()) {
                    *hv = saturate_i32_to_i8(p.eff.apply(a) + zp_h);
                }
            }
            None => state.h.data.copy_from_slice(&m.data),
        }
    }

    /// Batch-major step from float inputs: static input quantization per
    /// lane, then [`Self::step_batch_q`].
    pub fn step_batch(&self, x: &Matrix<f32>, state: &mut IntegerBatchState) {
        assert_eq!(x.cols, self.spec.n_input);
        let mut qx = self.batch_qx.borrow_mut();
        qx.resize(x.rows, x.cols);
        for (q, &v) in qx.data.iter_mut().zip(x.data.iter()) {
            *q = self.input_q.quantize(f64::from(v));
        }
        self.step_batch_q(&qx, state);
    }

    /// Dequantize one lane of the batched output state.
    pub fn dequantize_h_lane(&self, state: &IntegerBatchState, lane: usize, out: &mut [f32]) {
        for (o, &q) in out.iter_mut().zip(state.h.row(lane)) {
            *o = self.output_q.dequantize(q) as f32;
        }
    }

    /// Dequantize the whole batched output state (`out` is
    /// `[batch, n_output]`).
    pub fn dequantize_h_batch(&self, state: &IntegerBatchState, out: &mut Matrix<f32>) {
        assert_eq!(out.rows, state.h.rows);
        assert_eq!(out.cols, state.h.cols);
        for (o, &q) in out.data.iter_mut().zip(state.h.data.iter()) {
            *o = self.output_q.dequantize(q) as f32;
        }
    }

    /// One step from a float input: quantize with the *precomputed*
    /// input scale (a static transformation at the system boundary —
    /// not the hybrid engine's dynamic on-the-fly requantization) and
    /// run the integer path.
    pub fn step(&self, x: &[f32], state: &mut IntegerState) {
        let mut qx = self.qx_buf.borrow_mut();
        for (q, &v) in qx.iter_mut().zip(x) {
            *q = self.input_q.quantize(f64::from(v));
        }
        self.step_q(&qx, state);
    }

    /// Dequantize the output state to floats.
    pub fn dequantize_h(&self, state: &IntegerState, out: &mut [f32]) {
        for (o, &q) in out.iter_mut().zip(&state.h) {
            *o = self.output_q.dequantize(q) as f32;
        }
    }

    /// Dequantize the cell state (`Q_{m.15-m}`).
    pub fn dequantize_c(&self, state: &IntegerState, out: &mut [f32]) {
        let scale = 2f64.powi(self.cell_ib as i32 - 15);
        for (o, &q) in out.iter_mut().zip(&state.c) {
            *o = (f64::from(q) * scale) as f32;
        }
    }

    /// Run a full float sequence, returning dequantized outputs.
    pub fn run_sequence(&self, xs: &[Vec<f32>], state: &mut IntegerState) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(xs.len());
        let mut h = vec![0f32; self.spec.n_output];
        for x in xs {
            self.step(x, state);
            self.dequantize_h(state, &mut h);
            out.push(h.clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::float_cell::{FloatLstm, FloatState};
    use crate::lstm::quantize::{
        quantize_lstm, CalibrationStats, QuantizeOptions, WeightBits,
    };
    use crate::lstm::spec::LstmWeights;
    use crate::quant::recipe::VariantFlags;
    use crate::sparse::prune_magnitude;
    use crate::util::Pcg32;

    fn make_seqs(rng: &mut Pcg32, n: usize, t: usize, dim: usize) -> Vec<Vec<Vec<f32>>> {
        (0..n)
            .map(|_| {
                (0..t)
                    .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                    .collect()
            })
            .collect()
    }

    /// Calibrate + quantize with explicit options + compare against
    /// float on held-out data. Returns the mean absolute output
    /// divergence. `prune` magnitude-prunes the gate weights first
    /// (the sparse-storage scenario).
    fn divergence_opts(
        flags: VariantFlags,
        prune: bool,
        opts: QuantizeOptions,
        seed: u64,
    ) -> f64 {
        let mut rng = Pcg32::seeded(seed);
        let mut spec = crate::lstm::spec::LstmSpec::plain(12, 32);
        spec.flags = flags;
        if flags.projection {
            spec.n_output = 20;
        }
        let mut w = LstmWeights::random(spec, &mut rng);
        if prune {
            for g in w.gates.iter_mut().flatten() {
                prune_magnitude(&mut g.w, 0.5);
                prune_magnitude(&mut g.r, 0.5);
            }
        }
        let float = FloatLstm::new(w.clone());
        let calib = make_seqs(&mut rng, 8, 24, 12);
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&w, &stats, opts);

        let eval = make_seqs(&mut rng, 4, 32, 12);
        let mut total = 0f64;
        let mut count = 0usize;
        for seq in &eval {
            let mut fs = FloatState::zeros(&spec);
            let mut is = IntegerState::zeros(&integer);
            let fo = float.run_sequence(seq, &mut fs);
            let io = integer.run_sequence(seq, &mut is);
            for (a, b) in fo.iter().zip(&io) {
                for (&x, &y) in a.iter().zip(b) {
                    total += f64::from((x - y).abs());
                    count += 1;
                }
            }
        }
        total / count as f64
    }

    /// The int8 shorthand the pre-int4 tests use.
    fn divergence(flags: VariantFlags, sparse: bool, seed: u64) -> f64 {
        divergence_opts(
            flags,
            sparse,
            QuantizeOptions { sparse_weights: sparse, ..Default::default() },
            seed,
        )
    }

    #[test]
    fn integer_tracks_float_plain() {
        let d = divergence(VariantFlags::plain(), false, 101);
        assert!(d < 0.03, "mean divergence {d}");
    }

    #[test]
    fn integer_tracks_float_all_eight_variants() {
        for flags in VariantFlags::all_eight() {
            let d = divergence(flags, false, 202);
            assert!(d < 0.04, "{flags:?}: mean divergence {d}");
        }
    }

    #[test]
    fn integer_tracks_float_cifg_variants() {
        for ln in [false, true] {
            for ph in [false, true] {
                let flags = VariantFlags {
                    cifg: true,
                    layer_norm: ln,
                    peephole: ph,
                    projection: false,
                };
                let d = divergence(flags, false, 303);
                assert!(d < 0.04, "{flags:?}: mean divergence {d}");
            }
        }
    }

    #[test]
    fn integer_tracks_float_int4_weights() {
        // Int4 weights cost accuracy (16x coarser grid) but must stay
        // in the same ballpark, not diverge — the bench tracks the
        // exact bits/char delta, this pins "still works".
        let opts = QuantizeOptions {
            weight_bits: WeightBits::Int4,
            ..Default::default()
        };
        let d = divergence_opts(VariantFlags::plain(), false, opts, 505);
        assert!(d < 0.3, "int4 mean divergence {d}");
        let mut flags = VariantFlags::plain();
        flags.projection = true;
        let d = divergence_opts(flags, false, opts, 506);
        assert!(d < 0.3, "int4 projection mean divergence {d}");
    }

    #[test]
    fn int4_weight_bytes_at_most_55_percent_of_int8() {
        // The acceptance bound, at the whole-cell level (biases and
        // scales stay full width; the weight matrices halve).
        let mut rng = Pcg32::seeded(89);
        let spec = crate::lstm::spec::LstmSpec::plain(128, 128);
        let w = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(w.clone());
        let calib = make_seqs(&mut rng, 2, 8, 128);
        let stats = CalibrationStats::collect(&float, &calib);
        let int8 = quantize_lstm(&w, &stats, QuantizeOptions::default());
        let int4 = quantize_lstm(
            &w,
            &stats,
            QuantizeOptions { weight_bits: WeightBits::Int4, ..Default::default() },
        );
        let ratio = int4.weight_bytes() as f64 / int8.weight_bytes() as f64;
        assert!(ratio <= 0.55, "int4/int8 byte ratio {ratio}");
    }

    #[test]
    #[should_panic]
    fn sparse_plus_int4_panics() {
        // The mutually-exclusive combination must refuse loudly, never
        // silently pick one format.
        let mut rng = Pcg32::seeded(90);
        let spec = crate::lstm::spec::LstmSpec::plain(6, 8);
        let w = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(w.clone());
        let calib = make_seqs(&mut rng, 2, 4, 6);
        let stats = CalibrationStats::collect(&float, &calib);
        let _ = quantize_lstm(
            &w,
            &stats,
            QuantizeOptions {
                sparse_weights: true,
                weight_bits: WeightBits::Int4,
                ..Default::default()
            },
        );
    }

    #[test]
    fn integer_tracks_float_sparse() {
        let d = divergence(VariantFlags::plain(), true, 404);
        assert!(d < 0.03, "sparse mean divergence {d}");
        let mut flags = VariantFlags::plain();
        flags.cifg = true;
        let d = divergence(flags, true, 404);
        assert!(d < 0.03, "sparse CIFG mean divergence {d}");
    }

    #[test]
    fn long_sequence_error_does_not_blow_up() {
        // The paper's YouTube result: robustness on long utterances.
        // Error must stay bounded over 1000 steps, not accumulate.
        let mut rng = Pcg32::seeded(55);
        let spec = crate::lstm::spec::LstmSpec::plain(8, 24);
        let w = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(w.clone());
        let calib = make_seqs(&mut rng, 6, 32, 8);
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&w, &stats, QuantizeOptions::default());

        let seq = make_seqs(&mut rng, 1, 1000, 8).pop().unwrap();
        let mut fs = FloatState::zeros(&spec);
        let mut is = IntegerState::zeros(&integer);
        let fo = float.run_sequence(&seq, &mut fs);
        let io = integer.run_sequence(&seq, &mut is);
        let err_of = |lo: usize, hi: usize| -> f64 {
            let mut tot = 0.0;
            let mut n = 0;
            for t in lo..hi {
                for (a, b) in fo[t].iter().zip(&io[t]) {
                    tot += f64::from((a - b).abs());
                    n += 1;
                }
            }
            tot / f64::from(n as u32)
        };
        let head = err_of(10, 110);
        let tail = err_of(890, 990);
        assert!(tail < 0.06, "tail error {tail}");
        assert!(tail < head * 6.0 + 0.02, "head {head} tail {tail}: drift");
    }

    #[test]
    fn integer_state_zero_dequantizes_to_zero() {
        let mut rng = Pcg32::seeded(77);
        let spec = crate::lstm::spec::LstmSpec::plain(4, 8);
        let w = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(w.clone());
        let calib = make_seqs(&mut rng, 2, 8, 4);
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&w, &stats, QuantizeOptions::default());
        let st = IntegerState::zeros(&integer);
        let mut h = vec![1f32; 8];
        integer.dequantize_h(&st, &mut h);
        assert!(h.iter().all(|&v| v == 0.0), "{h:?}");
    }

    #[test]
    fn weight_bytes_quarter_of_float() {
        let mut rng = Pcg32::seeded(88);
        let spec = crate::lstm::spec::LstmSpec::plain(128, 128);
        let w = LstmWeights::random(spec, &mut rng);
        let float_bytes = w.param_count() * 4;
        let float = FloatLstm::new(w.clone());
        let calib = make_seqs(&mut rng, 2, 8, 128);
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&w, &stats, QuantizeOptions::default());
        let ratio = float_bytes as f64 / integer.weight_bytes() as f64;
        assert!(ratio > 3.0, "compression ratio {ratio}");
    }

    #[test]
    fn cifg_integer_coupling_range() {
        // §3.2.9: coupled input gate lies in [1/32768, 32767/32768].
        for f in [0i32, 1, 16384, 32767] {
            let i = (32768 - f).min(32767);
            assert!((1..=32767).contains(&i), "f={f} i={i}");
        }
    }

    #[test]
    fn step_q_equals_step_on_prequantized_input() {
        let mut rng = Pcg32::seeded(99);
        let spec = crate::lstm::spec::LstmSpec::plain(6, 12);
        let w = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(w.clone());
        let calib = make_seqs(&mut rng, 2, 8, 6);
        let stats = CalibrationStats::collect(&float, &calib);
        let integer = quantize_lstm(&w, &stats, QuantizeOptions::default());
        let x: Vec<f32> = (0..6).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let qx: Vec<i8> =
            x.iter().map(|&v| integer.input_q.quantize(f64::from(v))).collect();
        let mut s1 = IntegerState::zeros(&integer);
        let mut s2 = IntegerState::zeros(&integer);
        integer.step(&x, &mut s1);
        integer.step_q(&qx, &mut s2);
        assert_eq!(s1.c, s2.c);
        assert_eq!(s1.h, s2.h);
    }
}
