//! Hybrid LSTM (the Table-1 middle column): int8 *weights*, dynamic
//! floating-point activations — the strategy of ref. [6] the paper
//! compares against.
//!
//! At every step the activation vector is quantized on the fly
//! (symmetric, scale recomputed from the live min/max), multiplied
//! against the int8 weights into int32, then immediately dequantized
//! back to float for the elementwise parts. This gets the 4× weight
//! memory win and most of the matmul speedup, but keeps floats on the
//! execution path — exactly the hardware-portability gap the paper's
//! integer-only strategy removes.

use crate::quant::params::SymmetricQuant;
use crate::quant::recipe::Gate;
use crate::quant::{quantize_symmetric_i4, quantize_symmetric_i8};
use crate::tensor::Matrix;
use super::float_cell::{FloatBatchState, FloatState};
use super::integer_cell::WeightMat;
use super::layernorm::layernorm_f32;
use super::quantize::WeightBits;
use super::spec::{gate_index, LstmSpec, LstmWeights};

/// One gate's quantized weights, packed at build time into the storage
/// form the register-tiled batched GEMM executes — int8 panels by
/// default, nibble-packed int4 panels under [`WeightBits::Int4`] (the
/// sequential matvec path reads the same storage).
#[derive(Debug, Clone)]
struct HybridGate {
    w: WeightMat,
    w_scale: f64,
    r: WeightMat,
    r_scale: f64,
    bias: Vec<f32>,
    peephole: Option<Vec<f32>>,
    ln_weight: Option<Vec<f32>>,
}

/// The hybrid engine. State remains float ([`FloatState`]).
#[derive(Debug)]
pub struct HybridLstm {
    pub spec: LstmSpec,
    gates: [Option<HybridGate>; 4],
    w_proj: Option<(WeightMat, f64)>,
    b_proj: Option<Vec<f32>>,
    scratch: std::cell::RefCell<Scratch>,
    batch_scratch: std::cell::RefCell<BatchScratch>,
}

/// Batch-major scratch: per-lane dynamic-quantization scales plus
/// batched accumulators, lazily resized to the live batch.
#[derive(Debug, Clone)]
struct BatchScratch {
    qx: Matrix<i8>,
    qh: Matrix<i8>,
    qm: Matrix<i8>,
    sx: Vec<f64>,
    sh: Vec<f64>,
    acc_cell: Matrix<i32>,
    acc_out: Matrix<i32>,
    pre: [Matrix<f32>; 4],
    tmp: Vec<f32>,
    m: Matrix<f32>,
}

impl BatchScratch {
    fn empty() -> Self {
        BatchScratch {
            qx: Matrix::zeros(0, 0),
            qh: Matrix::zeros(0, 0),
            qm: Matrix::zeros(0, 0),
            sx: Vec::new(),
            sh: Vec::new(),
            acc_cell: Matrix::zeros(0, 0),
            acc_out: Matrix::zeros(0, 0),
            pre: std::array::from_fn(|_| Matrix::zeros(0, 0)),
            tmp: Vec::new(),
            m: Matrix::zeros(0, 0),
        }
    }

    fn ensure(&mut self, spec: &LstmSpec, batch: usize) {
        if self.m.rows != batch || self.m.cols != spec.n_cell {
            // Every buffer is fully overwritten before it is read, so
            // resize-in-place (allocation-reusing) is safe.
            self.qx.resize(batch, spec.n_input);
            self.qh.resize(batch, spec.n_output);
            self.qm.resize(batch, spec.n_cell);
            self.sx.resize(batch, 0.0);
            self.sh.resize(batch, 0.0);
            self.acc_cell.resize(batch, spec.n_cell);
            self.acc_out.resize(batch, spec.n_output);
            for p in &mut self.pre {
                p.resize(batch, spec.n_cell);
            }
            self.tmp.resize(spec.n_cell, 0.0);
            self.m.resize(batch, spec.n_cell);
        }
    }
}

#[derive(Debug, Clone)]
struct Scratch {
    qx: Vec<i8>,
    qh: Vec<i8>,
    qm: Vec<i8>,
    acc: Vec<i32>,
    pre: [Vec<f32>; 4],
    tmp: Vec<f32>,
    m: Vec<f32>,
}

/// Dynamically quantize a float vector: symmetric int8 with live scale.
fn dynamic_quantize(x: &[f32], out: &mut [i8]) -> f64 {
    let max_abs = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let q = SymmetricQuant::for_weights_i8(f64::from(max_abs));
    for (o, &v) in out.iter_mut().zip(x) {
        *o = q.quantize_i8(f64::from(v));
    }
    q.scale
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Quantize one float weight matrix into the hybrid storage form at
/// the requested bit width.
fn hybrid_quantize(w: &Matrix<f32>, bits: WeightBits) -> (WeightMat, f64) {
    match bits {
        WeightBits::Int8 => {
            let (q, s) = quantize_symmetric_i8(w);
            (WeightMat::dense(q), s.scale)
        }
        WeightBits::Int4 => {
            let (q, s) = quantize_symmetric_i4(w);
            (WeightMat::int4(&q), s.scale)
        }
    }
}

impl HybridLstm {
    /// Quantize float master weights into the hybrid form (int8
    /// weights, the Table-1 middle column).
    pub fn from_weights(weights: &LstmWeights) -> Self {
        Self::from_weights_bits(weights, WeightBits::Int8)
    }

    /// Quantize float master weights into the hybrid form at an
    /// explicit weight bit width: [`WeightBits::Int4`] nibble-packs the
    /// gate/projection matrices (half the resident bytes) with the
    /// symmetric `max(|T|)/7` scale; activations stay dynamically
    /// quantized int8 either way.
    pub fn from_weights_bits(weights: &LstmWeights, bits: WeightBits) -> Self {
        let spec = weights.spec;
        let mk = |g: Gate| -> Option<HybridGate> {
            weights.gate_opt(g).map(|gw| {
                let (w, w_scale) = hybrid_quantize(&gw.w, bits);
                let (r, r_scale) = hybrid_quantize(&gw.r, bits);
                HybridGate {
                    w,
                    w_scale,
                    r,
                    r_scale,
                    bias: gw.bias.clone(),
                    peephole: gw.peephole.clone(),
                    ln_weight: gw.ln_weight.clone(),
                }
            })
        };
        let gates = [mk(Gate::Input), mk(Gate::Forget), mk(Gate::Update), mk(Gate::Output)];
        let w_proj = weights.w_proj.as_ref().map(|w| hybrid_quantize(w, bits));
        let scratch = Scratch {
            qx: vec![0; spec.n_input],
            qh: vec![0; spec.n_output],
            qm: vec![0; spec.n_cell],
            acc: vec![0; spec.n_cell.max(spec.n_output)],
            pre: std::array::from_fn(|_| vec![0.0; spec.n_cell]),
            tmp: vec![0.0; spec.n_cell],
            m: vec![0.0; spec.n_cell],
        };
        HybridLstm {
            spec,
            gates,
            w_proj,
            b_proj: weights.b_proj.clone(),
            scratch: std::cell::RefCell::new(scratch),
            batch_scratch: std::cell::RefCell::new(BatchScratch::empty()),
        }
    }

    /// Quantized-weight bytes (Table 1 size accounting).
    pub fn weight_bytes(&self) -> usize {
        let mut bytes = 0;
        for g in self.gates.iter().flatten() {
            bytes += g.w.storage_bytes() + g.r.storage_bytes() + 4 * g.bias.len();
            bytes += g.peephole.as_ref().map_or(0, |p| 4 * p.len());
            bytes += g.ln_weight.as_ref().map_or(0, |l| 4 * l.len());
        }
        if let Some((w, _)) = &self.w_proj {
            bytes += w.storage_bytes();
        }
        bytes += self.b_proj.as_ref().map_or(0, |b| 4 * b.len());
        bytes
    }

    fn gate(&self, g: Gate) -> &HybridGate {
        self.gates[gate_index(g)].as_ref().expect("gate absent")
    }

    /// One time step (single sequence).
    pub fn step(&self, x: &[f32], state: &mut FloatState) {
        let spec = self.spec;
        assert_eq!(x.len(), spec.n_input);
        let mut s = self.scratch.borrow_mut();
        let Scratch { qx, qh, qm, acc, pre, tmp, m } = &mut *s;

        // Dynamic quantization of the two activation vectors (the
        // "on-the-fly" cost the integer path eliminates).
        let sx = dynamic_quantize(x, qx);
        let sh = dynamic_quantize(&state.h, qh);

        let gate_list: [(Gate, usize); 4] = [
            (Gate::Input, 0),
            (Gate::Forget, 1),
            (Gate::Update, 2),
            (Gate::Output, 3),
        ];
        for (g, idx) in gate_list {
            if g == Gate::Input && !spec.has_input_gate() {
                continue;
            }
            let hg = self.gate(g);
            let out = &mut pre[idx];
            // W x (int8 matmul, dequantized with s_W * s_x).
            hg.w.matvec(qx, &[], &mut acc[..spec.n_cell]);
            let kx = (hg.w_scale * sx) as f32;
            for (o, &a) in out.iter_mut().zip(acc.iter()) {
                *o = a as f32 * kx;
            }
            // + R h.
            hg.r.matvec(qh, &[], &mut acc[..spec.n_cell]);
            let kh = (hg.r_scale * sh) as f32;
            for (o, &a) in out.iter_mut().zip(acc.iter()) {
                *o += a as f32 * kh;
            }
        }

        // Peepholes on i/f read c^{t-1}; bias/LN; then the nonlinear
        // part — all float, as in the hybrid strategy.
        for (g, idx) in [(Gate::Input, 0), (Gate::Forget, 1), (Gate::Update, 2)] {
            if g == Gate::Input && !spec.has_input_gate() {
                continue;
            }
            let hg = self.gate(g);
            if let Some(p) = &hg.peephole {
                for ((o, &pw), &cv) in pre[idx].iter_mut().zip(p).zip(state.c.iter()) {
                    *o += pw * cv;
                }
            }
            self.finish_pre(hg, &mut pre[idx], tmp);
        }

        for j in 0..spec.n_cell {
            let f = sigmoid(pre[1][j]);
            let i = if spec.has_input_gate() { sigmoid(pre[0][j]) } else { 1.0 - f };
            let z = pre[2][j].tanh();
            state.c[j] = i * z + f * state.c[j];
        }

        // Output gate: peephole reads c^t.
        {
            let hg = self.gate(Gate::Output);
            if let Some(p) = &hg.peephole {
                for ((o, &pw), &cv) in pre[3].iter_mut().zip(p).zip(state.c.iter()) {
                    *o += pw * cv;
                }
            }
            self.finish_pre(hg, &mut pre[3], tmp);
        }

        for j in 0..spec.n_cell {
            let o = sigmoid(pre[3][j]);
            m[j] = o * state.c[j].tanh();
        }

        if let Some((w_proj, wp_scale)) = &self.w_proj {
            let sm = dynamic_quantize(m, qm);
            w_proj.matvec(qm, &[], &mut acc[..spec.n_output]);
            let k = (wp_scale * sm) as f32;
            for (h, &a) in state.h.iter_mut().zip(acc.iter()) {
                *h = a as f32 * k;
            }
            if let Some(b) = &self.b_proj {
                for (h, &bv) in state.h.iter_mut().zip(b) {
                    *h += bv;
                }
            }
        } else {
            state.h.copy_from_slice(m);
        }
    }

    /// One batch-major time step: row `b` of `x` advances lane `b`,
    /// bit-exactly equal to per-lane [`Self::step`] — each lane's
    /// activation scale is still computed from that lane alone, so
    /// dynamic quantization is unchanged by batching.
    pub fn step_batch(&self, x: &Matrix<f32>, state: &mut FloatBatchState) {
        let spec = self.spec;
        let batch = x.rows;
        assert_eq!(x.cols, spec.n_input);
        assert_eq!(state.c.rows, batch);
        assert_eq!(state.h.rows, batch);
        let mut s = self.batch_scratch.borrow_mut();
        s.ensure(&spec, batch);
        let BatchScratch { qx, qh, qm, sx, sh, acc_cell, acc_out, pre, tmp, m } =
            &mut *s;

        for b in 0..batch {
            sx[b] = dynamic_quantize(x.row(b), qx.row_mut(b));
            sh[b] = dynamic_quantize(state.h.row(b), qh.row_mut(b));
        }

        let gate_list: [(Gate, usize); 4] = [
            (Gate::Input, 0),
            (Gate::Forget, 1),
            (Gate::Update, 2),
            (Gate::Output, 3),
        ];
        for (g, idx) in gate_list {
            if g == Gate::Input && !spec.has_input_gate() {
                continue;
            }
            let hg = self.gate(g);
            hg.w.matmul_batch(qx, &[], acc_cell);
            for b in 0..batch {
                let kx = (hg.w_scale * sx[b]) as f32;
                for (o, &a) in pre[idx].row_mut(b).iter_mut().zip(acc_cell.row(b)) {
                    *o = a as f32 * kx;
                }
            }
            hg.r.matmul_batch(qh, &[], acc_cell);
            for b in 0..batch {
                let kh = (hg.r_scale * sh[b]) as f32;
                for (o, &a) in pre[idx].row_mut(b).iter_mut().zip(acc_cell.row(b)) {
                    *o += a as f32 * kh;
                }
            }
        }

        for (g, idx) in [(Gate::Input, 0), (Gate::Forget, 1), (Gate::Update, 2)] {
            if g == Gate::Input && !spec.has_input_gate() {
                continue;
            }
            let hg = self.gate(g);
            if let Some(p) = &hg.peephole {
                for b in 0..batch {
                    for ((o, &pw), &cv) in
                        pre[idx].row_mut(b).iter_mut().zip(p).zip(state.c.row(b).iter())
                    {
                        *o += pw * cv;
                    }
                }
            }
            for b in 0..batch {
                self.finish_pre(hg, pre[idx].row_mut(b), tmp);
            }
        }

        for (j, c) in state.c.data.iter_mut().enumerate() {
            let f = sigmoid(pre[1].data[j]);
            let i = if spec.has_input_gate() { sigmoid(pre[0].data[j]) } else { 1.0 - f };
            let z = pre[2].data[j].tanh();
            *c = i * z + f * *c;
        }

        // Output gate: peephole reads c^t.
        {
            let hg = self.gate(Gate::Output);
            if let Some(p) = &hg.peephole {
                for b in 0..batch {
                    for ((o, &pw), &cv) in
                        pre[3].row_mut(b).iter_mut().zip(p).zip(state.c.row(b).iter())
                    {
                        *o += pw * cv;
                    }
                }
            }
            for b in 0..batch {
                self.finish_pre(hg, pre[3].row_mut(b), tmp);
            }
        }

        for (j, mv) in m.data.iter_mut().enumerate() {
            let o = sigmoid(pre[3].data[j]);
            *mv = o * state.c.data[j].tanh();
        }

        if let Some((w_proj, wp_scale)) = &self.w_proj {
            for b in 0..batch {
                let sm = dynamic_quantize(m.row(b), qm.row_mut(b));
                sx[b] = sm; // reuse the lane-scale scratch for `m`
            }
            w_proj.matmul_batch(qm, &[], acc_out);
            for b in 0..batch {
                let k = (wp_scale * sx[b]) as f32;
                for (h, &a) in state.h.row_mut(b).iter_mut().zip(acc_out.row(b)) {
                    *h = a as f32 * k;
                }
            }
            if let Some(bias) = &self.b_proj {
                for b in 0..batch {
                    for (h, &bv) in state.h.row_mut(b).iter_mut().zip(bias) {
                        *h += bv;
                    }
                }
            }
        } else {
            state.h.data.copy_from_slice(&m.data);
        }
    }

    fn finish_pre(&self, hg: &HybridGate, pre: &mut [f32], tmp: &mut [f32]) {
        if self.spec.flags.layer_norm {
            let gamma = hg.ln_weight.as_ref().expect("LN variant needs L");
            tmp.copy_from_slice(pre);
            layernorm_f32(tmp, gamma, &hg.bias, pre);
        } else {
            for (p, &b) in pre.iter_mut().zip(hg.bias.iter()) {
                *p += b;
            }
        }
    }

    /// Run a full sequence.
    pub fn run_sequence(&self, xs: &[Vec<f32>], state: &mut FloatState) -> Vec<Vec<f32>> {
        xs.iter()
            .map(|x| {
                self.step(x, state);
                state.h.clone()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::float_cell::FloatLstm;
    use crate::quant::recipe::VariantFlags;
    use crate::util::Pcg32;

    fn compare_with_float(flags: VariantFlags, tol: f64) {
        let mut rng = Pcg32::seeded(1234);
        let mut spec = LstmSpec::plain(12, 24);
        spec.flags = flags;
        if flags.projection {
            spec.n_output = 16;
        }
        let w = LstmWeights::random(spec, &mut rng);
        let float = FloatLstm::new(w.clone());
        let hybrid = HybridLstm::from_weights(&w);
        let mut fs = FloatState::zeros(&spec);
        let mut hs = FloatState::zeros(&spec);
        let xs: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..12).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let fo = float.run_sequence(&xs, &mut fs);
        let ho = hybrid.run_sequence(&xs, &mut hs);
        let mut worst = 0f64;
        for (a, b) in fo.iter().zip(&ho) {
            for (&x, &y) in a.iter().zip(b) {
                worst = worst.max(f64::from((x - y).abs()));
            }
        }
        assert!(worst < tol, "{flags:?}: worst output divergence {worst}");
    }

    #[test]
    fn hybrid_tracks_float_plain() {
        compare_with_float(VariantFlags::plain(), 0.05);
    }

    #[test]
    fn hybrid_tracks_float_all_variants() {
        for flags in VariantFlags::all_eight() {
            compare_with_float(flags, 0.08);
        }
    }

    #[test]
    fn hybrid_tracks_float_cifg() {
        let mut flags = VariantFlags::plain();
        flags.cifg = true;
        compare_with_float(flags, 0.05);
        flags.layer_norm = true;
        compare_with_float(flags, 0.08);
    }

    #[test]
    fn weight_bytes_quarter_of_float() {
        let mut rng = Pcg32::seeded(5);
        let spec = LstmSpec::plain(128, 256);
        let w = LstmWeights::random(spec, &mut rng);
        let hybrid = HybridLstm::from_weights(&w);
        let float_bytes = w.param_count() * 4;
        let ratio = float_bytes as f64 / hybrid.weight_bytes() as f64;
        assert!(ratio > 3.5, "ratio {ratio}");
    }

    #[test]
    fn int4_tracks_float_with_looser_tolerance() {
        // The int4 hybrid trades accuracy for bytes: it must still
        // track the float reference, just with a wider envelope than
        // the int8 engine's 0.05.
        let mut rng = Pcg32::seeded(1235);
        let spec = LstmSpec::plain(12, 24);
        let w = LstmWeights::random(spec, &mut rng);
        let float = crate::lstm::float_cell::FloatLstm::new(w.clone());
        let hybrid = HybridLstm::from_weights_bits(&w, WeightBits::Int4);
        let mut fs = FloatState::zeros(&spec);
        let mut hs = FloatState::zeros(&spec);
        let xs: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..12).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let fo = float.run_sequence(&xs, &mut fs);
        let ho = hybrid.run_sequence(&xs, &mut hs);
        let mut worst = 0f64;
        for (a, b) in fo.iter().zip(&ho) {
            for (&x, &y) in a.iter().zip(b) {
                worst = worst.max(f64::from((x - y).abs()));
            }
        }
        assert!(worst < 0.5, "int4 worst output divergence {worst}");
    }

    #[test]
    fn int4_weight_bytes_at_most_55_percent_of_int8() {
        let mut rng = Pcg32::seeded(6);
        let spec = LstmSpec::plain(128, 256);
        let w = LstmWeights::random(spec, &mut rng);
        let int8 = HybridLstm::from_weights(&w);
        let int4 = HybridLstm::from_weights_bits(&w, WeightBits::Int4);
        let ratio = int4.weight_bytes() as f64 / int8.weight_bytes() as f64;
        assert!(ratio <= 0.55, "int4/int8 byte ratio {ratio}");
        // And float/int4 lands near 8x.
        let float_bytes = w.param_count() * 4;
        assert!(
            float_bytes as f64 / int4.weight_bytes() as f64 > 6.0,
            "float/int4 ratio {}",
            float_bytes as f64 / int4.weight_bytes() as f64
        );
    }
}
